"""Per-tenant Session state: repeat clients get *tracked* solves.

The prefill/decode analogy from ``launch/serve.py``: a tenant's first
request pays the cold Krylov budget (prefill); every later request against
its drifted operand warm-starts from the previous Ritz basis and runs the
Session's learned refine budget (decode) — strictly fewer GK iterations
end-to-end, which is the acceptance bar ``tests/test_serve.py`` pins.

The registry is a bounded LRU: past ``max_tenants`` live sessions the
coldest is evicted — checkpointed first (``repro.checkpoint``, atomic)
when a ``checkpoint_dir`` is configured, so an evicted tenant that
returns restores its factorization and keeps refining instead of
re-paying prefill.
"""
from __future__ import annotations

import collections
import os
import threading
import zlib
from typing import Any, Dict, Optional

import jax

from repro.api.session import Session
from repro.api.spec import SVDSpec

Array = jax.Array


def _tenant_key(base: Array, tenant_id: str) -> Array:
    """Deterministic per-tenant key stream seed (stable across restarts,
    unlike ``hash``)."""
    return jax.random.fold_in(base, zlib.crc32(str(tenant_id).encode()))


class TenantRegistry:
    """LRU map tenant-id -> :class:`~repro.api.session.Session`.

    Thread-safe for lookups/insertions; the sessions themselves are NOT —
    the server funnels all tenant solves through its single dispatch
    worker, which is the supported usage.
    """

    def __init__(self, spec: Optional[SVDSpec] = None, *,
                 max_tenants: int = 32,
                 checkpoint_dir: Optional[str] = None,
                 key: Optional[Array] = None,
                 refine_iters: Optional[int] = None,
                 restart_angle: float = 0.5,
                 update_tol: Optional[float] = None):
        if max_tenants < 1:
            raise ValueError(f"max_tenants must be >= 1, got {max_tenants}")
        self.spec = spec or SVDSpec()
        self.max_tenants = int(max_tenants)
        self.checkpoint_dir = checkpoint_dir
        self.refine_iters = refine_iters
        self.restart_angle = float(restart_angle)
        # parity gate for the zero-iteration structured-drift path; None
        # lets each session learn it from its own stream (see Session).
        self.update_tol = update_tol
        self._key = key if key is not None else jax.random.key(0)
        self._sessions: "collections.OrderedDict[str, Session]" = \
            collections.OrderedDict()
        self._lock = threading.RLock()
        self._counters = {"creates": 0, "restores": 0, "evictions": 0,
                          "reuses": 0, "restore_failures": 0}

    def _tenant_dir(self, tenant_id: str) -> Optional[str]:
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, str(tenant_id))

    # --- lookup ---------------------------------------------------------
    def get(self, tenant_id: str, A: Any) -> Session:
        """The tenant's session (most-recently-used), created — or
        restored from its eviction checkpoint — around operand ``A``."""
        with self._lock:
            sess = self._sessions.get(tenant_id)
            if sess is not None:
                self._sessions.move_to_end(tenant_id)
                self._counters["reuses"] += 1
                return sess
            sess = self._make(tenant_id, A)
            self._sessions[tenant_id] = sess
            while len(self._sessions) > self.max_tenants:
                old_id, old = self._sessions.popitem(last=False)
                self._counters["evictions"] += 1
                self._checkpoint(old_id, old)
            return sess

    def _make(self, tenant_id: str, A: Any) -> Session:
        key = _tenant_key(self._key, tenant_id)
        directory = self._tenant_dir(tenant_id)
        if directory is not None:
            try:
                sess = Session.restore(directory, A, key=key)
                self._counters["restores"] += 1
                return sess
            except FileNotFoundError:
                pass
            except Exception:    # noqa: BLE001 — a tenant must never be
                # unservable because its checkpoint rotted or the restore
                # failpoint fired: fall back to a fresh (cold) session.
                # Session.restore already skipped to the newest VERIFIED
                # step, so landing here means none survived.
                self._counters["restore_failures"] += 1
        self._counters["creates"] += 1
        # track_residuals costs r extra matvecs + a host sync per solve —
        # a latency-critical serving session reads residuals from the
        # in-graph ConvergenceInfo instead.  Structured-drift (delta)
        # requests still hit the gated update path: the session measures
        # its gate reference lazily, only when the first delta arrives.
        return Session(A, self.spec, key=key,
                       refine_iters=self.refine_iters,
                       restart_angle=self.restart_angle,
                       track_residuals=False,
                       update_tol=self.update_tol)

    def _checkpoint(self, tenant_id: str, sess: Session) -> None:
        directory = self._tenant_dir(tenant_id)
        if directory is not None and sess.fact is not None:
            sess.save(directory, keep=1)

    def touch(self, tenant_id: str) -> Optional[Session]:
        """The tenant's live session, bumped to most-recently-used; None
        when not resident.  Delta (structured-drift) requests route here:
        unlike :meth:`get` they carry no full operand to create a session
        around, so a missing tenant is the caller's error to surface."""
        with self._lock:
            sess = self._sessions.get(tenant_id)
            if sess is not None:
                self._sessions.move_to_end(tenant_id)
                self._counters["reuses"] += 1
            return sess

    # --- maintenance ----------------------------------------------------
    def peek(self, tenant_id: str) -> Optional[Session]:
        """The tenant's live session without touching LRU order (stats /
        tests); None when not resident."""
        with self._lock:
            return self._sessions.get(tenant_id)

    def save_all(self) -> int:
        """Checkpoint every resident session (graceful shutdown)."""
        with self._lock:
            items = list(self._sessions.items())
        n = 0
        for tenant_id, sess in items:
            if self.checkpoint_dir is not None and sess.fact is not None:
                self._checkpoint(tenant_id, sess)
                n += 1
        return n

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {**self._counters, "resident": len(self._sessions)}


__all__ = ["TenantRegistry"]
