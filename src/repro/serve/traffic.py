"""Synthetic serve traffic: Zipf shape mix + slowly drifting tenant streams.

One generator feeds the CLI demo, the ``serve-smoke`` CI job and
``benchmarks/serve_bench.py`` so all three measure the same workload: a
head-heavy (Zipf) distribution over operand shapes — the regime where
shape bucketing and continuous batching pay — with an optional fraction of
requests pinned to repeat *tenants* whose operands drift slowly between
requests (the Session-tracking regime).

Pure numpy; operands are low-rank-plus-noise like the solver zoo, so every
request is a realistic partial-SVD target rather than white noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

# a head-heavy but bounded shape menu: several logical shapes per 32-grid
# bucket, so bucketing actually coalesces.
DEFAULT_SHAPES: Tuple[Tuple[int, int], ...] = (
    (96, 64), (90, 60), (80, 56), (64, 64), (120, 48), (48, 96),
)


@dataclasses.dataclass
class Request:
    """One synthetic serve request.

    ``kind="delta"`` carries the low-rank drift factors in ``delta``
    (``(U, s, Vt)`` with ``U (m, k)``, ``s (k,)``, ``Vt (k, n)``); ``A``
    is then the *post-drift* operand — kept for accuracy checking on the
    consumer side, never shipped to the server.  ``kind="entries"``
    carries an unstructured COO drift in ``entries`` (``(rows, cols,
    vals)``) with the same ``A`` convention.
    """

    A: np.ndarray
    shape: Tuple[int, int]
    tenant: Optional[str] = None
    kind: str = "factorize"
    delta: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    entries: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None


def zipf_choice(rng: np.random.Generator, k: int, size: int,
                a: float = 1.1) -> np.ndarray:
    """``size`` indices in [0, k) with a truncated-Zipf(a) rank law
    (index 0 = hottest)."""
    ranks = np.arange(1, k + 1, dtype=np.float64)
    p = ranks ** -a
    p /= p.sum()
    return rng.choice(k, size=size, p=p)


def lowrank_operand(rng: np.random.Generator, shape: Tuple[int, int],
                    rank: int, noise: float = 1e-3,
                    dtype=np.float32) -> np.ndarray:
    """Low-rank-plus-noise operand with a geometric spectrum (the zoo's
    default texture)."""
    m, n = shape
    r = min(rank, m, n)
    U = rng.standard_normal((m, r))
    V = rng.standard_normal((n, r))
    s = np.logspace(0.0, -2.0, r)
    A = (U * s) @ V.T + noise * rng.standard_normal((m, n))
    return np.asarray(A, dtype=dtype)


def entry_drift(rng: np.random.Generator, A: np.ndarray, *,
                drift: float, nnz: int, dtype=np.float32
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unstructured COO drift: ``nnz`` uniformly placed entry updates
    ``(rows, cols, vals)`` with ``||vals||_2 = drift * ||A||_F`` — the
    sparse/entrywise regime no low-rank factor pair can express."""
    m, n = A.shape
    rows = rng.integers(0, m, size=nnz).astype(np.int32)
    cols = rng.integers(0, n, size=nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(dtype)
    scale = drift * np.linalg.norm(A) / max(np.linalg.norm(vals), 1e-30)
    return rows, cols, (scale * vals).astype(dtype)


def lowrank_drift(rng: np.random.Generator, A: np.ndarray, *,
                  drift: float, drift_rank: int, dtype=np.float32
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Rank-``drift_rank`` drift factors ``(U, s, Vt)`` with
    ``||U diag(s) Vt||_F = drift * ||A||_F``."""
    m, n = A.shape
    k = max(1, min(drift_rank, m, n))
    U = rng.standard_normal((m, k)).astype(dtype)
    Vt = rng.standard_normal((k, n)).astype(dtype)
    W = U @ Vt
    scale = drift * np.linalg.norm(A) / max(np.linalg.norm(W), 1e-30)
    s = np.full((k,), scale, dtype)
    return U, s, Vt


def synthetic_stream(n_requests: int, *,
                     shapes: Sequence[Tuple[int, int]] = DEFAULT_SHAPES,
                     zipf_a: float = 1.1,
                     rank: int = 8,
                     tenants: int = 0,
                     tenant_fraction: float = 0.25,
                     drift: float = 1e-3,
                     estimate_fraction: float = 0.0,
                     structured_drift: bool = False,
                     drift_rank: int = 2,
                     entry_drift_nnz: int = 0,
                     seed: int = 0) -> Iterator[Request]:
    """Yield ``n_requests`` synthetic :class:`Request`\\ s.

    ``tenants > 0`` routes ~``tenant_fraction`` of the stream to that many
    repeat clients, each pinned to one shape with an operand that drifts
    by ``drift`` (relative Frobenius) per request — small enough that the
    Session refine path stays engaged.  ``estimate_fraction`` converts
    that share of the anonymous stream into rank-estimate requests.

    ``structured_drift=True`` makes every tenant drift a rank-
    ``drift_rank`` *structured* perturbation shipped as a ``kind="delta"``
    request (the factors, not the operand) — the regime where the serving
    stack's zero-iteration update path engages.  Tenant first-contact
    operands are then exactly rank-``rank`` (no additive noise), matching
    how a real incremental stream starts from a factorized state.

    ``entry_drift_nnz > 0`` instead ships every tenant drift as a
    ``kind="entries"`` request of that many COO triplets (unstructured —
    no factor pair exists), engaging the sketch-resident path.  Mutually
    exclusive with ``structured_drift``.
    """
    if structured_drift and entry_drift_nnz > 0:
        raise ValueError("structured_drift and entry_drift_nnz are "
                         "mutually exclusive drift regimes")
    rng = np.random.default_rng(seed)
    shapes = [tuple(s) for s in shapes]
    picks = zipf_choice(rng, len(shapes), n_requests, a=zipf_a)
    tenant_state: Dict[str, np.ndarray] = {}
    for i in range(n_requests):
        if tenants > 0 and rng.random() < tenant_fraction:
            tid = f"tenant-{int(rng.integers(tenants))}"
            A = tenant_state.get(tid)
            if A is None:
                shape = shapes[picks[i]]
                incremental = structured_drift or entry_drift_nnz > 0
                noise = 0.0 if incremental else 1e-3
                A = lowrank_operand(rng, shape, rank, noise=noise)
                tenant_state[tid] = A
                yield Request(A=A, shape=tuple(A.shape), tenant=tid)
                continue
            if entry_drift_nnz > 0:
                rows, cols, vals = entry_drift(rng, A, drift=drift,
                                               nnz=entry_drift_nnz,
                                               dtype=A.dtype)
                A = A.copy()
                np.add.at(A, (rows, cols), vals)
                tenant_state[tid] = A
                yield Request(A=A, shape=tuple(A.shape), tenant=tid,
                              kind="entries",
                              entries=(rows, cols, vals))
                continue
            if structured_drift:
                U, s, Vt = lowrank_drift(rng, A, drift=drift,
                                         drift_rank=drift_rank,
                                         dtype=A.dtype)
                A = (A + (U * s) @ Vt).astype(A.dtype)
                tenant_state[tid] = A
                yield Request(A=A, shape=tuple(A.shape), tenant=tid,
                              kind="delta", delta=(U, s, Vt))
                continue
            step = rng.standard_normal(A.shape).astype(A.dtype)
            scale = drift * np.linalg.norm(A) / max(
                np.linalg.norm(step), 1e-30)
            A = A + scale * step
            tenant_state[tid] = A
            yield Request(A=A, shape=tuple(A.shape), tenant=tid)
            continue
        shape = shapes[picks[i]]
        kind = "estimate" if rng.random() < estimate_fraction \
            else "factorize"
        yield Request(A=lowrank_operand(rng, shape, rank), shape=shape,
                      kind=kind)


__all__ = ["DEFAULT_SHAPES", "Request", "entry_drift", "lowrank_drift",
           "lowrank_operand", "synthetic_stream", "zipf_choice"]
