"""The solve server: intake -> bucket -> continuous batch -> plan cache.

One :class:`SolveServer` owns a solve configuration (an ``SVDSpec``) and
serves three request kinds through a single dispatch worker:

* anonymous ``factorize`` — bucketed, coalesced by the continuous batcher
  and dispatched through ``SolverPlan.solve_batched`` (one ``jit(vmap)``
  executable per (group, padded-batch-size) signature, shared process-wide
  via the plan LRU).  Batch sizes are padded up to powers of two by
  repeating the last request, so the executable count per group is
  ``O(log max_batch)``, not ``O(max_batch)``.
* ``estimate`` — Algorithm-3 rank estimates, staged per logical shape with
  the in-graph loop (``host_loop=False``) so repeat shapes reuse one
  executable.
* tenant ``factorize`` — routed to the tenant's
  :class:`~repro.api.session.Session`; repeat requests run the tracked
  refine path (strictly fewer GK iterations than cold).
* tenant ``delta`` — a *structured drift* against the tenant's tracked
  state: the payload is the low-rank drift itself (``LowRankOp`` or raw
  ``(U, s, Vt)`` factors), not a full operand.  Routed through
  ``Session.delta``, which takes the zero-iteration rank-k update path
  when the measured residual passes the parity gate (see
  ``repro.core.update``) and falls back to refine/restart otherwise.

Accuracy contract: in ``mode="exact"`` (default) every solver input is
the caller's logical operand, bit-for-bit — padding is transport-only.
``mode="shared"`` solves at bucket shape for maximal executable sharing,
with the documented roundoff-level σ perturbation (see ``serve.bucket``).
Rank estimates always run exact.

Resilience (see ``serve.resilience`` for the failure taxonomy):

* **quarantine** — ``submit`` rejects NaN/Inf operands with
  :class:`~repro.serve.resilience.PoisonedOperand` before they can enter
  a batch (one poisoned example contaminates every co-batched result of
  a vmapped stacked solve).
* **deadlines** — per-request (or server-default) deadlines are enforced
  at *dispatch admission*: an expired ticket is failed with
  :class:`~repro.serve.resilience.DeadlineExceeded` without burning a
  batch slot or solver time.
* **retry** — transient dispatch failures
  (:class:`~repro.runtime.faults.TransientFault`) are retried with
  bounded exponential backoff before the batch is failed.
* **circuit breaker** — per-group consecutive-failure breaker; while
  open, anonymous solve groups take the degraded path (or fail fast with
  :class:`~repro.serve.resilience.CircuitOpen`), half-opening on a timer.
* **degraded mode** — under breaker-open, deadline pressure, or primary
  failure, anonymous solves are answered by a cheaper plan (default
  ``method="gnystrom"`` — a single operator sweep — configurable via
  ``degraded_method``, reduced oversample).  EVERY degraded answer is
  gated by an HMT randomized residual probe: pass → the result is
  labeled ``meta={"degraded": True, ...}``; fail →
  :class:`~repro.serve.resilience.DegradedRejected`.  The server never
  silently returns an uncertified cheap answer.
* **supervision** — the batcher restarts a crashed/hung dispatch worker,
  failing only the in-flight batch (see ``serve.batcher``).

The stats endpoint (:meth:`SolveServer.stats`) reports requests/sec,
p50/p99 latency (``runtime.telemetry.LatencyStats``), the bucket hit rate
(fraction of requests landing on an already-staged (group, batch)
signature — ground-truthed against ``plan_cache_stats`` in the tests),
batch-size histogram, tenant-session counters, the plan-cache counters,
and the :meth:`SolveServer.health` block (breaker states, worker
restarts, quarantines, deadline drops, degraded fraction).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Hashable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import SolverPlan, plan as _make_plan, plan_cache_stats
from repro.api.spec import SVDSpec
from repro.core.operators import DenseOp, LowRankOp
from repro.runtime.faults import TransientFault
from repro.runtime.telemetry import LatencyStats
from repro.serve.batcher import ContinuousBatcher, QueueFull, Ticket
from repro.serve.resilience import (CircuitBreaker, CircuitOpen,
                                    DeadlineExceeded, DegradedRejected,
                                    finite_or_raise, residual_probe,
                                    retry_with_backoff)
from repro.serve.bucket import (DEFAULT_QUANTUM, Bucketed, embed,
                                stack_buckets, unpad_factors)
from repro.serve.tenant import TenantRegistry

Array = jax.Array

_KINDS = ("factorize", "estimate", "delta", "entries")
_MODES = ("exact", "shared")


@dataclasses.dataclass
class ServeResult:
    """What a resolved ticket carries.

    ``value`` is a ``Factorization`` (factorize/tenant) or a
    ``RankEstimate`` (estimate); ``info`` the per-request
    ``ConvergenceInfo`` when the path captures one; ``batch`` the size of
    the coalesced batch this request rode in; ``meta`` path-specific
    extras (tenant solves report the Session's kind + iteration count).
    """

    kind: str
    value: Any
    batch: int = 1
    info: Any = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _pow2_pad(n: int) -> int:
    return 1 << (n - 1).bit_length()


# Process-wide (NOT per-server): each server instance jitting its own
# closure would recompile this per (server, batch size) — ~100ms a pop on
# every fresh server's first batches.  Shared, it stages once per
# (key aval, batch size) for the life of the process.
_FOLD_KEYS = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))


class SolveServer:
    """Multi-tenant factorization service over one ``SVDSpec``.

    Parameters
    ----------
    spec            solve configuration (``**overrides`` merge like
                    ``plan(...)``).
    quantum         bucket granularity (dims round up to multiples).
    mode            "exact" (bit-identical inputs, default) | "shared"
                    (solve at bucket shape, maximal executable sharing).
    max_batch       continuous-batching flush size.
    window_ms       continuous-batching deadline window.
    max_queue       backpressure bound; beyond it ``submit`` raises
                    :class:`~repro.serve.batcher.QueueFull`.
    max_tenants     resident tenant-session LRU capacity.
    checkpoint_dir  evicted tenant sessions checkpoint here (optional).
    key             base PRNG key; per-request keys are folded in.
    deadline_ms     default per-request deadline (None = no deadline);
                    individual ``submit(..., deadline_ms=)`` overrides.
    hang_timeout_s  restart the dispatch worker when a single dispatch
                    overruns this (None disables hang detection).
    max_retries     bounded retries for transient dispatch failures.
    retry_backoff_ms  base backoff; doubles per attempt.
    breaker_threshold consecutive batch failures that open a group's
                    circuit breaker.
    breaker_reset_s seconds an open breaker sheds before half-opening.
    degraded        answer with the cheap plan under breaker-open /
                    deadline pressure / primary failure (anonymous
                    solves only); False fails fast instead.
    degraded_method in-graph solver backing the degraded plan (default
                    "gnystrom": one operator sweep per shed answer);
                    reported in ``meta["method"]``.
    degraded_tol    residual-probe gate: a degraded answer whose HMT
                    probe exceeds this is rejected, never returned.
    degrade_under_ms  take the degraded path outright when a ticket has
                    less than this left on its deadline at admission
                    (None = only under breaker-open / failure).
    """

    def __init__(self, spec: Optional[SVDSpec] = None, *,
                 quantum: int = DEFAULT_QUANTUM,
                 mode: str = "exact",
                 max_batch: int = 8,
                 window_ms: float = 4.0,
                 max_queue: int = 256,
                 max_tenants: int = 32,
                 checkpoint_dir: Optional[str] = None,
                 key: Optional[Array] = None,
                 deadline_ms: Optional[float] = None,
                 hang_timeout_s: Optional[float] = 30.0,
                 max_retries: int = 2,
                 retry_backoff_ms: float = 10.0,
                 breaker_threshold: int = 5,
                 breaker_reset_s: float = 5.0,
                 degraded: bool = True,
                 degraded_method: str = "gnystrom",
                 degraded_tol: float = 0.35,
                 degrade_under_ms: Optional[float] = None,
                 **overrides):
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        spec = spec or SVDSpec()
        if overrides:
            spec = spec.replace(**overrides)
        self.spec = spec
        self.quantum = int(quantum)
        self.mode = mode
        self.plan: SolverPlan = _make_plan(spec)
        # estimates stage per shape with the in-graph loop: a server must
        # not stall its dispatch thread on per-iteration host round-trips.
        self._est_plan: SolverPlan = _make_plan(spec.replace(host_loop=False))
        self.deadline_ms = deadline_ms
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_ms) / 1e3
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_reset_s = float(breaker_reset_s)
        self.degraded_tol = float(degraded_tol)
        self.degrade_under_s = (None if degrade_under_ms is None
                                else float(degrade_under_ms) / 1e3)
        self._breakers: Dict[Hashable, CircuitBreaker] = {}
        # the degraded plan: same rank contract, a cheap in-graph solver
        # (default single-pass generalized Nyström — one operator sweep;
        # ``degraded_method`` picks any registered in-graph method, e.g.
        # "rsvd" for the pre-breaker behaviour).  Built eagerly so the
        # first degraded batch doesn't pay plan construction inside a
        # failure storm; its executables stage lazily (or via warmup).
        self.degraded_method = str(degraded_method)
        self._deg_plan: Optional[SolverPlan] = None
        if degraded:
            self._deg_plan = _make_plan(spec.replace(
                method=self.degraded_method, host_loop=False,
                oversample=min(spec.oversample, 4), power_iters=0))
        self.tenants = TenantRegistry(
            spec, max_tenants=max_tenants, checkpoint_dir=checkpoint_dir,
            key=key)
        self._base_key = key if key is not None else jax.random.key(0)
        self._seq = 0
        self._lock = threading.Lock()
        self._counters = {"submitted": 0, "completed": 0, "rejected": 0,
                          "cancelled": 0, "timeouts": 0, "errors": 0,
                          "batches": 0, "tenant_requests": 0,
                          "bucket_hits": 0, "bucket_misses": 0,
                          "quarantined": 0, "deadline_drops": 0,
                          "retries": 0, "degraded": 0,
                          "degraded_rejected": 0, "breaker_open_shed": 0}
        self._batch_hist: Dict[int, int] = {}
        self._seen_signatures: set = set()
        self.latency = LatencyStats()
        self._t0 = time.perf_counter()
        self._closed = False
        self.batcher = ContinuousBatcher(
            self._dispatch, max_batch=max_batch, window_ms=window_ms,
            max_queue=max_queue, hang_timeout_s=hang_timeout_s)

    # --- intake ---------------------------------------------------------
    def _next_seq(self) -> int:
        """Per-request key *sequence number* — the key itself materializes
        at dispatch (one vmapped fold_in per batch), keeping the submit
        path free of jax ops."""
        with self._lock:
            seq = self._seq
            self._seq += 1
        return seq

    def _request_key(self, seq: int) -> Array:
        return jax.random.fold_in(self._base_key, seq)

    def _group(self, kind: str, tenant: Optional[str],
               b: Bucketed) -> Hashable:
        if tenant is not None:
            return ("tenant", str(tenant))
        dtype = str(b.data.dtype)
        if kind == "estimate":
            return ("estimate", b.logical_shape, dtype)
        if self.mode == "shared":
            return ("solve", b.bucket, dtype)
        return ("solve", b.logical_shape, dtype)

    def submit(self, A, *, kind: str = "factorize",
               tenant: Optional[str] = None,
               deadline_ms: Optional[float] = None) -> Ticket:
        """Enqueue one request; returns its :class:`Ticket` immediately.

        Raises :class:`QueueFull` under backpressure — the request was
        NOT accepted; retry with backoff.  Raises
        :class:`~repro.serve.resilience.PoisonedOperand` for NaN/Inf
        operands (quarantined before they can contaminate a batch).
        ``deadline_ms`` overrides the server default; expired requests
        are dropped at dispatch admission with
        :class:`~repro.serve.resilience.DeadlineExceeded`.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
        if kind == "estimate" and tenant is not None:
            raise ValueError("estimate requests are stateless; "
                             "tenant routing applies to factorize only")
        try:
            finite_or_raise(A, what=f"{kind} operand")
        except Exception:
            with self._lock:
                self._counters["quarantined"] += 1
            raise
        if deadline_ms is None:
            deadline_ms = self.deadline_ms
        deadline_s = None if deadline_ms is None else float(deadline_ms) / 1e3
        if kind == "delta":
            # structured drift against a tenant's tracked state: ``A`` is
            # the drift itself — a LowRankOp or raw (U, s, Vt) factors —
            # not an operand, so it bypasses bucketing entirely.  The
            # group stays ("tenant", id): deltas serialize FIFO with the
            # tenant's factorize requests on the dispatch worker.
            if tenant is None:
                raise ValueError("delta requests require tenant= routing; "
                                 "there is no anonymous tracked state to "
                                 "update")
            payload = {"delta": A, "kind": kind, "tenant": tenant,
                       "seq": self._next_seq()}
            group: Hashable = ("tenant", str(tenant))
        elif kind == "entries":
            # unstructured drift as raw COO triplets (rows, cols, vals)
            # against a tenant's tracked state: no operand transport, no
            # bucketing — the triplets fold into the tenant session's
            # resident sketch on the dispatch worker.  Same FIFO tenant
            # group as delta.
            if tenant is None:
                raise ValueError("entries requests require tenant= "
                                 "routing; there is no anonymous tracked "
                                 "state to fold into")
            try:
                rows, cols, vals = A
            except (TypeError, ValueError):
                raise ValueError("entries requests ship a (rows, cols, "
                                 "vals) COO triplet") from None
            payload = {"entries": (rows, cols, vals), "kind": kind,
                       "tenant": tenant, "seq": self._next_seq()}
            group = ("tenant", str(tenant))
        else:
            b = embed(A, self.quantum)
            payload = {"bucketed": b, "kind": kind, "tenant": tenant,
                       "seq": self._next_seq()}
            group = self._group(kind, tenant, b)
        try:
            ticket = self.batcher.submit(group, payload,
                                         deadline_s=deadline_s)
        except QueueFull:
            with self._lock:
                self._counters["rejected"] += 1
            raise
        with self._lock:
            self._counters["submitted"] += 1
            if tenant is not None:
                self._counters["tenant_requests"] += 1
        return ticket

    def solve(self, A, *, kind: str = "factorize",
              tenant: Optional[str] = None,
              timeout: Optional[float] = 30.0,
              deadline_ms: Optional[float] = None) -> ServeResult:
        """Synchronous submit + wait.  On timeout the request is cancelled
        (it will never reach the solver) and ``TimeoutError`` re-raises."""
        ticket = self.submit(A, kind=kind, tenant=tenant,
                             deadline_ms=deadline_ms)
        try:
            return ticket.result(timeout)
        except TimeoutError:
            self.cancel(ticket)
            with self._lock:
                self._counters["timeouts"] += 1
            raise

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a submitted ticket (counted in the stats)."""
        won = ticket.cancel()
        if won:
            with self._lock:
                self._counters["cancelled"] += 1
        return won

    # --- warmup ---------------------------------------------------------
    def warmup(self, shapes, *, dtype=np.float32,
               estimates: bool = False) -> int:
        """Stage every executable the dispatch path can reach for a menu
        of logical operand ``shapes`` — call at deploy time.

        Batch composition is timing-dependent: without warmup, which
        (group, batch-size) signatures compile is decided by how requests
        happen to coalesce, and the first batch of any new signature pays
        its full XLA compile (~1s) *inside* the serving path — a latency
        cliff for whichever requests ride that batch.  Warming every
        power-of-two batch size up to ``max_batch`` per shape removes the
        cliff deterministically.  Returns the number of staged (group,
        batch) signatures.
        """
        shapes = [tuple(s) for s in shapes]
        staged = 0
        for shape in dict.fromkeys(shapes):
            b = embed(np.zeros(shape, dtype), self.quantum)
            group = self._group("factorize", None, b)
            solve_shape = b.bucket if self.mode == "shared" else shape
            if not self.plan.staged:
                fact = self.plan.solve(np.zeros(solve_shape, dtype),
                                       key=self._request_key(0))
                jax.block_until_ready(fact.s)
                with self._lock:
                    self._seen_signatures.add((group, 1))
                staged += 1
            else:
                batch = 1
                while batch <= self.batcher.max_batch:
                    stacked = jax.device_put(
                        np.zeros((batch,) + solve_shape, dtype))
                    keys = _FOLD_KEYS(self._base_key,
                                      jnp.zeros((batch,), jnp.uint32))
                    fact, _ = self.plan.solve_batched(
                        DenseOp(stacked), keys=keys, with_info=True)
                    jax.block_until_ready(fact.s)
                    with self._lock:
                        self._seen_signatures.add((group, batch))
                    staged += 1
                    batch *= 2
            if estimates:
                res = self._est_plan.estimate(np.zeros(shape, dtype),
                                              key=self._request_key(0))
                jax.block_until_ready(res.rank)
                with self._lock:
                    self._seen_signatures.add(
                        (("estimate", shape, str(np.dtype(dtype))), 1))
                staged += 1
        # warmup is deploy time, not serving time: restart the stats clock
        # so requests_per_sec reflects traffic actually served.
        self._t0 = time.perf_counter()
        return staged

    # --- dispatch (runs on the batcher worker thread) -------------------
    def _admit(self, tickets: List[Ticket]) -> List[Ticket]:
        """Deadline admission: fail already-expired tickets NOW, before
        they burn a batch slot or solver time, and return the survivors.
        Dropping at admission (not at submit, not after the solve) is
        what keeps an overloaded server's capacity pointed at requests
        that can still meet their deadline."""
        live, dropped = [], 0
        for t in tickets:
            if t.expired:
                t._fail(DeadlineExceeded(
                    f"deadline passed before dispatch (queued "
                    f"{(time.perf_counter() - t.submitted_at) * 1e3:.1f}"
                    "ms); dropped at admission"))
                dropped += 1
            else:
                live.append(t)
        if dropped:
            with self._lock:
                self._counters["deadline_drops"] += dropped
        return live

    def _breaker(self, group: Hashable) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(group)
            if br is None:
                br = CircuitBreaker(self.breaker_threshold,
                                    self.breaker_reset_s)
                self._breakers[group] = br
            return br

    def _retrying(self, fn):
        """Run ``fn`` with bounded exponential backoff on
        :class:`~repro.runtime.faults.TransientFault` only — permanent
        errors propagate immediately."""
        def _count(_attempt):
            with self._lock:
                self._counters["retries"] += 1
        return retry_with_backoff(
            fn, retries=self.max_retries, backoff_s=self.retry_backoff_s,
            retry_on=(TransientFault,), on_retry=_count)

    def _dispatch(self, group: Hashable, tickets: List[Ticket]) -> None:
        try:
            tickets = self._admit(tickets)
            if not tickets:
                return
            if group[0] == "tenant":
                self._dispatch_tenant(tickets)
            elif group[0] == "estimate":
                self._dispatch_estimate(group, tickets)
            else:
                self._dispatch_solve(group, tickets)
        except BaseException:
            with self._lock:
                self._counters["errors"] += 1
            raise
        finally:
            with self._lock:
                self._counters["batches"] += 1
                n = len(tickets)
                self._batch_hist[n] = self._batch_hist.get(n, 0) + 1
                for t in tickets:
                    if t.done and t.latency_ms is not None \
                            and t._error is None:
                        self._counters["completed"] += 1
                        self.latency.record(t.latency_ms)

    def _note_signature(self, signature: Hashable, n: int) -> None:
        """Bucket-hit accounting: a request 'hits' when its executable
        signature (group x padded batch size) is already staged."""
        with self._lock:
            if signature in self._seen_signatures:
                self._counters["bucket_hits"] += n
            else:
                self._seen_signatures.add(signature)
                self._counters["bucket_misses"] += n

    def _dispatch_solve(self, group: Hashable, tickets: List[Ticket]
                        ) -> None:
        breaker = self._breaker(group)
        if not breaker.allow():
            # open breaker: shed to the degraded path (or fail fast) —
            # don't feed a failing executable more batches until the
            # half-open trial says it recovered.
            with self._lock:
                self._counters["breaker_open_shed"] += len(tickets)
            self._degraded_dispatch(
                group, tickets, reason="breaker_open",
                fallback_error=CircuitOpen(
                    f"circuit breaker open for group {group!r}; "
                    "load shed — retry after the reset window"))
            return
        pressured: List[Ticket] = []
        normal: List[Ticket] = []
        if self.degrade_under_s is not None and self._deg_plan is not None:
            for t in tickets:
                rem = t.remaining_s()
                (pressured if rem is not None
                 and rem < self.degrade_under_s else normal).append(t)
        else:
            normal = list(tickets)
        if pressured:
            # not enough deadline left for the full solve: a certified
            # cheap answer in time beats an accurate one too late.
            self._degraded_dispatch(group, pressured,
                                    reason="deadline_pressure")
        if not normal:
            return
        try:
            self._primary_solve(group, normal)
        except BaseException as exc:   # noqa: BLE001 — degrade, don't die
            breaker.record_failure()
            self._degraded_dispatch(group, normal, reason="primary_failed",
                                    fallback_error=exc)
            return
        breaker.record_success()

    def _primary_solve(self, group: Hashable, tickets: List[Ticket]
                       ) -> None:
        n = len(tickets)
        if not self.plan.staged:
            # host-loop methods cannot vmap-batch: serve them one by one
            # through the same plan (still compile-once per shape).
            self._note_signature((group, 1), n)
            for t in tickets:
                A = t.payload["bucketed"].extract()
                fact, info = self._retrying(
                    lambda A=A, t=t: self.plan.solve(
                        A, key=self._request_key(t.payload["seq"]),
                        with_info=True))
                t._resolve(ServeResult(kind="factorize", value=fact,
                                       batch=1, info=info))
            return
        self._note_signature((group, _pow2_pad(n)), n)
        facts, infos = self._solve_batch(self.plan, tickets)
        for t, fi, ii in zip(tickets, facts, infos):
            t._resolve(ServeResult(kind="factorize", value=fi, batch=n,
                                   info=ii))

    def _solve_batch(self, plan: SolverPlan, tickets: List[Ticket]):
        """Pad, stack, solve once, unstack: per-ticket host-side
        ``(facts, infos)`` lists.  Transient dispatch faults retry with
        backoff inside this call."""
        n = len(tickets)
        shared = self.mode == "shared"
        if shared:
            ops = [t.payload["bucketed"] for t in tickets]
        else:
            ops = [t.payload["bucketed"].extract() for t in tickets]
        seqs = [t.payload["seq"] for t in tickets]
        pad_to_n = _pow2_pad(n)
        ops = ops + [ops[-1]] * (pad_to_n - n)
        seqs = seqs + [seqs[-1]] * (pad_to_n - n)
        # host-side stack + one device_put: no XLA compile per (shape,
        # batch) signature on the dispatch path (jnp.stack would stage a
        # fresh concatenate for each — ~30ms of compile per combination).
        stacked = stack_buckets(ops) if shared \
            else jax.device_put(np.stack([np.asarray(o) for o in ops]))
        keys = _FOLD_KEYS(self._base_key, jnp.asarray(seqs, jnp.uint32))
        fact, info = self._retrying(
            lambda: plan.solve_batched(DenseOp(stacked), keys=keys,
                                       with_info=True))
        # one device->host sync for the whole batch, then per-ticket
        # numpy-view slicing: per-request jax slicing would issue ~10 tiny
        # device ops per ticket and dominate the dispatch loop.
        fact, info = jax.tree.map(np.asarray, (fact, info))
        facts, infos = [], []
        for i, t in enumerate(tickets):
            fi = jax.tree.map(lambda x, i=i: x[i], fact)
            ii = jax.tree.map(lambda x, i=i: x[i], info)
            if shared:
                fi = unpad_factors(fi, t.payload["bucketed"].logical_shape)
            facts.append(fi)
            infos.append(ii)
        return facts, infos

    def _degraded_dispatch(self, group: Hashable, tickets: List[Ticket],
                           *, reason: str,
                           fallback_error: Optional[BaseException] = None
                           ) -> None:
        """Answer with the cheap plan — but ONLY if the answer certifies.

        Every degraded factorization is gated by the HMT residual probe
        against the caller's logical operand; an answer that fails the
        gate becomes :class:`DegradedRejected`, never a silent wrong
        result.  Passing answers carry ``meta["degraded"]=True`` +
        the probe value so clients (and ``stats()``) can see exactly
        which fraction of traffic got the cheap path.
        """
        if self._deg_plan is None:
            err = fallback_error or CircuitOpen(
                f"group {group!r} unavailable and degraded mode disabled")
            for t in tickets:
                t._fail(err)
            return
        try:
            facts, infos = self._solve_batch(self._deg_plan, tickets)
        except BaseException as exc:   # noqa: BLE001 — terminate every ticket
            for t in tickets:
                t._fail(exc)
            return
        for t, fi, ii in zip(tickets, facts, infos):
            A = np.asarray(t.payload["bucketed"].extract())
            probe = residual_probe(A, fi, seed=t.payload["seq"])
            if probe <= self.degraded_tol:
                with self._lock:
                    self._counters["degraded"] += 1
                t._resolve(ServeResult(
                    kind="factorize", value=fi, batch=len(tickets), info=ii,
                    meta={"degraded": True, "reason": reason,
                          "method": self.degraded_method, "probe": probe}))
            else:
                with self._lock:
                    self._counters["degraded_rejected"] += 1
                t._fail(DegradedRejected(
                    f"degraded answer failed the residual probe "
                    f"({probe:.3g} > degraded_tol={self.degraded_tol:g}, "
                    f"reason={reason}); refusing to return an "
                    "uncertified result"))

    def _dispatch_estimate(self, group: Hashable, tickets: List[Ticket]
                           ) -> None:
        self._note_signature((group, 1), len(tickets))
        for t in tickets:
            res = self._est_plan.estimate(
                t.payload["bucketed"].extract(),
                key=self._request_key(t.payload["seq"]))
            t._resolve(ServeResult(kind="estimate", value=res,
                                   batch=len(tickets)))

    @staticmethod
    def _as_lowrank(delta) -> LowRankOp:
        if isinstance(delta, LowRankOp):
            return delta
        U, s, Vt = delta
        return LowRankOp(jnp.asarray(U), jnp.asarray(s), jnp.asarray(Vt))

    def _dispatch_tenant(self, tickets: List[Ticket]) -> None:
        for t in tickets:
            tid = t.payload["tenant"]
            key = self._request_key(t.payload["seq"])
            try:
                if t.payload["kind"] == "delta":
                    sess = self.tenants.touch(tid)
                    if sess is None or sess.fact is None:
                        t._fail(RuntimeError(
                            f"tenant {tid!r}: delta before any factorize "
                            "— there is no tracked state to update"))
                        continue
                    dop = self._as_lowrank(t.payload["delta"])
                    fact = self._retrying(
                        lambda s=sess, d=dop, k=key: s.delta(d, key=k))
                elif t.payload["kind"] == "entries":
                    sess = self.tenants.touch(tid)
                    if sess is None or sess.fact is None:
                        t._fail(RuntimeError(
                            f"tenant {tid!r}: entries before any "
                            "factorize — there is no tracked state to "
                            "fold into"))
                        continue
                    rows, cols, vals = t.payload["entries"]
                    fact = self._retrying(
                        lambda s=sess, r=rows, c=cols, v=vals, k=key:
                        s.entries(r, c, v, key=k))
                else:
                    A = t.payload["bucketed"].extract()
                    sess = self.tenants.get(tid, A)
                    fact = self._retrying(
                        lambda s=sess, A=A, k=key: s.update(A, key=k))
            except Exception as exc:   # noqa: BLE001 — isolate per ticket:
                # one tenant request failing (retries exhausted, rotten
                # state, ...) must not fail the whole coalesced batch.
                t._fail(exc)
                continue
            rec = sess.history[-1]
            meta = {"kind": rec["kind"],
                    "iterations": rec["iterations"],
                    "step": rec["step"]}
            for k in ("probe", "gate", "staleness", "sketch_stale",
                      "sketch_rejected"):
                if k in rec:
                    meta[k] = rec[k]
            t._resolve(ServeResult(
                kind="tenant", value=fact, batch=len(tickets),
                meta=meta))

    # --- stats / lifecycle ----------------------------------------------
    def health(self) -> dict:
        """Reliability counters: breaker states, worker restarts/crashes,
        quarantines, deadline drops, retries and the degraded-answer
        fraction.  A monitoring endpoint would scrape exactly this."""
        with self._lock:
            counters = dict(self._counters)
            breakers = {"|".join(map(str, g)): br.snapshot()
                        for g, br in self._breakers.items()}
        completed = counters["completed"]
        return {
            "worker_restarts": self.batcher.restarts,
            "worker_crashes": self.batcher.crashes,
            "quarantined": counters["quarantined"],
            "deadline_drops": counters["deadline_drops"],
            "retries": counters["retries"],
            "degraded": counters["degraded"],
            "degraded_rejected": counters["degraded_rejected"],
            "breaker_open_shed": counters["breaker_open_shed"],
            "degraded_fraction":
                counters["degraded"] / completed if completed else 0.0,
            "breakers": breakers,
        }

    def stats(self) -> dict:
        """JSON-able snapshot of the serving counters (the CLI's stats
        endpoint payload).  Health counters are merged at top level AND
        nested under ``"health"``."""
        now = time.perf_counter()
        health = self.health()
        with self._lock:
            counters = dict(self._counters)
            hist = {str(k): v for k, v in sorted(self._batch_hist.items())}
        elapsed = max(now - self._t0, 1e-9)
        lookups = counters["bucket_hits"] + counters["bucket_misses"]
        return {
            "uptime_s": elapsed,
            **counters,
            **{k: v for k, v in health.items() if k != "breakers"},
            "requests_per_sec": counters["completed"] / elapsed,
            "latency_ms": self.latency.summary(),
            "batch_histogram": hist,
            "bucket_hit_rate":
                counters["bucket_hits"] / lookups if lookups else 0.0,
            "mode": self.mode,
            "quantum": self.quantum,
            "tenants": self.tenants.stats(),
            "plan_cache": plan_cache_stats(),
            "health": health,
        }

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain the queue, stop the worker, checkpoint tenant sessions."""
        if self._closed:
            return
        self._closed = True
        self.batcher.stop(timeout)
        self.tenants.save_all()

    def __enter__(self) -> "SolveServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["ServeResult", "SolveServer"]
