"""The solve server: intake -> bucket -> continuous batch -> plan cache.

One :class:`SolveServer` owns a solve configuration (an ``SVDSpec``) and
serves three request kinds through a single dispatch worker:

* anonymous ``factorize`` — bucketed, coalesced by the continuous batcher
  and dispatched through ``SolverPlan.solve_batched`` (one ``jit(vmap)``
  executable per (group, padded-batch-size) signature, shared process-wide
  via the plan LRU).  Batch sizes are padded up to powers of two by
  repeating the last request, so the executable count per group is
  ``O(log max_batch)``, not ``O(max_batch)``.
* ``estimate`` — Algorithm-3 rank estimates, staged per logical shape with
  the in-graph loop (``host_loop=False``) so repeat shapes reuse one
  executable.
* tenant ``factorize`` — routed to the tenant's
  :class:`~repro.api.session.Session`; repeat requests run the tracked
  refine path (strictly fewer GK iterations than cold).
* tenant ``delta`` — a *structured drift* against the tenant's tracked
  state: the payload is the low-rank drift itself (``LowRankOp`` or raw
  ``(U, s, Vt)`` factors), not a full operand.  Routed through
  ``Session.delta``, which takes the zero-iteration rank-k update path
  when the measured residual passes the parity gate (see
  ``repro.core.update``) and falls back to refine/restart otherwise.

Accuracy contract: in ``mode="exact"`` (default) every solver input is
the caller's logical operand, bit-for-bit — padding is transport-only.
``mode="shared"`` solves at bucket shape for maximal executable sharing,
with the documented roundoff-level σ perturbation (see ``serve.bucket``).
Rank estimates always run exact.

The stats endpoint (:meth:`SolveServer.stats`) reports requests/sec,
p50/p99 latency (``runtime.telemetry.LatencyStats``), the bucket hit rate
(fraction of requests landing on an already-staged (group, batch)
signature — ground-truthed against ``plan_cache_stats`` in the tests),
batch-size histogram, tenant-session counters and the plan-cache counters.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Hashable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import SolverPlan, plan as _make_plan, plan_cache_stats
from repro.api.spec import SVDSpec
from repro.core.operators import DenseOp, LowRankOp
from repro.runtime.telemetry import LatencyStats
from repro.serve.batcher import ContinuousBatcher, QueueFull, Ticket
from repro.serve.bucket import (DEFAULT_QUANTUM, Bucketed, embed,
                                stack_buckets, unpad_factors)
from repro.serve.tenant import TenantRegistry

Array = jax.Array

_KINDS = ("factorize", "estimate", "delta")
_MODES = ("exact", "shared")


@dataclasses.dataclass
class ServeResult:
    """What a resolved ticket carries.

    ``value`` is a ``Factorization`` (factorize/tenant) or a
    ``RankEstimate`` (estimate); ``info`` the per-request
    ``ConvergenceInfo`` when the path captures one; ``batch`` the size of
    the coalesced batch this request rode in; ``meta`` path-specific
    extras (tenant solves report the Session's kind + iteration count).
    """

    kind: str
    value: Any
    batch: int = 1
    info: Any = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _pow2_pad(n: int) -> int:
    return 1 << (n - 1).bit_length()


# Process-wide (NOT per-server): each server instance jitting its own
# closure would recompile this per (server, batch size) — ~100ms a pop on
# every fresh server's first batches.  Shared, it stages once per
# (key aval, batch size) for the life of the process.
_FOLD_KEYS = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))


class SolveServer:
    """Multi-tenant factorization service over one ``SVDSpec``.

    Parameters
    ----------
    spec            solve configuration (``**overrides`` merge like
                    ``plan(...)``).
    quantum         bucket granularity (dims round up to multiples).
    mode            "exact" (bit-identical inputs, default) | "shared"
                    (solve at bucket shape, maximal executable sharing).
    max_batch       continuous-batching flush size.
    window_ms       continuous-batching deadline window.
    max_queue       backpressure bound; beyond it ``submit`` raises
                    :class:`~repro.serve.batcher.QueueFull`.
    max_tenants     resident tenant-session LRU capacity.
    checkpoint_dir  evicted tenant sessions checkpoint here (optional).
    key             base PRNG key; per-request keys are folded in.
    """

    def __init__(self, spec: Optional[SVDSpec] = None, *,
                 quantum: int = DEFAULT_QUANTUM,
                 mode: str = "exact",
                 max_batch: int = 8,
                 window_ms: float = 4.0,
                 max_queue: int = 256,
                 max_tenants: int = 32,
                 checkpoint_dir: Optional[str] = None,
                 key: Optional[Array] = None,
                 **overrides):
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        spec = spec or SVDSpec()
        if overrides:
            spec = spec.replace(**overrides)
        self.spec = spec
        self.quantum = int(quantum)
        self.mode = mode
        self.plan: SolverPlan = _make_plan(spec)
        # estimates stage per shape with the in-graph loop: a server must
        # not stall its dispatch thread on per-iteration host round-trips.
        self._est_plan: SolverPlan = _make_plan(spec.replace(host_loop=False))
        self.tenants = TenantRegistry(
            spec, max_tenants=max_tenants, checkpoint_dir=checkpoint_dir,
            key=key)
        self._base_key = key if key is not None else jax.random.key(0)
        self._seq = 0
        self._lock = threading.Lock()
        self._counters = {"submitted": 0, "completed": 0, "rejected": 0,
                          "cancelled": 0, "timeouts": 0, "errors": 0,
                          "batches": 0, "tenant_requests": 0,
                          "bucket_hits": 0, "bucket_misses": 0}
        self._batch_hist: Dict[int, int] = {}
        self._seen_signatures: set = set()
        self.latency = LatencyStats()
        self._t0 = time.perf_counter()
        self._closed = False
        self.batcher = ContinuousBatcher(
            self._dispatch, max_batch=max_batch, window_ms=window_ms,
            max_queue=max_queue)

    # --- intake ---------------------------------------------------------
    def _next_seq(self) -> int:
        """Per-request key *sequence number* — the key itself materializes
        at dispatch (one vmapped fold_in per batch), keeping the submit
        path free of jax ops."""
        with self._lock:
            seq = self._seq
            self._seq += 1
        return seq

    def _request_key(self, seq: int) -> Array:
        return jax.random.fold_in(self._base_key, seq)

    def _group(self, kind: str, tenant: Optional[str],
               b: Bucketed) -> Hashable:
        if tenant is not None:
            return ("tenant", str(tenant))
        dtype = str(b.data.dtype)
        if kind == "estimate":
            return ("estimate", b.logical_shape, dtype)
        if self.mode == "shared":
            return ("solve", b.bucket, dtype)
        return ("solve", b.logical_shape, dtype)

    def submit(self, A, *, kind: str = "factorize",
               tenant: Optional[str] = None) -> Ticket:
        """Enqueue one request; returns its :class:`Ticket` immediately.

        Raises :class:`QueueFull` under backpressure — the request was
        NOT accepted; retry with backoff.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
        if kind == "estimate" and tenant is not None:
            raise ValueError("estimate requests are stateless; "
                             "tenant routing applies to factorize only")
        if kind == "delta":
            # structured drift against a tenant's tracked state: ``A`` is
            # the drift itself — a LowRankOp or raw (U, s, Vt) factors —
            # not an operand, so it bypasses bucketing entirely.  The
            # group stays ("tenant", id): deltas serialize FIFO with the
            # tenant's factorize requests on the dispatch worker.
            if tenant is None:
                raise ValueError("delta requests require tenant= routing; "
                                 "there is no anonymous tracked state to "
                                 "update")
            payload = {"delta": A, "kind": kind, "tenant": tenant,
                       "seq": self._next_seq()}
            group: Hashable = ("tenant", str(tenant))
        else:
            b = embed(A, self.quantum)
            payload = {"bucketed": b, "kind": kind, "tenant": tenant,
                       "seq": self._next_seq()}
            group = self._group(kind, tenant, b)
        try:
            ticket = self.batcher.submit(group, payload)
        except QueueFull:
            with self._lock:
                self._counters["rejected"] += 1
            raise
        with self._lock:
            self._counters["submitted"] += 1
            if tenant is not None:
                self._counters["tenant_requests"] += 1
        return ticket

    def solve(self, A, *, kind: str = "factorize",
              tenant: Optional[str] = None,
              timeout: Optional[float] = 30.0) -> ServeResult:
        """Synchronous submit + wait.  On timeout the request is cancelled
        (it will never reach the solver) and ``TimeoutError`` re-raises."""
        ticket = self.submit(A, kind=kind, tenant=tenant)
        try:
            return ticket.result(timeout)
        except TimeoutError:
            self.cancel(ticket)
            with self._lock:
                self._counters["timeouts"] += 1
            raise

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a submitted ticket (counted in the stats)."""
        won = ticket.cancel()
        if won:
            with self._lock:
                self._counters["cancelled"] += 1
        return won

    # --- warmup ---------------------------------------------------------
    def warmup(self, shapes, *, dtype=np.float32,
               estimates: bool = False) -> int:
        """Stage every executable the dispatch path can reach for a menu
        of logical operand ``shapes`` — call at deploy time.

        Batch composition is timing-dependent: without warmup, which
        (group, batch-size) signatures compile is decided by how requests
        happen to coalesce, and the first batch of any new signature pays
        its full XLA compile (~1s) *inside* the serving path — a latency
        cliff for whichever requests ride that batch.  Warming every
        power-of-two batch size up to ``max_batch`` per shape removes the
        cliff deterministically.  Returns the number of staged (group,
        batch) signatures.
        """
        shapes = [tuple(s) for s in shapes]
        staged = 0
        for shape in dict.fromkeys(shapes):
            b = embed(np.zeros(shape, dtype), self.quantum)
            group = self._group("factorize", None, b)
            solve_shape = b.bucket if self.mode == "shared" else shape
            if not self.plan.staged:
                fact = self.plan.solve(np.zeros(solve_shape, dtype),
                                       key=self._request_key(0))
                jax.block_until_ready(fact.s)
                with self._lock:
                    self._seen_signatures.add((group, 1))
                staged += 1
            else:
                batch = 1
                while batch <= self.batcher.max_batch:
                    stacked = jax.device_put(
                        np.zeros((batch,) + solve_shape, dtype))
                    keys = _FOLD_KEYS(self._base_key,
                                      jnp.zeros((batch,), jnp.uint32))
                    fact, _ = self.plan.solve_batched(
                        DenseOp(stacked), keys=keys, with_info=True)
                    jax.block_until_ready(fact.s)
                    with self._lock:
                        self._seen_signatures.add((group, batch))
                    staged += 1
                    batch *= 2
            if estimates:
                res = self._est_plan.estimate(np.zeros(shape, dtype),
                                              key=self._request_key(0))
                jax.block_until_ready(res.rank)
                with self._lock:
                    self._seen_signatures.add(
                        (("estimate", shape, str(np.dtype(dtype))), 1))
                staged += 1
        # warmup is deploy time, not serving time: restart the stats clock
        # so requests_per_sec reflects traffic actually served.
        self._t0 = time.perf_counter()
        return staged

    # --- dispatch (runs on the batcher worker thread) -------------------
    def _dispatch(self, group: Hashable, tickets: List[Ticket]) -> None:
        try:
            if group[0] == "tenant":
                self._dispatch_tenant(tickets)
            elif group[0] == "estimate":
                self._dispatch_estimate(group, tickets)
            else:
                self._dispatch_solve(group, tickets)
        except BaseException:
            with self._lock:
                self._counters["errors"] += 1
            raise
        finally:
            with self._lock:
                self._counters["batches"] += 1
                n = len(tickets)
                self._batch_hist[n] = self._batch_hist.get(n, 0) + 1
                for t in tickets:
                    if t.done and t.latency_ms is not None \
                            and t._error is None:
                        self._counters["completed"] += 1
                        self.latency.record(t.latency_ms)

    def _note_signature(self, signature: Hashable, n: int) -> None:
        """Bucket-hit accounting: a request 'hits' when its executable
        signature (group x padded batch size) is already staged."""
        with self._lock:
            if signature in self._seen_signatures:
                self._counters["bucket_hits"] += n
            else:
                self._seen_signatures.add(signature)
                self._counters["bucket_misses"] += n

    def _dispatch_solve(self, group: Hashable, tickets: List[Ticket]
                        ) -> None:
        n = len(tickets)
        shared = self.mode == "shared"
        if shared:
            ops = [t.payload["bucketed"] for t in tickets]
        else:
            ops = [t.payload["bucketed"].extract() for t in tickets]
        seqs = [t.payload["seq"] for t in tickets]
        if not self.plan.staged:
            # host-loop methods cannot vmap-batch: serve them one by one
            # through the same plan (still compile-once per shape).
            self._note_signature((group, 1), n)
            for t, A, s in zip(tickets,
                               (o.extract() if shared else o for o in ops),
                               seqs):
                fact, info = self.plan.solve(A, key=self._request_key(s),
                                             with_info=True)
                t._resolve(ServeResult(kind="factorize", value=fact,
                                       batch=1, info=info))
            return
        pad_to_n = _pow2_pad(n)
        ops = ops + [ops[-1]] * (pad_to_n - n)
        seqs = seqs + [seqs[-1]] * (pad_to_n - n)
        self._note_signature((group, pad_to_n), n)
        # host-side stack + one device_put: no XLA compile per (shape,
        # batch) signature on the dispatch path (jnp.stack would stage a
        # fresh concatenate for each — ~30ms of compile per combination).
        stacked = stack_buckets(ops) if shared \
            else jax.device_put(np.stack([np.asarray(o) for o in ops]))
        keys = _FOLD_KEYS(self._base_key, jnp.asarray(seqs, jnp.uint32))
        fact, info = self.plan.solve_batched(
            DenseOp(stacked), keys=keys, with_info=True)
        # one device->host sync for the whole batch, then per-ticket
        # numpy-view slicing: per-request jax slicing would issue ~10 tiny
        # device ops per ticket and dominate the dispatch loop.
        fact, info = jax.tree.map(np.asarray, (fact, info))
        for i, t in enumerate(tickets):
            fi = jax.tree.map(lambda x, i=i: x[i], fact)
            ii = jax.tree.map(lambda x, i=i: x[i], info)
            if shared:
                fi = unpad_factors(fi, t.payload["bucketed"].logical_shape)
            t._resolve(ServeResult(kind="factorize", value=fi, batch=n,
                                   info=ii))

    def _dispatch_estimate(self, group: Hashable, tickets: List[Ticket]
                           ) -> None:
        self._note_signature((group, 1), len(tickets))
        for t in tickets:
            res = self._est_plan.estimate(
                t.payload["bucketed"].extract(),
                key=self._request_key(t.payload["seq"]))
            t._resolve(ServeResult(kind="estimate", value=res,
                                   batch=len(tickets)))

    @staticmethod
    def _as_lowrank(delta) -> LowRankOp:
        if isinstance(delta, LowRankOp):
            return delta
        U, s, Vt = delta
        return LowRankOp(jnp.asarray(U), jnp.asarray(s), jnp.asarray(Vt))

    def _dispatch_tenant(self, tickets: List[Ticket]) -> None:
        for t in tickets:
            tid = t.payload["tenant"]
            key = self._request_key(t.payload["seq"])
            if t.payload["kind"] == "delta":
                sess = self.tenants.touch(tid)
                if sess is None or sess.fact is None:
                    t._fail(RuntimeError(
                        f"tenant {tid!r}: delta before any factorize — "
                        "there is no tracked state to update"))
                    continue
                dop = self._as_lowrank(t.payload["delta"])
                fact = sess.delta(dop, key=key)
            else:
                A = t.payload["bucketed"].extract()
                sess = self.tenants.get(tid, A)
                fact = sess.update(A, key=key)
            rec = sess.history[-1]
            t._resolve(ServeResult(
                kind="tenant", value=fact, batch=len(tickets),
                meta={"kind": rec["kind"],
                      "iterations": rec["iterations"],
                      "step": rec["step"]}))

    # --- stats / lifecycle ----------------------------------------------
    def stats(self) -> dict:
        """JSON-able snapshot of the serving counters (the CLI's stats
        endpoint payload)."""
        now = time.perf_counter()
        with self._lock:
            counters = dict(self._counters)
            hist = {str(k): v for k, v in sorted(self._batch_hist.items())}
        elapsed = max(now - self._t0, 1e-9)
        lookups = counters["bucket_hits"] + counters["bucket_misses"]
        return {
            "uptime_s": elapsed,
            **counters,
            "requests_per_sec": counters["completed"] / elapsed,
            "latency_ms": self.latency.summary(),
            "batch_histogram": hist,
            "bucket_hit_rate":
                counters["bucket_hits"] / lookups if lookups else 0.0,
            "mode": self.mode,
            "quantum": self.quantum,
            "tenants": self.tenants.stats(),
            "plan_cache": plan_cache_stats(),
        }

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain the queue, stop the worker, checkpoint tenant sessions."""
        if self._closed:
            return
        self._closed = True
        self.batcher.stop(timeout)
        self.tenants.save_all()

    def __enter__(self) -> "SolveServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["ServeResult", "SolveServer"]
