"""Serving resilience primitives: typed failure taxonomy, per-group
circuit breaker, bounded retry backoff, and the HMT residual probe that
gates degraded-mode answers.

The failure taxonomy is the serve layer's contract that *every* ticket
terminates with something a client can switch on:

  :class:`DeadlineExceeded`   the request aged past its deadline before a
                              worker could touch it (dropped at dispatch
                              admission — an expired ticket must not burn
                              a batch slot).
  :class:`WorkerCrashed`      the dispatch worker died or hung while this
                              request was in flight; the supervisor
                              restarted the worker and failed only the
                              in-flight batch.  Retryable by the client.
  :class:`CircuitOpen`        the request's group breaker is shedding
                              load and no degraded answer was possible.
  :class:`PoisonedOperand`    the operand carries NaN/Inf and was
                              quarantined at submit — it never entered a
                              batch (one NaN row poisons every example of
                              a vmapped stacked solve).
  :class:`DegradedRejected`   a degraded (cheap-solve) answer was
                              computed but failed the randomized residual
                              probe — the server refuses to return an
                              answer it cannot certify.

The residual probe is Halko–Martinsson–Tropp posterior error estimation
(PAPERS.md): for factors ``U diag(s) Vᵀ ≈ A`` and a few Gaussian probe
vectors ``ω``, ``‖Aω − U diag(s) Vᵀ ω‖ / ‖Aω‖`` estimates the relative
spectral defect of the approximation at the cost of ``probes`` extra
matvecs — cheap enough to run on every degraded answer, host-side, with
no device round-trip beyond the factors the answer already carries.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import numpy as np


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before dispatch admission."""


class WorkerCrashed(RuntimeError):
    """The dispatch worker died/hung with this request in flight; the
    supervisor restarted the worker.  Safe to retry."""


class CircuitOpen(RuntimeError):
    """The group's circuit breaker is open (shedding load) and degraded
    mode could not answer."""


class PoisonedOperand(ValueError):
    """The operand contains NaN/Inf; quarantined at submit."""


class DegradedRejected(RuntimeError):
    """The degraded-mode answer failed the residual-probe accuracy gate."""


class CircuitBreaker:
    """Per-group consecutive-failure circuit breaker.

    closed     normal operation; ``threshold`` consecutive failures open
               it.
    open       shed load (callers take the degraded path or fail fast)
               until ``reset_s`` elapses.
    half-open  after the reset timer one trial batch is admitted; success
               closes the breaker, failure re-opens it (and restarts the
               timer).

    All transitions are timestamp-driven inside :meth:`allow` — no
    background thread.  Thread-safe; the dispatch worker is the only
    writer in practice but stats readers race it.
    """

    def __init__(self, threshold: int = 5, reset_s: float = 5.0):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.reset_s = float(reset_s)
        self._lock = threading.Lock()
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self._opens = 0

    def allow(self) -> bool:
        """May a (non-degraded) dispatch proceed right now?  Flips open →
        half-open when the reset timer has elapsed."""
        with self._lock:
            if self._state == "open":
                if time.perf_counter() - self._opened_at >= self.reset_s:
                    self._state = "half-open"
                    return True
                return False
            return True

    def record_success(self) -> None:
        with self._lock:
            self._state = "closed"
            self._failures = 0

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            if self._state == "half-open" or self._failures >= self.threshold:
                self._state = "open"
                self._opened_at = time.perf_counter()
                self._opens += 1

    @property
    def state(self) -> str:
        with self._lock:
            if self._state == "open" and \
                    time.perf_counter() - self._opened_at >= self.reset_s:
                return "half-open"
            return self._state

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self._state, "failures": self._failures,
                    "opens": self._opens}


def retry_with_backoff(fn, *, retries: int, backoff_s: float,
                       retry_on=(Exception,), on_retry=None):
    """Run ``fn()`` with up to ``retries`` retries on ``retry_on``
    exceptions, sleeping ``backoff_s * 2**attempt`` between attempts
    (bounded exponential backoff).  The final failure re-raises."""
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on:
            if attempt >= retries:
                raise
            if on_retry is not None:
                on_retry(attempt)
            time.sleep(backoff_s * (2 ** attempt))
            attempt += 1


def residual_probe(A, fact, *, probes: int = 4,
                   seed: int = 0) -> float:
    """HMT-style randomized posterior residual of ``fact`` against ``A``:
    ``‖AΩ − U diag(s) (VᵀΩ)‖_F / ‖AΩ‖_F`` over ``probes`` Gaussian
    columns Ω.  ~0 for a faithful factorization, O(1) for garbage; the
    degraded-mode gate compares it against ``degraded_tol``.

    Host-side numpy on purpose: the probe certifies the *answer being
    returned*, so it must not share fate (or executables) with the solver
    path it is checking.
    """
    A = np.asarray(A)
    U = np.asarray(fact.U)
    s = np.asarray(fact.s)
    V = np.asarray(fact.V)
    rng = np.random.default_rng(seed)
    omega = rng.standard_normal((A.shape[1], int(probes)))
    omega = omega.astype(np.result_type(A.dtype, np.float32), copy=False)
    ao = A @ omega
    approx = U @ (s[:, None] * (V.T @ omega))
    denom = float(np.linalg.norm(ao))
    if denom <= 0.0:
        # zero operand: any zero-ish factorization is exact
        return float(np.linalg.norm(approx))
    return float(np.linalg.norm(ao - approx) / denom)


def finite_or_raise(tree, *, what: str = "operand") -> None:
    """Quarantine gate: raise :class:`PoisonedOperand` when any float
    leaf of ``tree`` carries NaN/Inf.  One poisoned example in a stacked
    vmapped batch contaminates *every* co-batched result (NaN propagates
    through the shared reductions), so this must run per-request at
    submit time, before batching."""
    import jax
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and \
                not np.isfinite(arr).all():
            raise PoisonedOperand(
                f"{what} contains NaN/Inf and was quarantined; a "
                "non-finite operand would poison every request in its "
                "batch")


__all__ = [
    "CircuitBreaker", "CircuitOpen", "DeadlineExceeded", "DegradedRejected",
    "PoisonedOperand", "WorkerCrashed", "finite_or_raise", "residual_probe",
    "retry_with_backoff",
]
