"""Continuous batching: coalesce same-group requests within a deadline
window, dispatch them as one batch — under a supervisor that keeps the
dispatch worker alive.

Plain threads + ``queue.Queue`` — no asyncio runtime dependency, so the
batcher embeds in any host (a test, the CLI, a larger service) without an
event loop.  One worker thread owns all dispatch; per-group pending lists
flush when they reach ``max_batch`` or when their oldest request has aged
past the ``window_ms`` deadline, whichever comes first.  The intake queue
is *bounded*: past ``max_queue`` undispatched requests, ``submit`` raises
:class:`QueueFull` — the server rejects rather than OOMs under overload
(the caller retries with backoff; silently buffering unbounded operands is
how a solve server dies).

Per-request lifecycle is a :class:`Ticket`: the client blocks on
``result(timeout=...)``, may ``cancel()`` at any point (a cancelled ticket
is dropped at flush time, before any solver work), may carry a deadline
(enforced by the dispatch function at admission time, so an expired
request never burns a batch slot), and reads its measured ``latency_ms``
afterwards.  ``result(timeout, cancel_on_timeout=True)`` cancels on the
way out, so an abandoned request releases its ``max_queue`` slot instead
of pinning backpressure capacity until dispatch.

Supervision: the worker is restartable.  Its loop state (pending groups,
in-flight batch, a heartbeat timestamp set when a dispatch starts) lives
on the batcher instance, and every worker carries a *generation* number.
A watchdog thread restarts the worker when it dies (crash anywhere in the
dispatch path) or when a dispatch overruns ``hang_timeout_s``; only the
in-flight batch is failed (:class:`~repro.serve.resilience.WorkerCrashed`
— retryable), queued tickets survive to be served by the next generation.
A superseded worker that wakes from a hang discovers its generation is
stale and exits without touching successor state.  The ``serve.dispatch``
failpoint (``repro.runtime.faults``) fires *outside* the dispatch
try/except precisely so raise-mode faults kill the worker (exercising
supervisor restart) and delay-mode faults hang it (exercising the
watchdog) instead of being absorbed as batch errors.

Shutdown is race-free: ``stop()`` drains everything already queued, then
any ``submit`` that raced the drain finds ``_stopping`` set after its
enqueue and claims its own straggler back — every ticket terminates, none
can land in the intake queue after the drain and hang its client forever.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Callable, Dict, Hashable, List, Optional

from repro.runtime import faults
from repro.serve.resilience import WorkerCrashed

_SENTINEL = object()


class QueueFull(RuntimeError):
    """Backpressure: the intake queue is at capacity; retry later."""


class Cancelled(RuntimeError):
    """The request was cancelled (by the client or at shutdown)."""


class Ticket:
    """One in-flight request: a result slot + completion event.

    Created by :meth:`ContinuousBatcher.submit`; resolved (or failed) by
    the dispatch function on the worker thread.

    State transitions (resolve / fail / cancel) are serialized by a
    per-ticket lock: exactly ONE transition wins, so ``cancel()`` returns
    True only when the cancel actually preempted a result — it can no
    longer race the worker's ``_resolve`` and claim a delivered result was
    cancelled.  The backpressure slot a ticket occupies in its batcher is
    released exactly once (at cancel time, flush time, or shutdown —
    whichever comes first).
    """

    __slots__ = ("group", "payload", "submitted_at", "dispatched_at",
                 "deadline_at", "latency_ms", "_done", "_result", "_error",
                 "_cancelled", "_lock", "_released", "_batcher")

    def __init__(self, group: Hashable, payload: Any,
                 batcher: Optional["ContinuousBatcher"] = None,
                 deadline_s: Optional[float] = None):
        self.group = group
        self.payload = payload
        self.submitted_at = time.perf_counter()
        self.deadline_at: Optional[float] = (
            None if deadline_s is None else self.submitted_at
            + float(deadline_s))
        self.dispatched_at: Optional[float] = None
        self.latency_ms: Optional[float] = None
        self._done = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self._lock = threading.Lock()
        self._released = False
        self._batcher = batcher

    # --- client side ---------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    @property
    def expired(self) -> bool:
        """Past its deadline (always False for deadline-less tickets)."""
        return (self.deadline_at is not None
                and time.perf_counter() > self.deadline_at)

    def remaining_s(self) -> Optional[float]:
        """Seconds until the deadline (negative if past); None if no
        deadline."""
        if self.deadline_at is None:
            return None
        return self.deadline_at - time.perf_counter()

    def cancel(self) -> bool:
        """Cancel if not already completed; True when the cancel won.

        A cancelled ticket never reaches the solver (the worker drops it
        at flush time) and immediately stops occupying the batcher's
        backpressure budget; any thread blocked in :meth:`result` gets
        :class:`Cancelled`.
        """
        with self._lock:
            if self._done.is_set():
                return False
            self._cancelled = True
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._error = Cancelled("request cancelled")
            self._done.set()
        self._release_slot()
        return True

    def result(self, timeout: Optional[float] = None, *,
               cancel_on_timeout: bool = False) -> Any:
        """Block until resolved; raises the dispatch error, ``Cancelled``,
        or ``TimeoutError`` after ``timeout`` seconds.

        With ``cancel_on_timeout=True`` an expiring wait also cancels the
        ticket, releasing its ``max_queue`` slot — the contract for
        callers that abandon the request on timeout (otherwise the
        abandoned ticket pins backpressure capacity until the worker gets
        around to flushing its group).  If the cancel loses the race to a
        concurrent resolve, the result is returned normally.
        """
        if not self._done.wait(timeout):
            if not cancel_on_timeout or self.cancel():
                raise TimeoutError(
                    f"request not served within {timeout}s (group="
                    f"{self.group!r})"
                    + ("; cancelled, slot released"
                       if cancel_on_timeout else "; cancel() to drop it"))
            # cancel lost the race: a result (or error) landed while we
            # were timing out — deliver it.
        if self._error is not None:
            raise self._error
        return self._result

    # --- worker side ---------------------------------------------------
    def _resolve(self, result: Any) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._result = result
            self._done.set()

    def _fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._error = exc
            self._done.set()

    def _release_slot(self) -> None:
        """Give the batcher's backpressure slot back, exactly once.

        Callable from the client (cancel), the worker (flush) and the
        shutdown drain; the per-ticket lock arbitrates, so concurrent
        callers can never double-decrement ``_pending_n``.  Lock order is
        always ticket → batcher (never the reverse), so no deadlock.
        """
        with self._lock:
            if self._released:
                return
            self._released = True
        b = self._batcher
        if b is not None:
            with b._lock:
                b._pending_n -= 1


DispatchFn = Callable[[Hashable, List[Ticket]], None]


class ContinuousBatcher:
    """Deadline-window request coalescer with a supervised dispatch
    worker thread.

    ``dispatch(group, tickets)`` receives only live (non-cancelled)
    tickets and must resolve every one (``Ticket._resolve``/``_fail``);
    an exception escaping dispatch fails the whole batch, and any ticket
    a dispatch forgets is failed defensively — a client can never hang on
    a flushed batch.  A crash *outside* that try (the ``serve.dispatch``
    failpoint, or a bug in the flush machinery itself) kills the worker;
    the watchdog restarts it, failing only the in-flight batch.

    Parameters
    ----------
    dispatch       the batch executor (runs on the worker thread).
    max_batch      flush a group at this many pending requests.
    window_ms      flush a group when its oldest request is this old.
    max_queue      bound on undispatched requests across all groups;
                   beyond it ``submit`` raises :class:`QueueFull`.
    hang_timeout_s declare a single dispatch hung after this long and
                   restart the worker (None disables hang detection;
                   crash detection still runs).
    supervise      run the watchdog thread (disable only in tests that
                   need a deliberately dead batcher).
    watchdog_interval_s  how often the watchdog polls liveness.
    """

    def __init__(self, dispatch: DispatchFn, *, max_batch: int = 8,
                 window_ms: float = 4.0, max_queue: int = 256,
                 name: str = "solve-batcher",
                 hang_timeout_s: Optional[float] = 30.0,
                 supervise: bool = True,
                 watchdog_interval_s: float = 0.05):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._dispatch = dispatch
        self.max_batch = int(max_batch)
        self.window = float(window_ms) / 1e3
        self.max_queue = int(max_queue)
        self.hang_timeout = (None if hang_timeout_s is None
                             else float(hang_timeout_s))
        self._name = name
        self._intake: "queue.Queue" = queue.Queue()
        self._pending_n = 0
        self._lock = threading.Lock()
        self._stopping = threading.Event()
        self._stopped = threading.Event()
        # worker-generation state (all guarded by _lock) -----------------
        self._gen = 0
        self._restarts = 0
        self._crashes = 0
        self._inflight: Optional[List[Ticket]] = None
        self._dispatch_started: Optional[float] = None
        # loop state lives on the instance so a restarted worker resumes
        # exactly where its predecessor died — queued groups survive.
        self._pending_map: "collections.OrderedDict[Hashable, List[Ticket]]" \
            = collections.OrderedDict()
        self._oldest: Dict[Hashable, float] = {}
        self._thread = threading.Thread(target=self._run, args=(0,),
                                        name=name, daemon=True)
        self._thread.start()
        self._watchdog: Optional[threading.Thread] = None
        if supervise:
            self._watch_interval = float(watchdog_interval_s)
            self._watchdog = threading.Thread(
                target=self._watch, name=f"{name}-watchdog", daemon=True)
            self._watchdog.start()

    # --- client side ---------------------------------------------------
    def submit(self, group: Hashable, payload: Any, *,
               deadline_s: Optional[float] = None) -> Ticket:
        with self._lock:
            if self._stopping.is_set():
                raise RuntimeError("batcher is stopped")
            if self._pending_n >= self.max_queue:
                raise QueueFull(
                    f"{self._pending_n} requests already queued "
                    f"(max_queue={self.max_queue}); retry with backoff")
            self._pending_n += 1
        ticket = Ticket(group, payload, batcher=self, deadline_s=deadline_s)
        self._intake.put(ticket)
        if self._stopping.is_set():
            # stop() raced our enqueue and the worker's final drain may
            # already have passed without seeing this ticket.  Wait for
            # the drain to finish, then claim any stragglers ourselves:
            # the ticket terminates either way — served if the worker got
            # to it, failed with RuntimeError here if not — and can never
            # sit in the intake queue forever.
            self._stopped.wait(30.0)
            self._fail_stragglers()
        return ticket

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending_n

    @property
    def restarts(self) -> int:
        """Worker restarts performed by the watchdog (crash or hang)."""
        with self._lock:
            return self._restarts

    @property
    def crashes(self) -> int:
        """Worker deaths observed (crashes noted by the dying worker)."""
        with self._lock:
            return self._crashes

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Drain: flush everything already queued, then stop the worker."""
        self._stopping.set()
        self._intake.put(_SENTINEL)
        self._stopped.wait(timeout)

    # --- worker side ---------------------------------------------------
    def _current(self, gen: int) -> bool:
        with self._lock:
            return self._gen == gen

    def _run(self, gen: int) -> None:
        try:
            self._loop(gen)
        except BaseException as exc:   # noqa: BLE001 — the supervisor owns recovery
            self._note_crash(gen, exc)
            return
        if self._current(gen):
            self._drain_and_stop()

    def _loop(self, gen: int) -> None:
        while True:
            if not self._current(gen):
                return
            timeout: Optional[float] = None
            if self._pending_map:
                now = time.perf_counter()
                nearest = min(self._oldest.values())
                timeout = max(0.0, nearest + self.window - now)
            if self._stopping.is_set():
                # stay responsive during the drain even if our wake-up
                # sentinel was consumed by a dead predecessor
                timeout = 0.05 if timeout is None else min(timeout, 0.05)
            try:
                item = self._intake.get(timeout=timeout)
            except queue.Empty:
                item = None
            if item is not None and not self._current(gen):
                # superseded mid-get: hand the item to our successor
                self._intake.put(item)
                return
            if item is not None and item is not _SENTINEL:
                grp = self._pending_map.setdefault(item.group, [])
                grp.append(item)
                # window measured from when the group started pending,
                # NOT from submit time: requests that queued up behind
                # a long dispatch still get a chance to coalesce.
                self._oldest.setdefault(item.group, time.perf_counter())
                if len(grp) >= self.max_batch:
                    self._flush(item.group, gen)
            # deadline-expired groups (and everything, at shutdown)
            now = time.perf_counter()
            for g in [g for g, t0 in list(self._oldest.items())
                      if self._stopping.is_set()
                      or now - t0 >= self.window]:
                self._flush(g, gen)
            if not self._current(gen):
                return
            if (self._stopping.is_set() and not self._pending_map
                    and self._intake.empty()):
                return

    def _flush(self, group: Hashable, gen: int) -> None:
        batch = self._pending_map.pop(group, [])
        self._oldest.pop(group, None)
        if not batch:
            return
        # cancelled tickets released their slot at cancel time; the rest
        # release here — _release_slot is exactly-once per ticket.
        for t in batch:
            t._release_slot()
        live = [t for t in batch if not t.cancelled]
        if not live:
            return
        now = time.perf_counter()
        for t in live:
            t.dispatched_at = now
        with self._lock:
            self._inflight = list(live)
            self._dispatch_started = now
        # OUTSIDE the try: a raise-mode fault here kills the worker (the
        # watchdog restarts it and fails only this in-flight batch); a
        # delay-mode fault hangs it (the watchdog detects the stale
        # heartbeat).  Inside the try it would be just another dispatch
        # error — and prove nothing about recovery.
        faults.fire(faults.SERVE_DISPATCH)
        if not self._current(gen):
            # the watchdog declared us hung during the fault delay and
            # already failed this batch + started our successor: don't
            # burn solver time on tickets that have been answered.
            return
        try:
            self._dispatch(group, live)
        except BaseException as exc:   # noqa: BLE001 — fail the batch, keep serving
            for t in live:
                t._fail(exc)
        finally:
            with self._lock:
                if self._gen == gen:
                    self._inflight = None
                    self._dispatch_started = None
        for t in live:                 # dispatch forgot one: fail defensively
            if not t.done:
                t._fail(RuntimeError(
                    f"dispatch left ticket unresolved (group={group!r})"))

    def _note_crash(self, gen: int, exc: BaseException) -> None:
        """Dying worker's own crash bookkeeping: fail the in-flight batch
        so clients unblock immediately instead of at the next watchdog
        poll.  The watchdog still performs the restart."""
        with self._lock:
            if self._gen != gen:
                return
            self._crashes += 1
            inflight, self._inflight = self._inflight, None
            self._dispatch_started = None
        err = WorkerCrashed(
            f"dispatch worker crashed with {exc!r}; in-flight batch "
            "failed, worker restarting — safe to retry")
        for t in inflight or []:
            t._fail(err)

    def _drain_and_stop(self) -> None:
        """Clean shutdown (current generation only): fail anything still
        live so no client hangs forever, then mark stopped."""
        for batch in self._pending_map.values():
            for t in batch:
                t._fail(Cancelled("batcher stopped"))
                t._release_slot()
        self._pending_map.clear()
        self._oldest.clear()
        self._fail_stragglers()
        self._stopped.set()

    def _fail_stragglers(self) -> None:
        """Fail every ticket still sitting in intake.  Called by the
        stopping worker after its drain AND by any submitter whose enqueue
        raced stop() — ``Queue.get_nowait`` is atomic, so concurrent
        drainers each claim a disjoint set and every ticket is failed
        exactly once."""
        while True:
            try:
                item = self._intake.get_nowait()
            except queue.Empty:
                return
            if item is _SENTINEL:
                continue
            item._fail(RuntimeError(
                "ticket submitted while the batcher was stopping; the "
                "drain had already passed — resubmit to a live batcher"))
            item._release_slot()

    # --- supervisor -----------------------------------------------------
    def _watch(self) -> None:
        while not self._stopped.wait(self._watch_interval):
            with self._lock:
                thread = self._thread
                started = self._dispatch_started
            hung = (self.hang_timeout is not None and started is not None
                    and time.perf_counter() - started > self.hang_timeout)
            if self._stopped.is_set():
                return
            if not thread.is_alive():
                self._restart("dispatch worker died")
            elif hung:
                self._restart(
                    f"dispatch exceeded hang_timeout_s="
                    f"{self.hang_timeout:g}s")

    def _restart(self, reason: str) -> None:
        """Fail only the in-flight batch, bump the generation (stranding
        any zombie worker), and start a successor that resumes the queued
        work."""
        with self._lock:
            if self._stopped.is_set():
                return
            self._gen += 1
            gen = self._gen
            self._restarts += 1
            inflight, self._inflight = self._inflight, None
            self._dispatch_started = None
            successor = threading.Thread(
                target=self._run, args=(gen,),
                name=f"{self._name}-gen{gen}", daemon=True)
            self._thread = successor
        if inflight:
            err = WorkerCrashed(
                f"{reason}; in-flight batch failed, worker restarted — "
                "safe to retry")
            for t in inflight:
                t._fail(err)
        successor.start()
        if self._stopping.is_set():
            # the shutdown sentinel may have died with the predecessor;
            # re-arm it so the successor finishes the drain.
            self._intake.put(_SENTINEL)


__all__ = ["Cancelled", "ContinuousBatcher", "QueueFull", "Ticket"]
