"""Continuous batching: coalesce same-group requests within a deadline
window, dispatch them as one batch.

Plain threads + ``queue.Queue`` — no asyncio runtime dependency, so the
batcher embeds in any host (a test, the CLI, a larger service) without an
event loop.  One worker thread owns all dispatch; per-group pending lists
flush when they reach ``max_batch`` or when their oldest request has aged
past the ``window_ms`` deadline, whichever comes first.  The intake queue
is *bounded*: past ``max_queue`` undispatched requests, ``submit`` raises
:class:`QueueFull` — the server rejects rather than OOMs under overload
(the caller retries with backoff; silently buffering unbounded operands is
how a solve server dies).

Per-request lifecycle is a :class:`Ticket`: the client blocks on
``result(timeout=...)``, may ``cancel()`` at any point (a cancelled ticket
is dropped at flush time, before any solver work), and reads its measured
``latency_ms`` afterwards.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Callable, Dict, Hashable, List, Optional

_SENTINEL = object()


class QueueFull(RuntimeError):
    """Backpressure: the intake queue is at capacity; retry later."""


class Cancelled(RuntimeError):
    """The request was cancelled (by the client or at shutdown)."""


class Ticket:
    """One in-flight request: a result slot + completion event.

    Created by :meth:`ContinuousBatcher.submit`; resolved (or failed) by
    the dispatch function on the worker thread.

    State transitions (resolve / fail / cancel) are serialized by a
    per-ticket lock: exactly ONE transition wins, so ``cancel()`` returns
    True only when the cancel actually preempted a result — it can no
    longer race the worker's ``_resolve`` and claim a delivered result was
    cancelled.  The backpressure slot a ticket occupies in its batcher is
    released exactly once (at cancel time, flush time, or shutdown —
    whichever comes first).
    """

    __slots__ = ("group", "payload", "submitted_at", "dispatched_at",
                 "latency_ms", "_done", "_result", "_error", "_cancelled",
                 "_lock", "_released", "_batcher")

    def __init__(self, group: Hashable, payload: Any,
                 batcher: Optional["ContinuousBatcher"] = None):
        self.group = group
        self.payload = payload
        self.submitted_at = time.perf_counter()
        self.dispatched_at: Optional[float] = None
        self.latency_ms: Optional[float] = None
        self._done = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self._lock = threading.Lock()
        self._released = False
        self._batcher = batcher

    # --- client side ---------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Cancel if not already completed; True when the cancel won.

        A cancelled ticket never reaches the solver (the worker drops it
        at flush time) and immediately stops occupying the batcher's
        backpressure budget; any thread blocked in :meth:`result` gets
        :class:`Cancelled`.
        """
        with self._lock:
            if self._done.is_set():
                return False
            self._cancelled = True
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._error = Cancelled("request cancelled")
            self._done.set()
        self._release_slot()
        return True

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until resolved; raises the dispatch error, ``Cancelled``,
        or ``TimeoutError`` after ``timeout`` seconds."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request not served within {timeout}s (group="
                f"{self.group!r}); cancel() to drop it")
        if self._error is not None:
            raise self._error
        return self._result

    # --- worker side ---------------------------------------------------
    def _resolve(self, result: Any) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._result = result
            self._done.set()

    def _fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self.latency_ms = (time.perf_counter()
                               - self.submitted_at) * 1e3
            self._error = exc
            self._done.set()

    def _release_slot(self) -> None:
        """Give the batcher's backpressure slot back, exactly once.

        Callable from the client (cancel), the worker (flush) and the
        shutdown drain; the per-ticket lock arbitrates, so concurrent
        callers can never double-decrement ``_pending_n``.  Lock order is
        always ticket → batcher (never the reverse), so no deadlock.
        """
        with self._lock:
            if self._released:
                return
            self._released = True
        b = self._batcher
        if b is not None:
            with b._lock:
                b._pending_n -= 1


DispatchFn = Callable[[Hashable, List[Ticket]], None]


class ContinuousBatcher:
    """Deadline-window request coalescer with one dispatch worker thread.

    ``dispatch(group, tickets)`` receives only live (non-cancelled)
    tickets and must resolve every one (``Ticket._resolve``/``_fail``);
    an exception escaping dispatch fails the whole batch, and any ticket
    a dispatch forgets is failed defensively — a client can never hang on
    a flushed batch.

    Parameters
    ----------
    dispatch    the batch executor (runs on the worker thread).
    max_batch   flush a group at this many pending requests.
    window_ms   flush a group when its oldest request is this old.
    max_queue   bound on undispatched requests across all groups; beyond
                it ``submit`` raises :class:`QueueFull`.
    """

    def __init__(self, dispatch: DispatchFn, *, max_batch: int = 8,
                 window_ms: float = 4.0, max_queue: int = 256,
                 name: str = "solve-batcher"):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._dispatch = dispatch
        self.max_batch = int(max_batch)
        self.window = float(window_ms) / 1e3
        self.max_queue = int(max_queue)
        self._intake: "queue.Queue" = queue.Queue()
        self._pending_n = 0
        self._lock = threading.Lock()
        self._stopping = threading.Event()
        self._stopped = threading.Event()
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # --- client side ---------------------------------------------------
    def submit(self, group: Hashable, payload: Any) -> Ticket:
        if self._stopping.is_set():
            raise RuntimeError("batcher is stopped")
        with self._lock:
            if self._pending_n >= self.max_queue:
                raise QueueFull(
                    f"{self._pending_n} requests already queued "
                    f"(max_queue={self.max_queue}); retry with backoff")
            self._pending_n += 1
        ticket = Ticket(group, payload, batcher=self)
        self._intake.put(ticket)
        return ticket

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending_n

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Drain: flush everything already queued, then stop the worker."""
        self._stopping.set()
        self._intake.put(_SENTINEL)
        self._stopped.wait(timeout)

    # --- worker side ---------------------------------------------------
    def _run(self) -> None:
        pending: "collections.OrderedDict[Hashable, List[Ticket]]" = \
            collections.OrderedDict()
        oldest: Dict[Hashable, float] = {}
        try:
            while True:
                timeout: Optional[float] = None
                if pending:
                    now = time.perf_counter()
                    nearest = min(oldest.values())
                    timeout = max(0.0, nearest + self.window - now)
                try:
                    item = self._intake.get(timeout=timeout)
                except queue.Empty:
                    item = None
                if item is not None and item is not _SENTINEL:
                    grp = pending.setdefault(item.group, [])
                    grp.append(item)
                    # window measured from when the group started pending,
                    # NOT from submit time: requests that queued up behind
                    # a long dispatch still get a chance to coalesce.
                    oldest.setdefault(item.group, time.perf_counter())
                    if len(grp) >= self.max_batch:
                        self._flush(pending, oldest, item.group)
                # deadline-expired groups (and everything, at shutdown)
                now = time.perf_counter()
                for g in [g for g, t0 in list(oldest.items())
                          if self._stopping.is_set()
                          or now - t0 >= self.window]:
                    self._flush(pending, oldest, g)
                if (self._stopping.is_set() and not pending
                        and self._intake.empty()):
                    return
        finally:
            # fail anything still live so no client hangs forever
            for batch in pending.values():
                for t in batch:
                    t._fail(Cancelled("batcher stopped"))
                    t._release_slot()
            self._stopped.set()

    def _flush(self, pending, oldest, group: Hashable) -> None:
        batch = pending.pop(group, [])
        oldest.pop(group, None)
        if not batch:
            return
        # cancelled tickets released their slot at cancel time; the rest
        # release here — _release_slot is exactly-once per ticket.
        for t in batch:
            t._release_slot()
        live = [t for t in batch if not t.cancelled]
        if not live:
            return
        now = time.perf_counter()
        for t in live:
            t.dispatched_at = now
        try:
            self._dispatch(group, live)
        except BaseException as exc:   # noqa: BLE001 — fail the batch, keep serving
            for t in live:
                t._fail(exc)
        for t in live:                 # dispatch forgot one: fail defensively
            if not t.done:
                t._fail(RuntimeError(
                    f"dispatch left ticket unresolved (group={group!r})"))


__all__ = ["Cancelled", "ContinuousBatcher", "QueueFull", "Ticket"]
