"""Factorization-as-a-service: the multi-tenant solve server.

The paper's target workloads issue *streams* of partial SVDs; PR 5's
Plan/Session layer made one stream compile-once, this package serves many
concurrent clients through the same process-wide plan cache:

    bucket.py     shape-bucketing + zero-padded transport to canonical avals
    batcher.py    continuous batching under a supervised, restartable
                  dispatch worker (thread + queue.Queue, no asyncio)
    resilience.py typed failure taxonomy, circuit breaker, retry backoff,
                  HMT residual probe gating degraded answers
    tenant.py     per-tenant Session state (LRU-evicted, checkpointable)
    traffic.py    synthetic Zipf traffic shared by the CLI and the bench
    server.py     the front end wiring intake -> bucket -> batch -> plan,
                  plus deadlines / quarantine / breaker / degraded mode

Quickstart::

    from repro.serve import SolveServer
    with SolveServer(SVDSpec(rank=8), key=jax.random.key(0)) as srv:
        fact = srv.solve(A).value            # sync, batched under the hood
        t = srv.submit(A2)                    # async: a Ticket
        print(t.result(timeout=5.0).value.s)
        print(srv.stats())

or from a shell: ``python -m repro.launch.solve_serve --requests 200``.
"""
from repro.serve.batcher import (Cancelled, ContinuousBatcher, QueueFull,
                                 Ticket)
from repro.serve.bucket import (Bucketed, bucket_shape, embed, stack_buckets,
                                unpad_factors)
from repro.serve.resilience import (CircuitBreaker, CircuitOpen,
                                    DeadlineExceeded, DegradedRejected,
                                    PoisonedOperand, WorkerCrashed,
                                    residual_probe)
from repro.serve.server import ServeResult, SolveServer
from repro.serve.tenant import TenantRegistry
from repro.serve.traffic import Request, lowrank_drift, synthetic_stream

__all__ = [
    "Bucketed", "bucket_shape", "embed", "stack_buckets", "unpad_factors",
    "Cancelled", "ContinuousBatcher", "QueueFull", "Ticket",
    "CircuitBreaker", "CircuitOpen", "DeadlineExceeded", "DegradedRejected",
    "PoisonedOperand", "WorkerCrashed", "residual_probe",
    "TenantRegistry", "ServeResult", "SolveServer",
    "Request", "lowrank_drift", "synthetic_stream",
]
