"""Shape bucketing — collapse a heavy-traffic shape mix onto canonical avals.

A serve front end sees an open-ended mix of operand shapes; staging one
executable per exact shape is an executable-count (and compile-latency)
DoS.  Bucketing rounds every dim up to a ``quantum`` grid — the same
arithmetic ``distributed.partition.padded_operand_shape`` uses for mesh
tiling, via the shared :mod:`repro.core.padding` helper — so the traffic
collapses onto a bounded set of canonical buckets, and same-bucket request
buffers stack into one batched dispatch.

Correctness contract (the part that earns the "never perturb σ" claim):

* **exact mode** (the default): the padded buffer is *transport only*.
  Before the solve, :meth:`Bucketed.extract` slices the logical operand
  back out — slicing moves bytes, it never rounds — and the solver runs at
  the logical shape through the ordinary plan cache.  Same executable,
  same input bits ⇒ σ **bit-identical** to an unbucketed solve.  Requests
  then group per *logical* shape; the bucket bounds transport avals and
  batch grouping, not the executable count.

* **shared mode**: the solver runs at the *bucket* shape, so every logical
  shape in a bucket shares one executable per batch size — maximal
  sharing.  Zero rows/cols are mathematically inert for every matvec/CGS
  reduction, but XLA re-associates reductions for the padded width, so σ
  can move in the last ulps (observed ~1e-6 relative on f32 zoo matrices).
  :func:`unpad_factors` slices U/V back to logical rows afterwards.

``tests/test_serve.py`` pins both halves of the contract on the parity
zoo: exact-mode round-trips are bit-identical, shared-mode stays within
accuracy tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import numpy as np

from repro.core.padding import pad_to, padded_shape, unpad

Array = jax.Array

# default bucket granularity: coarse enough to collapse a Zipf shape mix
# onto a handful of buckets, fine enough that padding waste stays < ~2x.
DEFAULT_QUANTUM = 32


def bucket_shape(shape: Sequence[int],
                 quantum: int = DEFAULT_QUANTUM) -> Tuple[int, ...]:
    """Canonical (bucket) shape for ``shape``: every dim rounded up to a
    multiple of ``quantum`` — the serve-side twin of
    ``partition.padded_operand_shape``."""
    return padded_shape(shape, (quantum,) * len(shape))


@dataclasses.dataclass(frozen=True)
class Bucketed:
    """One request operand in padded (canonical-aval) transport form.

    ``data`` is the zero-embedded bucket buffer; ``logical_shape`` is the
    caller's true geometry.  :meth:`extract` restores the logical operand
    exactly (a slice, no arithmetic).  Transport stays **numpy**: the
    intake path must not pay an XLA compile per (shape, batch) signature
    just to move bytes — arrays cross to the device once per dispatched
    batch, at the solve boundary (``stack_buckets`` / the server).
    """

    data: Any                      # np.ndarray (host transport buffer)
    logical_shape: Tuple[int, ...]

    @property
    def bucket(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def padded(self) -> bool:
        return self.bucket != tuple(self.logical_shape)

    def extract(self):
        """The logical operand, bit-for-bit (exact slice, numpy view)."""
        return unpad(self.data, self.logical_shape)


def embed(A, quantum: int = DEFAULT_QUANTUM) -> Bucketed:
    """Zero-embed ``A`` into its bucket's canonical aval (host-side)."""
    A = np.asarray(A)
    return Bucketed(data=pad_to(A, bucket_shape(A.shape, quantum)),
                    logical_shape=tuple(A.shape))


def stack_buckets(items: Sequence[Bucketed]) -> Array:
    """Stack same-bucket transport buffers into a (B, M, N) device batch.

    All items must share one bucket (that is what the batcher's group key
    guarantees).  The stack happens host-side (numpy), then crosses to the
    device in one ``device_put`` — the only transfer on the dispatch path.
    """
    if not items:
        raise ValueError("cannot stack an empty bucket batch")
    buckets = {it.bucket for it in items}
    if len(buckets) != 1:
        raise ValueError(f"mixed buckets in one batch: {sorted(buckets)}")
    return jax.device_put(np.stack([np.asarray(it.data) for it in items]))


def unpad_factors(fact, logical_shape: Tuple[int, int]):
    """Slice a bucket-shape factorization's U/V back to logical rows.

    For a zero-padded operand the top-r left/right singular vectors have
    (mathematically) zero support on the padded rows/cols; shared-mode
    serving discards them after the solve.  σ is returned as computed —
    shared mode's documented roundoff-level perturbation lives there.
    """
    m, n = logical_shape
    return dataclasses.replace(fact, U=fact.U[..., :m, :],
                               V=fact.V[..., :n, :])


__all__ = ["DEFAULT_QUANTUM", "Bucketed", "bucket_shape", "embed",
           "stack_buckets", "unpad_factors"]
