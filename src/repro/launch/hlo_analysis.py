"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, so any scanned-layer model under-reports FLOPs/bytes by ~num_layers x
(verified empirically; see EXPERIMENTS.md §Dry-run).  This module re-derives
the three roofline inputs from the post-SPMD optimized HLO text itself:

  * dot FLOPs       — every ``dot`` op: 2 x prod(result shape) x contracted
                      size, weighted by the product of enclosing while-loop
                      trip counts (parsed from each loop condition constant);
  * collective bytes — result bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute,
                      trip-weighted, by kind;
  * HBM bytes       — trip-weighted sum of result-buffer bytes written by
                      non-fused instructions (read traffic ~= write traffic
                      for the big streams, so memory time uses 2x this;
                      ``dynamic-update-slice`` counts only the update
                      operand — it writes a slice, not the buffer).

All numbers are PER DEVICE: the input is the SPMD-partitioned module.
Elementwise FLOPs are ignored (dots dominate every cell here); fusion
computations contribute their dots to FLOPs but not their internals to HBM
bytes (a fusion is one kernel; intermediates stay in registers/VMEM).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls)=\{?%?([\w.\-]+)\}?")
_OPCODE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)(?:\(|\.)")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_type_bytes(tstr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tstr):
        dt, dims = m.groups()
        total += _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    is_entry: bool = False


def parse_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1), [],
                                  is_entry=line.startswith("ENTRY"))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        if "=" not in line:
            continue
        name = line.split("=", 1)[0].strip().lstrip("%")
        m = _OPCODE.search(line)
        if not m:
            continue
        opcode = m.group(1)
        # result type: text between '=' and the opcode
        rt = line.split("=", 1)[1]
        rt = rt[:rt.find(opcode)].strip()
        cur.instructions.append(Instruction(name, opcode, rt, line))
    return comps


_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(ins: Instruction, comps: dict) -> int:
    """Trip count of a while: prefer the compiler's known_trip_count
    backend_config; fall back to the largest constant in the condition."""
    m = _TRIP_CFG.search(ins.line)
    if m:
        return int(m.group(1))
    mcnd = re.search(r"condition=\{?%?([\w.\-]+)\}?", ins.line)
    if mcnd and mcnd.group(1) in comps:
        best = 1
        for cins in comps[mcnd.group(1)].instructions:
            for mm in re.finditer(r"constant\((\d+)\)", cins.line):
                best = max(best, int(mm.group(1)))
        return best
    return 1


def _dot_flops(ins: Instruction, symtab: dict) -> float:
    """2 x prod(result) x contracted-size for one dot line.

    Operand types are not inline in optimized HLO — resolve the lhs type via
    the per-computation symbol table."""
    out_elems = 0
    for m in _SHAPE_RE.finditer(ins.result_type):
        out_elems += _shape_elems(m.group(2))
    args = ins.line[ins.line.find("dot(") + 4:]
    # older jaxlibs print operand types inline: ``dot(f32[64,64]{1,0}
    # %Arg_0.1, ...)`` — use the inline lhs type directly when present.
    m_inline = re.match(r"\s*(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+%?[\w.\-]+",
                        args)
    if m_inline:
        lhs_type = m_inline.group(1)
    else:
        mo = re.match(r"\s*%?([\w.\-]+)", args)
        if mo is None:
            return 0.0
        lhs_type = symtab.get(mo.group(1), "")
    ml = _SHAPE_RE.search(lhs_type)
    if ml is None:
        return 0.0
    lhs_dims = [int(d) for d in ml.group(2).split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if mc:
        for d in mc.group(1).split(","):
            if d:
                contracted *= lhs_dims[int(d)]
    return 2.0 * out_elems * contracted


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "while", "conditional", "call", "custom-call",
    "get-dimension-size", "broadcast", "reshape",
}


_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
# ops whose operand reads we charge to HBM (weights/activations streamed
# from HBM into the compute unit); elementwise ops are fusion-wrapped by XLA
# so charging fusion operands covers them.
_READ_OPS = {"dot", "fusion"} | set(COLLECTIVE_KINDS) \
    | {k + "-start" for k in COLLECTIVE_KINDS}


def _operand_read_bytes(ins: Instruction, symtab: dict,
                        vmem_threshold: int = 0) -> int:
    """Sum of operand-buffer bytes for ops that stream inputs from HBM
    (operands smaller than ``vmem_threshold`` are assumed VMEM-resident).

    Elementwise (``kind=kLoop``) fusions touch at most result-size elements
    of each operand — a kLoop fusion that dynamic-slices one layer out of a
    stacked (L, ...) buffer reads ONE slice, not the whole stack, so each
    operand's charge is capped at the result size.  Reduction-rooted
    (kInput) fusions and raw dots read their operands fully.
    """
    call = ins.line[ins.line.find("=") + 1:]
    p0 = call.find("(")
    if p0 < 0:
        return 0
    # cut at the matching close paren of the operand list
    depth = 0
    end = len(call)
    for i, ch in enumerate(call[p0:], start=p0):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    cap = None
    if ins.opcode == "fusion" and "kind=kLoop" in ins.line:
        cap = _first_type_bytes(ins.result_type)
    total = 0
    for name in _OPERANDS_RE.findall(call[p0:end]):
        b = _first_type_bytes(symtab.get(name, ""))
        if cap is not None:
            b = min(b, cap)
        if b >= vmem_threshold:
            total += b
    return total


def _is_dus(ins: Instruction) -> bool:
    """dynamic-update-slice either as a raw op or as the root of a fusion
    (XLA emits `..._dynamic-update-slice_fusion` for in-place stacking —
    scan residual stashes, cache writes)."""
    return (ins.opcode.startswith("dynamic-update-slice")
            or (ins.opcode == "fusion" and "dynamic-update-slice" in ins.line
                and "dynamic-update-slice" in ins.name))


def _dus_update_bytes(ins: Instruction, symtab: dict) -> int:
    """Bytes of the updated slice: the largest operand strictly smaller than
    the result buffer (skips the aliased accumulator and the indices)."""
    result = _first_type_bytes(ins.result_type)
    best = 0
    for name in _OPERANDS_RE.findall(ins.line[ins.line.find("("):]):
        b = _first_type_bytes(symtab.get(name, ""))
        if b < result:
            best = max(best, b)
    return best


def _instr_write_bytes(ins: Instruction, symtab: dict) -> int:
    if ins.opcode in _SKIP_BYTES_OPS:
        return 0
    if _is_dus(ins):
        return _dus_update_bytes(ins, symtab)
    return _first_type_bytes(ins.result_type)


@dataclasses.dataclass
class HLOCost:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: dict          # kind -> bytes
    collective_counts: dict         # kind -> static instruction count

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# Buffers smaller than this are assumed VMEM-resident on the TPU target
# (v5e has ~100 MiB VMEM/core; 16 MiB leaves room for double buffering and
# concurrent live tiles) and charged zero HBM traffic.  This is what makes
# flash-style tiled attention measurable: its per-tile intermediates fit
# VMEM while naive attention's (B, H, S, S) logits buffer cannot.
VMEM_THRESHOLD = 16 * 2**20


def analyze(hlo: str, vmem_threshold: int = VMEM_THRESHOLD) -> HLOCost:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:                      # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instructions))

    flops = 0.0
    hbm = 0.0
    coll_b = {k: 0.0 for k in COLLECTIVE_KINDS}
    coll_n = {k: 0 for k in COLLECTIVE_KINDS}

    # (computation, weight, fused?) work-list; visited per (name, context)
    # may legitimately repeat (a body called from two sites) — accumulate.
    symtabs = {name: {i.name: i.result_type for i in c.instructions}
               for name, c in comps.items()}

    stack = [(entry, 1.0, False)]
    seen_guard = 0
    while stack:
        comp, weight, fused = stack.pop()
        symtab = symtabs[comp.name]
        seen_guard += 1
        if seen_guard > 100000:
            break
        for ins in comp.instructions:
            op = ins.opcode
            if op == "dot":
                flops += weight * _dot_flops(ins, symtab)
            kind = None
            for k in COLLECTIVE_KINDS:
                if op == k or op == k + "-start":
                    kind = k
                    break
            if kind is not None:
                coll_b[kind] += weight * _first_type_bytes(ins.result_type)
                coll_n[kind] += 1
            if not fused:
                wb = _instr_write_bytes(ins, symtab)
                if wb >= vmem_threshold:
                    hbm += weight * wb
                if op in _READ_OPS and not _is_dus(ins):
                    hbm += weight * _operand_read_bytes(ins, symtab,
                                                        vmem_threshold)
            # recurse into called computations
            if op == "while":
                mb = re.search(r"body=\{?%?([\w.\-]+)\}?", ins.line)
                trip = _trip_count(ins, comps)
                if mb and mb.group(1) in comps:
                    stack.append((comps[mb.group(1)], weight * trip, fused))
            elif op == "fusion":
                mf = re.search(r"calls=\{?%?([\w.\-]+)\}?", ins.line)
                if mf and mf.group(1) in comps:
                    stack.append((comps[mf.group(1)], weight, True))
            elif op in ("call", "conditional", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter"):
                for name in _CALLED.findall(ins.line):
                    if name in comps and op in ("call", "conditional"):
                        stack.append((comps[name], weight, fused))

    return HLOCost(flops, hbm, coll_b, coll_n)
