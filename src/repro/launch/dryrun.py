"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=...).lower(*abstract_inputs).compile()`` on a
512-placeholder-device mesh.  Sharding mismatches, OOM-at-compile and
unsupported collectives all fail HERE.

Per compiled cell we record (for EXPERIMENTS.md §Dry-run / §Roofline):
  * ``memory_analysis()``  — per-device argument/output/temp/peak bytes,
  * ``cost_analysis()``    — HLO FLOPs + bytes accessed,
  * collective bytes by op kind, parsed from the post-SPMD HLO text,
  * MODEL_FLOPS = 6·N·D (2·N·D fwd-only), N = active params.

Usage:
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# at first init.  Do NOT copy this into conftest/pyproject — tests and
# benches must see 1 device.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch, get_shape  # noqa: E402
from repro.configs.base import ModelConfig, OptimConfig, ShapeConfig  # noqa: E402
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_mod  # noqa: E402
from repro.runtime import steps as steps_mod  # noqa: E402

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _type_bytes(tstr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tstr):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes and instruction counts by collective kind."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        tstr, kind = m.groups()
        out[kind]["bytes"] += _type_bytes(tstr)
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D)
# ---------------------------------------------------------------------------

def active_param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total params, active-per-token params) from the abstract init."""
    params, logical = ispec.abstract_init(cfg)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_l = jax.tree_util.tree_leaves(
        logical, is_leaf=lambda x: isinstance(x, tuple))
    total = active = 0
    for p, axes in zip(flat_p, flat_l):
        n = 1
        for d in p.shape:
            n *= d
        total += n
        if cfg.moe is not None and "experts" in axes:
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    _, n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             optim_cfg: OptimConfig = OptimConfig(),
             cfg_overrides: dict | None = None,
             save_hlo_to: str | None = None,
             compressed_grads: bool = False) -> dict:
    """Lower+compile one cell.  ``cfg_overrides`` patches the ModelConfig
    (hillclimb variants); ``compressed_grads`` swaps in the pod-axis
    Krylov-compressed train step (multi-pod train cells only)."""
    cfg = get_arch(arch)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(arch, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = ispec.cell_inputs(cfg, shape, optim_cfg, mesh)

    if cell["kind"] == "train":
        if compressed_grads:
            from repro.configs.base import FsvdConfig
            fn = steps_mod.build_compressed_train_step(
                cfg, optim_cfg, mesh,
                FsvdConfig(compression_rank=8, compression_min_dim=512,
                           max_iters=16))
        else:
            fn = steps_mod.build_train_step(cfg, optim_cfg, mesh)
        donate = (0,)
    elif cell["kind"] == "prefill":
        def fn(params, batch):
            return model_mod.prefill_step(params, batch, cfg, mesh)
        donate = ()
    else:
        def fn(params, cache, batch):
            return model_mod.decode_step(params, cache, batch, cfg, mesh)
        donate = (1,)

    out_shardings = None
    if cell["kind"] == "decode":
        # pin the updated cache to its input layout: without this XLA may
        # pick a different output sharding and reshard the whole multi-GiB
        # cache every step
        out_shardings = (None, cell["in_shardings"][1])

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=cell["in_shardings"],
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell["args_struct"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    if save_hlo_to:
        import gzip
        os.makedirs(os.path.dirname(save_hlo_to) or ".", exist_ok=True)
        with gzip.open(save_hlo_to, "wt") as f:
            f.write(hlo_text)
    # trip-count-aware per-device analysis (cost_analysis counts while
    # bodies ONCE — a ~num_layers x undercount on scanned models; see
    # repro.launch.hlo_analysis)
    hc = hlo_analysis.analyze(hlo_text)
    n_total, n_active = active_param_count(cfg)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell["kind"], "status": "ok",
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": hc.dot_flops,
        "bytes_per_device": hc.hbm_bytes,
        "xla_flops_per_device": float(cost.get("flops", -1.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", -1)
                              if hasattr(mem, "peak_memory_in_bytes") else -1),
        },
        "collectives": {
            **{k: {"bytes": hc.collective_bytes[k],
                   "count": hc.collective_counts[k]}
               for k in hlo_analysis.COLLECTIVE_KINDS},
            "total_bytes": hc.total_collective_bytes,
        },
        "params_total": n_total, "params_active": n_active,
        "model_flops_global": model_flops(cfg, shape),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true",
                    help="also store gzipped post-SPMD HLO per cell")
    ap.add_argument("--pin", action="store_true",
                    help="tuned profile: pin_activations=True (see §Perf)")
    args = ap.parse_args()
    overrides = {"pin_activations": True} if args.pin else None

    cells = []
    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                tag = f"{arch}_{shape}_{mesh_name}"
                hlo_path = (os.path.join(args.out, "hlo", tag + ".hlo.gz")
                            if args.save_hlo else None)
                try:
                    rec = run_cell(arch, shape, mp, save_hlo_to=hlo_path,
                                   cfg_overrides=overrides)
                except Exception as e:                    # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "failed", "error": str(e),
                           "traceback": traceback.format_exc()}
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                extra = ""
                if st == "ok":
                    gb = rec["memory"]["argument_bytes"] / 2**30
                    extra = (f" args {gb:.2f} GiB/dev, "
                             f"{rec['flops_per_device']:.3g} flops/dev, "
                             f"coll {rec['collectives']['total_bytes']/2**20:.1f} MiB, "
                             f"compile {rec['compile_s']:.1f}s")
                elif st == "failed":
                    extra = " " + rec["error"][:200]
                print(f"[dryrun] {tag}: {st}{extra}", flush=True)
    print(f"[dryrun] done: {n_ok} ok / {n_skip} skipped / {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
