"""Solve-server CLI: synthetic traffic through ``repro.serve``.

    PYTHONPATH=src python -m repro.launch.solve_serve --requests 200 \
        --rank 8 --tenants 4 --max-batch 8 --window-ms 4

Drives a Zipf-distributed shape mix (``repro.serve.traffic``) into a
:class:`~repro.serve.server.SolveServer` from a pool of client threads and
prints the server's stats endpoint as JSON — requests/sec, p50/p99
latency, bucket hit rate, batch histogram, tenant-session counters, the
process-wide plan-cache counters and the health block (breaker states,
worker restarts, quarantines, deadline drops, degraded fraction).
``--stats-every N`` streams interim snapshots (one JSON line each) while
traffic runs, which is the "endpoint": poll it instead of scraping logs.

``--deadline-ms`` attaches a per-request deadline (expired requests are
dropped at dispatch admission); ``--chaos`` runs the whole replay under
fault injection (``repro.runtime.faults.chaos``: dispatch crashes/hangs +
transient solver faults) — the reliability claim is that the driver still
drains with every request terminating in a result, a labeled degraded
result, or a typed error.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import threading
import time

import jax

from repro.api.spec import SVDSpec
from repro.runtime import faults
from repro.serve import QueueFull, SolveServer, WorkerCrashed
from repro.serve.traffic import DEFAULT_SHAPES, synthetic_stream


def run_traffic(server: SolveServer, requests, *, clients: int = 4,
                timeout: float = 120.0, deadline_ms=None,
                max_attempts: int = 3, on_result=None) -> dict:
    """Replay ``requests`` through ``server`` from ``clients`` threads.

    Returns ``{"ok", "degraded", "rejected", "failed", "timeouts",
    "errors", "wall_s"}``.  Rejected submissions (backpressure) and
    :class:`~repro.serve.resilience.WorkerCrashed` failures — typed "safe
    to retry" — retry with a short backoff up to ``max_attempts``; other
    failures are terminal and tallied by exception type under
    ``"errors"``.  Result waits use ``cancel_on_timeout=True`` so an
    abandoned request releases its ``max_queue`` slot instead of pinning
    backpressure capacity.  ``on_result(req, outcome, detail)`` (called
    under the tally lock) lets callers collect per-request results — the
    chaos bench uses it to gate degraded answers for accuracy.
    """
    requests = list(requests)
    counts = {"ok": 0, "degraded": 0, "rejected": 0, "failed": 0,
              "timeouts": 0}
    errors: dict = {}
    lock = threading.Lock()
    it = iter(requests)

    def one(operand, kind, tenant):
        attempt = 0
        while True:
            attempt += 1
            try:
                ticket = server.submit(operand, kind=kind, tenant=tenant,
                                       deadline_ms=deadline_ms)
            except QueueFull:
                if attempt < max_attempts:
                    time.sleep(0.05)
                    continue
                return "rejected", None
            except Exception as exc:    # noqa: BLE001 — e.g. quarantine
                return "failed", exc
            try:
                res = ticket.result(timeout, cancel_on_timeout=True)
                return "ok", res
            except TimeoutError:
                # cancel_on_timeout released the slot; the request is gone
                return "timeouts", None
            except WorkerCrashed as exc:
                if attempt < max_attempts:
                    time.sleep(0.02)
                    continue
                return "failed", exc
            except Exception as exc:    # noqa: BLE001 — typed, terminal
                return "failed", exc

    def worker():
        while True:
            with lock:
                req = next(it, None)
            if req is None:
                return
            if req.kind == "delta":
                # structured tenant drift: ship only the low-rank factors
                operand, kind = req.delta, "delta"
            elif req.kind == "entries":
                # unstructured tenant drift: ship only the COO triplets
                operand, kind = req.entries, "entries"
            elif req.tenant is not None:
                operand, kind = req.A, "factorize"
            else:
                operand, kind = req.A, req.kind
            outcome, detail = one(operand, kind, req.tenant)
            with lock:
                counts[outcome] += 1
                if outcome == "ok" and getattr(detail, "meta", None) \
                        and detail.meta.get("degraded"):
                    counts["degraded"] += 1
                if outcome == "failed":
                    name = type(detail).__name__
                    errors[name] = errors.get(name, 0) + 1
                if on_result is not None:
                    on_result(req, outcome, detail)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    counts["wall_s"] = time.perf_counter() - t0
    counts["errors"] = errors
    return counts


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--method", default="fsvd")
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--tenant-fraction", type=float, default=0.25)
    ap.add_argument("--estimate-fraction", type=float, default=0.0)
    ap.add_argument("--structured-drift", action="store_true",
                    help="tenant drifts are rank-k deltas shipped as "
                         "kind='delta' requests (the serving stack's "
                         "zero-iteration update path)")
    ap.add_argument("--drift-rank", type=int, default=2,
                    help="rank of each structured tenant drift")
    ap.add_argument("--quantum", type=int, default=32)
    ap.add_argument("--mode", choices=("exact", "shared"), default="exact")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=4.0)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="evicted tenant sessions checkpoint here")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expired requests are "
                         "dropped at dispatch admission with "
                         "DeadlineExceeded")
    ap.add_argument("--chaos", action="store_true",
                    help="replay under fault injection: dispatch "
                         "crashes/hangs + transient solver faults "
                         "(repro.runtime.faults.chaos)")
    ap.add_argument("--chaos-crash-p", type=float, default=0.03,
                    help="per-dispatch worker-crash probability under "
                         "--chaos")
    ap.add_argument("--chaos-hang-p", type=float, default=0.01,
                    help="per-dispatch hang probability under --chaos")
    ap.add_argument("--chaos-transient-p", type=float, default=0.05,
                    help="per-solve transient-fault probability under "
                         "--chaos")
    ap.add_argument("--hang-timeout-s", type=float, default=30.0,
                    help="watchdog restarts the dispatch worker when one "
                         "dispatch overruns this")
    ap.add_argument("--degraded-method", default="gnystrom",
                    help="in-graph solver backing the breaker's shed "
                         "plan (reported in meta['method'])")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="stream interim stats JSON every N seconds")
    ap.add_argument("--stats-json", default=None,
                    help="write the final stats snapshot to this file")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip deploy-time staging of the traffic shape "
                         "menu (first-of-a-signature batches then compile "
                         "inside the serving path)")
    args = ap.parse_args(argv)

    spec = SVDSpec(method=args.method, rank=args.rank)
    server = SolveServer(spec, quantum=args.quantum, mode=args.mode,
                         max_batch=args.max_batch,
                         window_ms=args.window_ms,
                         max_queue=args.max_queue,
                         checkpoint_dir=args.checkpoint_dir,
                         deadline_ms=args.deadline_ms,
                         hang_timeout_s=args.hang_timeout_s,
                         degraded_method=args.degraded_method,
                         key=jax.random.key(args.seed))
    stream = synthetic_stream(
        args.requests, zipf_a=args.zipf_a, rank=args.rank,
        tenants=args.tenants, tenant_fraction=args.tenant_fraction,
        estimate_fraction=args.estimate_fraction,
        structured_drift=args.structured_drift,
        drift_rank=args.drift_rank, seed=args.seed)
    if not args.no_warmup:
        t0 = time.perf_counter()
        staged = server.warmup(DEFAULT_SHAPES,
                               estimates=args.estimate_fraction > 0)
        print(json.dumps({"warmup": {
            "signatures": staged,
            "wall_s": time.perf_counter() - t0}}), flush=True)

    stop_poll = threading.Event()
    if args.stats_every > 0:
        def poll():
            while not stop_poll.wait(args.stats_every):
                print(json.dumps({"interim": server.stats()}), flush=True)
        threading.Thread(target=poll, daemon=True).start()

    chaos_ctx = faults.chaos(
        args.seed, dispatch_crash_p=args.chaos_crash_p,
        dispatch_hang_p=args.chaos_hang_p,
        solve_transient_p=args.chaos_transient_p) \
        if args.chaos else contextlib.nullcontext()
    with server, chaos_ctx:
        counts = run_traffic(server, stream, clients=args.clients,
                             deadline_ms=args.deadline_ms)
        faults.disarm_all()   # serve the drain (close) fault-free
        stop_poll.set()
        stats = server.stats()

    out = {"driver": counts, "server": stats}
    print(json.dumps(out, indent=2, sort_keys=True))
    if args.stats_json:
        with open(args.stats_json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
    return out


if __name__ == "__main__":
    main()
