"""Solve-server CLI: synthetic traffic through ``repro.serve``.

    PYTHONPATH=src python -m repro.launch.solve_serve --requests 200 \
        --rank 8 --tenants 4 --max-batch 8 --window-ms 4

Drives a Zipf-distributed shape mix (``repro.serve.traffic``) into a
:class:`~repro.serve.server.SolveServer` from a pool of client threads and
prints the server's stats endpoint as JSON — requests/sec, p50/p99
latency, bucket hit rate, batch histogram, tenant-session counters and the
process-wide plan-cache counters.  ``--stats-every N`` streams interim
snapshots (one JSON line each) while traffic runs, which is the
"endpoint": poll it instead of scraping logs.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import jax

from repro.api.spec import SVDSpec
from repro.serve import QueueFull, SolveServer
from repro.serve.traffic import DEFAULT_SHAPES, synthetic_stream


def run_traffic(server: SolveServer, requests, *, clients: int = 4,
                timeout: float = 120.0) -> dict:
    """Replay ``requests`` through ``server`` from ``clients`` threads.

    Returns {"ok": n, "rejected": n, "failed": n, "wall_s": t}.  Rejected
    submissions (backpressure) retry once after a short backoff, then
    count as rejected — the server's contract is reject-don't-OOM and the
    driver honors it.
    """
    requests = list(requests)
    counts = {"ok": 0, "rejected": 0, "failed": 0}
    lock = threading.Lock()
    it = iter(requests)

    def worker():
        while True:
            with lock:
                req = next(it, None)
            if req is None:
                return
            if req.kind == "delta":
                # structured tenant drift: ship only the low-rank factors
                operand, kind = req.delta, "delta"
            elif req.tenant is not None:
                operand, kind = req.A, "factorize"
            else:
                operand, kind = req.A, req.kind
            for attempt in (0, 1):
                try:
                    server.solve(operand, kind=kind, tenant=req.tenant,
                                 timeout=timeout)
                    with lock:
                        counts["ok"] += 1
                    break
                except QueueFull:
                    if attempt == 0:
                        time.sleep(0.05)
                        continue
                    with lock:
                        counts["rejected"] += 1
                except Exception:           # noqa: BLE001 — keep draining
                    with lock:
                        counts["failed"] += 1
                    break

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    counts["wall_s"] = time.perf_counter() - t0
    return counts


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--method", default="fsvd")
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--tenant-fraction", type=float, default=0.25)
    ap.add_argument("--estimate-fraction", type=float, default=0.0)
    ap.add_argument("--structured-drift", action="store_true",
                    help="tenant drifts are rank-k deltas shipped as "
                         "kind='delta' requests (the serving stack's "
                         "zero-iteration update path)")
    ap.add_argument("--drift-rank", type=int, default=2,
                    help="rank of each structured tenant drift")
    ap.add_argument("--quantum", type=int, default=32)
    ap.add_argument("--mode", choices=("exact", "shared"), default="exact")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=4.0)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="evicted tenant sessions checkpoint here")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="stream interim stats JSON every N seconds")
    ap.add_argument("--stats-json", default=None,
                    help="write the final stats snapshot to this file")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip deploy-time staging of the traffic shape "
                         "menu (first-of-a-signature batches then compile "
                         "inside the serving path)")
    args = ap.parse_args(argv)

    spec = SVDSpec(method=args.method, rank=args.rank)
    server = SolveServer(spec, quantum=args.quantum, mode=args.mode,
                         max_batch=args.max_batch,
                         window_ms=args.window_ms,
                         max_queue=args.max_queue,
                         checkpoint_dir=args.checkpoint_dir,
                         key=jax.random.key(args.seed))
    stream = synthetic_stream(
        args.requests, zipf_a=args.zipf_a, rank=args.rank,
        tenants=args.tenants, tenant_fraction=args.tenant_fraction,
        estimate_fraction=args.estimate_fraction,
        structured_drift=args.structured_drift,
        drift_rank=args.drift_rank, seed=args.seed)
    if not args.no_warmup:
        t0 = time.perf_counter()
        staged = server.warmup(DEFAULT_SHAPES,
                               estimates=args.estimate_fraction > 0)
        print(json.dumps({"warmup": {
            "signatures": staged,
            "wall_s": time.perf_counter() - t0}}), flush=True)

    stop_poll = threading.Event()
    if args.stats_every > 0:
        def poll():
            while not stop_poll.wait(args.stats_every):
                print(json.dumps({"interim": server.stats()}), flush=True)
        threading.Thread(target=poll, daemon=True).start()

    with server:
        counts = run_traffic(server, stream, clients=args.clients)
        stop_poll.set()
        stats = server.stats()

    out = {"driver": counts, "server": stats}
    print(json.dumps(out, indent=2, sort_keys=True))
    if args.stats_json:
        with open(args.stats_json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
    return out


if __name__ == "__main__":
    main()
