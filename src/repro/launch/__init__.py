"""Launch layer: production meshes, abstract input specs, the multi-pod
dry-run (AOT lower+compile for every arch x shape x mesh cell), and the
train / serve CLI drivers."""
