"""Training CLI driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 100 --batch 8 --seq 128

On this CPU container ``--reduced`` is the practical path (full configs are
exercised via the dry-run); on a real cluster drop ``--reduced`` and pass
``--mesh single|multi``.  Supports checkpoint auto-resume, the in-graph NaN
guard, straggler telemetry and Krylov gradient compression (``--compress``).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, RunConfig, get_arch
from repro.configs.base import (CheckpointConfig, FsvdConfig, MeshConfig,
                                OptimConfig, RuntimeConfig, ShapeConfig)
from repro.data.synthetic import lm_batch, spec_for
from repro.launch.mesh import mesh_from_config
from repro.launch import input_specs as ispec
from repro.runtime import Trainer, build_train_step
from repro.runtime.steps import init_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/krylovlr_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true",
                    help="Krylov low-rank gradient compression (DP mesh)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    optim = OptimConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps)
    run = RunConfig(
        model=cfg, shape=shape, optim=optim,
        mesh=MeshConfig(multi_pod=args.mesh == "multi"),
        fsvd=FsvdConfig(compress_gradients=args.compress),
        checkpoint=CheckpointConfig(directory=args.ckpt_dir,
                                    every_steps=args.ckpt_every),
        runtime=RuntimeConfig(), seed=args.seed)

    mesh = None
    if args.mesh != "none":
        mesh = mesh_from_config(run.mesh)

    state = init_state(cfg, optim, jax.random.PRNGKey(args.seed))
    if mesh is not None:
        _, state_shard = ispec.state_struct_and_shardings(cfg, optim, mesh)
        state = jax.device_put(state, state_shard)
        step_fn = jax.jit(build_train_step(cfg, optim, mesh),
                          in_shardings=(state_shard, None),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(build_train_step(cfg, optim), donate_argnums=(0,))

    spec = spec_for(cfg, shape)
    trainer = Trainer(run, step_fn,
                      lambda s: lm_batch(spec, args.seed, s), state)
    trainer.maybe_resume()
    hist = trainer.run(args.steps)
    losses = [h["loss"] for h in hist]
    print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(hist)} steps, {np.mean([h['time'] for h in hist])*1e3:.0f} "
          f"ms/step)")


if __name__ == "__main__":
    main()
