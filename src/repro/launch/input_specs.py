"""Abstract input specs for the dry-run: ShapeDtypeStructs + NamedShardings
for every (arch x shape x mesh) cell — weak-type-correct, shardable, zero
device allocation.

Sharding layout (see DESIGN.md §5):
  * batch dims shard over ("pod", "data") when divisible;
  * the ``long_500k`` B=1 cells shard the *sequence* axis of KV caches over
    "data" instead (and SSM head axes over "model");
  * KV/latent caches shard kv-heads (or SSD heads) over "model" when
    divisible;
  * parameters + optimizer moments follow ``distributed.partition`` rules.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimConfig, ShapeConfig
from repro.distributed.partition import (batch_axes, logical_to_spec,
                                         param_shardings, spec_for_batch)
from repro.models import model as model_mod
from repro.optim import make_optimizer
from repro.runtime.steps import TrainState

PyTree = Any


def abstract_init(cfg: ModelConfig) -> tuple[PyTree, PyTree]:
    """(params ShapeDtypeStructs, logical axes) with zero allocation.

    ``init_model`` returns (params, logical); the logical tree is plain
    Python (tuples of strings), which ``eval_shape`` cannot return — capture
    it by side effect during the abstract trace instead.
    """
    captured = {}

    def f(key):
        params, logical = model_mod.init_model(cfg, key)
        captured["logical"] = logical
        return params

    struct = jax.eval_shape(f, jax.random.PRNGKey(0))
    return struct, captured["logical"]


def _mesh_sizes(mesh: Mesh) -> dict:
    return dict(mesh.shape)


def _batch_total(mesh: Mesh) -> int:
    sizes = _mesh_sizes(mesh)
    total = 1
    for a in batch_axes(mesh):
        total *= sizes[a]
    return total


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    sizes = _mesh_sizes(mesh)
    return axis in sizes and n % sizes[axis] == 0


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def train_batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.vlm.num_image_tokens
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vlm.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
    batch["labels"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
    return batch


def decode_batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "positions": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def batch_shardings(batch: dict, mesh: Mesh) -> dict:
    out = {}
    for k, v in batch.items():
        spec = spec_for_batch(mesh, v.shape[0], len(v.shape))
        out[k] = NamedSharding(mesh, spec)
    return out


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

_SEQ_LEAF_AXES = {
    # leaf-name -> (batch_axis, seq_axis, head_axis) measured from the END of
    # the *unstacked* shape; stacked caches add a leading layer dim that the
    # negative indexing skips automatically.
    "k": (-4, -3, -2), "v": (-4, -3, -2),               # gqa kv
    "cross_k": (-4, -3, -2), "cross_v": (-4, -3, -2),   # whisper cross
    "ckv": (-3, -2, None), "krope": (-3, -2, None),     # mla latents
}
_SSM_LEAF_AXES = {
    "h": (-4, -3), "conv": (-3, None),                  # (batch, head) axes
}


def _cache_leaf_spec(name: str, shape: tuple, mesh: Mesh, B: int) -> P:
    nd = len(shape)
    spec: list = [None] * nd
    b_shardable = B % _batch_total(mesh) == 0 and B >= _batch_total(mesh)
    if name in _SEQ_LEAF_AXES:
        b_ax, s_ax, h_ax = _SEQ_LEAF_AXES[name]
        if b_shardable:
            spec[nd + b_ax] = batch_axes(mesh)
        elif _div(shape[nd + s_ax], mesh, "data"):
            spec[nd + s_ax] = "data"
        if h_ax is not None and _div(shape[nd + h_ax], mesh, "model"):
            spec[nd + h_ax] = "model"
        elif _div(shape[nd + s_ax], mesh, "model"):
            # kv heads (or MLA latents) cannot shard over "model" — shard
            # the cache SEQUENCE axis there instead, or a 32k cache for a
            # 16-replicated-kv arch is ~90 GiB/device (> v5e HBM).  GSPMD
            # turns the attention over the seq-sharded cache into a
            # partial-softmax + small combine.
            cur = spec[nd + s_ax]
            spec[nd + s_ax] = (cur, "model") if cur else "model"
    elif name in _SSM_LEAF_AXES:
        b_ax, h_ax = _SSM_LEAF_AXES[name]
        if b_shardable:
            spec[nd + b_ax] = batch_axes(mesh)
        if h_ax is not None and _div(shape[nd + h_ax], mesh, "model"):
            spec[nd + h_ax] = "model"
    return P(*spec)


def cache_struct_and_shardings(cfg: ModelConfig, shape: ShapeConfig,
                               mesh: Mesh) -> tuple[PyTree, PyTree]:
    B, S = shape.global_batch, shape.seq_len
    struct = jax.eval_shape(
        functools.partial(model_mod.init_cache, cfg, B, S))

    def to_shard(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        return NamedSharding(mesh, _cache_leaf_spec(name, leaf.shape, mesh, B))

    flat, treedef = jax.tree_util.tree_flatten_with_path(struct)
    shardings = jax.tree_util.tree_unflatten(
        treedef, [to_shard(p, l) for p, l in flat])
    return struct, shardings


# ---------------------------------------------------------------------------
# state specs
# ---------------------------------------------------------------------------

def state_struct_and_shardings(cfg: ModelConfig, optim_cfg: OptimConfig,
                               mesh: Mesh) -> tuple[PyTree, PyTree]:
    params_struct, logical = abstract_init(cfg)
    p_shard = param_shardings(logical, params_struct, mesh)
    opt_init, _ = make_optimizer(optim_cfg)
    opt_struct = jax.eval_shape(opt_init, params_struct)
    rep = NamedSharding(mesh, P())

    def like_params(tree):
        # moments mirror params shape-for-shape -> reuse param shardings
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree),
            jax.tree_util.tree_leaves(p_shard))

    opt_shard = type(opt_struct)(
        step=rep,
        mu=like_params(opt_struct.mu),
        nu=like_params(opt_struct.nu) if opt_struct.nu is not None else None)
    state_struct = TrainState(params_struct, opt_struct)
    state_shard = TrainState(p_shard, opt_shard)
    return state_struct, state_shard


def params_struct_and_shardings(cfg: ModelConfig, mesh: Mesh
                                ) -> tuple[PyTree, PyTree]:
    params_struct, logical = abstract_init(cfg)
    return params_struct, param_shardings(logical, params_struct, mesh)


# ---------------------------------------------------------------------------
# full cell assembly
# ---------------------------------------------------------------------------

def cell_inputs(cfg: ModelConfig, shape: ShapeConfig, optim_cfg: OptimConfig,
                mesh: Mesh) -> dict:
    """Everything the dry-run needs to lower one (arch x shape) cell."""
    if shape.kind == "train":
        state_struct, state_shard = state_struct_and_shardings(
            cfg, optim_cfg, mesh)
        batch = train_batch_struct(cfg, shape)
        return {"kind": "train",
                "args_struct": (state_struct, batch),
                "in_shardings": (state_shard, batch_shardings(batch, mesh))}
    if shape.kind == "prefill":
        p_struct, p_shard = params_struct_and_shardings(cfg, mesh)
        batch = train_batch_struct(cfg, shape)
        batch.pop("labels")
        return {"kind": "prefill",
                "args_struct": (p_struct, batch),
                "in_shardings": (p_shard, batch_shardings(batch, mesh))}
    if shape.kind == "decode":
        p_struct, p_shard = params_struct_and_shardings(cfg, mesh)
        cache_struct, cache_shard = cache_struct_and_shardings(
            cfg, shape, mesh)
        batch = decode_batch_struct(cfg, shape)
        return {"kind": "decode",
                "args_struct": (p_struct, cache_struct, batch),
                "in_shardings": (p_shard, cache_shard,
                                 batch_shardings(batch, mesh))}
    raise ValueError(shape.kind)
