"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets ``xla_force_host_platform_device_count``
before first jax init; tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic runs / tests with few fake devices)."""
    return compat.make_mesh(shape, axes)


def mesh_from_config(cfg) -> Mesh:
    """RunConfig.mesh -> Mesh (production default, overridable for tests)."""
    if cfg.shape is not None:
        return make_mesh(cfg.shape, cfg.axes)
    return make_production_mesh(multi_pod=cfg.multi_pod)
