"""Serving CLI driver: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models import model as model_mod


def generate(params, cfg, prompts: jax.Array, gen: int,
             frames=None) -> jax.Array:
    """Greedy generation. prompts: (B, S) -> (B, S+gen)."""
    B, S = prompts.shape
    max_seq = S + gen
    batch = {"tokens": prompts}
    if frames is not None:
        batch["frames"] = frames
    logits, cache = model_mod.prefill_step(params, batch, cfg)
    cache = model_mod.pad_cache_to(cache, cfg, max_seq)

    decode = jax.jit(
        lambda params, cache, batch: model_mod.decode_step(
            params, cache, batch, cfg),
        donate_argnums=(1,))

    tokens = prompts
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(gen):
        tokens = jnp.concatenate([tokens, next_tok], axis=1)
        if i == gen - 1:
            break
        pos = jnp.full((B, 1), S + i, jnp.int32)
        logits, cache = decode(params, cache,
                               {"tokens": next_tok, "positions": pos})
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params, _ = model_mod.init_model(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02

    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, args.gen, frames)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"[serve] {args.arch}: generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. prefill+compile)")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
