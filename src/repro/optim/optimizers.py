"""AdamW / SGD with decoupled weight decay and global-norm clipping.

Moments are kept in f32 regardless of the (possibly bf16) parameter dtype —
the standard mixed-precision recipe.  Every state leaf mirrors its parameter
leaf's shape, so the parameter PartitionSpecs apply verbatim to the state
(FSDP: optimizer state shards with the weights).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig
from repro.optim.schedules import make_schedule

Array = jax.Array
PyTree = Any


class OptState(NamedTuple):
    step: Array                  # () int32
    mu: PyTree                   # first moment (f32) — zeros pytree for sgd
    nu: Optional[PyTree]         # second moment (f32) — None for sgd


def _f32_zeros_like(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def adamw_init(params: PyTree) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _f32_zeros_like(params),
                    _f32_zeros_like(params))


def sgd_init(params: PyTree) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _f32_zeros_like(params), None)


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def make_optimizer(cfg: OptimConfig) -> tuple[
        Callable[[PyTree], OptState],
        Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState, dict]]]:
    """Returns (init_fn, update_fn).

    ``update_fn(params, state, grads) -> (new_params, new_state, stats)``.
    """
    sched = make_schedule(cfg)

    if cfg.name == "adamw":
        def update(params, state, grads):
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            step = state.step + 1
            t = step.astype(jnp.float32)
            lr = sched(state.step)
            b1, b2 = cfg.b1, cfg.b2

            def upd(p, g, m, v):
                g32 = g.astype(jnp.float32)
                m = b1 * m + (1 - b1) * g32
                v = b2 * v + (1 - b2) * jnp.square(g32)
                mh = m / (1 - b1 ** t)
                vh = v / (1 - b2 ** t)
                delta = mh / (jnp.sqrt(vh) + cfg.eps)
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

            out = jax.tree.map(upd, params, grads, state.mu, state.nu)
            new_params = jax.tree.map(lambda o: o[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
            mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
            nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
            return new_params, OptState(step, mu, nu), \
                {"grad_norm": gnorm, "lr": lr}

        return adamw_init, update

    if cfg.name == "sgd":
        def update(params, state, grads):
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            step = state.step + 1
            lr = sched(state.step)

            def upd(p, g, m):
                g32 = g.astype(jnp.float32) \
                    + cfg.weight_decay * p.astype(jnp.float32)
                m = cfg.b1 * m + g32
                return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

            out = jax.tree.map(upd, params, grads, state.mu)
            new_params = jax.tree.map(lambda o: o[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
            mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
            return new_params, OptState(step, mu, None), \
                {"grad_norm": gnorm, "lr": lr}

        return sgd_init, update

    raise ValueError(f"unknown optimizer {cfg.name!r}")


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)
