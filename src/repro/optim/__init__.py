"""Optimizers: AdamW / SGD(+momentum) with warmup-cosine schedules and global
gradient clipping.  Self-contained (no optax dependency): states are plain
pytrees that shard exactly like the parameters they mirror.
"""
from repro.optim.optimizers import (OptState, adamw_init, apply_updates,
                                    global_norm, make_optimizer, sgd_init)
from repro.optim.schedules import make_schedule

__all__ = ["OptState", "adamw_init", "sgd_init", "apply_updates",
           "global_norm", "make_optimizer", "make_schedule"]
