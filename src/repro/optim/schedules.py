"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimConfig


def make_schedule(cfg: OptimConfig):
    """step (int32) -> lr (f32)."""
    base, warm, total = cfg.lr, cfg.warmup_steps, cfg.total_steps

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = base * (step + 1.0) / max(warm, 1)
        frac = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            rest = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            rest = base * (1.0 - frac)
        else:                       # constant
            rest = jnp.full_like(frac, base)
        return jnp.where(step < warm, warm_lr, rest)

    return sched
