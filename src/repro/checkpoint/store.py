"""Atomic, restart-safe checkpoint store.

Protocol (crash-safe at every point):
  1. write all array leaves + manifest into ``<dir>/tmp_step_N.XXXX``,
  2. fsync, then ``os.rename`` to ``<dir>/step_N``  (atomic on POSIX),
  3. GC old steps beyond ``keep``.

A checkpoint is *valid* iff its ``manifest.json`` exists and every leaf file
it lists is present with the right byte size AND the recorded CRC32 of its
bytes — half-written directories are ignored by ``latest_step`` and reaped
by GC, and a bit-flipped leaf (disk rot, torn write) is *rejected* rather
than silently restored, so a training job killed mid-write (or fed a
corrupted disk) restarts from the newest *verified* step.  Every file is
fsynced before the atomic rename: without that, a crash shortly after
``os.rename`` can surface a directory whose entries exist at full size but
whose data blocks never hit the platter — exactly the same-size truncation
``_is_valid``'s size check cannot see (the CRC can).

Fault injection: the ``checkpoint.write`` failpoint
(``repro.runtime.faults``) fires at the start of the protocol and
``corrupt``-mode specs mangle leaf bytes in flight — the chaos battery's
handle for crash-mid-write and bit-rot tests.

Reshard-on-restore: leaves are stored as host numpy arrays with their pytree
paths; ``load_checkpoint`` re-``device_put``s them under whatever sharding
the *current* mesh prescribes — restoring a 256-chip checkpoint onto 512
chips (or 8 test devices) is the same code path (elastic scaling).

Async: ``save_async`` snapshots leaves to host memory synchronously (cheap)
and runs the disk protocol on a daemon thread, overlapping I/O with the next
training steps; ``wait()`` joins before the next save or shutdown.
"""
from __future__ import annotations

import io
import json
import os
import re
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any


def _faults():
    # lazy: repro.runtime's package __init__ pulls in the trainer, which
    # imports this module back — a module-level import would cycle.
    from repro.runtime import faults
    return faults

_STEP_RE = re.compile(r"^step_(\d+)$")


def _key_name(p) -> str:
    if hasattr(p, "key"):       # DictKey
        return str(p.key)
    if hasattr(p, "name"):      # GetAttrKey (NamedTuple fields)
        return str(p.name)
    return str(p.idx)           # SequenceKey


def _flatten(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(_key_name(p) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def _leaf_bytes(arr: np.ndarray) -> bytes:
    """Serialize one leaf to .npy bytes in memory — the CRC is computed
    over exactly the bytes that hit disk, header included."""
    buf = io.BytesIO()
    np.save(buf, arr)
    return buf.getvalue()


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path.

    Every leaf carries its CRC32 in the manifest; every file (leaves and
    manifest) is fsynced, and so is the checkpoint directory around the
    atomic rename — a crash at any instant leaves either the previous
    valid step or this one, never a same-size-but-truncated hybrid.
    """
    fp = _faults()
    fp.fire(fp.CHECKPOINT_WRITE)
    os.makedirs(directory, exist_ok=True)
    leaves = _flatten(tree)
    tmp = tempfile.mkdtemp(prefix=f"tmp_step_{step}.", dir=directory)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": []}
    try:
        for i, (name, arr) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            raw = _leaf_bytes(arr)
            # the corrupt-mode failpoint mangles bytes *after* the CRC is
            # recorded — simulated bit-rot that _is_valid must catch
            crc = zlib.crc32(raw)
            raw = fp.corrupt(fp.CHECKPOINT_WRITE, raw)
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(raw)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "bytes": len(raw), "crc32": crc})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # fsync the tmp dir so its entries (names -> synced data) are
        # durable before the rename publishes them
        _fsync_dir(tmp)
        final = os.path.join(directory, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(directory)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:          # platforms without directory fds
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _is_valid(path: str, *, verify_crc: bool = True) -> bool:
    """Structural + integrity check: manifest parses, every listed leaf
    exists at the recorded size, and (when the manifest records one — old
    checkpoints predate it) the leaf bytes hash to the recorded CRC32.
    A bit-flipped leaf is as invalid as a missing one."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            fp = os.path.join(path, leaf["file"])
            if not os.path.exists(fp) or os.path.getsize(fp) != leaf["bytes"]:
                return False
            if verify_crc and "crc32" in leaf:
                with open(fp, "rb") as lf:
                    if zlib.crc32(lf.read()) != leaf["crc32"]:
                        return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def valid_steps(directory: str) -> list[int]:
    """Every step number with a *verified* checkpoint, newest first —
    restore paths walk this list so a corrupted newest step falls back to
    the most recent one that still checks out."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and _is_valid(os.path.join(directory, name)):
            steps.append(int(m.group(1)))
    return sorted(steps, reverse=True)


def latest_step(directory: str) -> Optional[int]:
    """Largest step with a *valid, checksum-verified* checkpoint, or
    None."""
    steps = valid_steps(directory)
    return steps[0] if steps else None


def load_checkpoint(directory: str, step: int, template: PyTree,
                    sharding_fn: Optional[Callable[[str], Any]] = None
                    ) -> tuple[PyTree, dict]:
    """Restore into ``template``'s pytree structure.

    ``sharding_fn(leaf_name) -> Sharding | None`` places each leaf under the
    *current* mesh (reshard-on-restore); None leaves it on the default device.
    Returns (tree, manifest_extra).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {leaf["name"]: leaf for leaf in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in flat:
        name = "/".join(_key_name(p) for p in pth)
        if name not in by_name:
            raise KeyError(f"checkpoint {path} missing leaf {name!r}")
        entry = by_name[name]
        with open(os.path.join(path, entry["file"]), "rb") as lf:
            raw = lf.read()
        if "crc32" in entry and zlib.crc32(raw) != entry["crc32"]:
            # read-time integrity: rot between the _is_valid scan and the
            # actual load (or a caller that skipped the scan) still fails
            # loudly instead of restoring garbage
            raise ValueError(
                f"checkpoint {path}: leaf {name!r} fails its CRC32 check "
                "(bit-rot or torn write); restore from an older step")
        arr = np.load(io.BytesIO(raw))
        expect = tuple(np.shape(leaf)) if leaf is not None else arr.shape
        if tuple(arr.shape) != tuple(expect):
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != {expect}")
        sh = sharding_fn(name) if sharding_fn else None
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef,
                                        [x for x in out]), manifest["extra"]


def _gc(directory: str, keep: int) -> None:
    if not os.path.isdir(directory):
        return
    valid = sorted(
        (int(m.group(1)), name)
        for name in os.listdir(directory)
        for m in [_STEP_RE.match(name)]
        if m and _is_valid(os.path.join(directory, name)))
    for _, name in valid[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    # reap stale tmp dirs (crashed writers)
    for name in os.listdir(directory):
        if name.startswith("tmp_step_"):
            full = os.path.join(directory, name)
            if time.time() - os.path.getmtime(full) > 300:
                shutil.rmtree(full, ignore_errors=True)


# ---------------------------------------------------------------------------
# Session state (repro.api.session): previous factorization + plan spec
# ---------------------------------------------------------------------------
# A Factorization flattens to exactly these children (results.py pytree
# registration order); the manifest stores them as indexed leaves, so a
# template can be rebuilt from shapes alone — restoring a session does not
# require the caller to know the factorization geometry up front.
_FACT_FIELDS = ("U", "s", "V", "iterations", "breakdown")


def save_session_state(directory: str, step: int, session,
                       keep: int = 0) -> str:
    """Atomic save of a ``repro.api.session.Session``'s tracking state.

    Array state (the previous :class:`Factorization`) goes through the
    leaf protocol; static state (plan spec, method, drift thresholds,
    history) rides in the manifest ``extra`` — the same crash-safety
    guarantees as any checkpoint.  ``keep > 0`` prunes to the newest
    ``keep`` valid session states (the tracking state only needs the
    latest, but keep-N matches the model-checkpoint retention so a
    rolled-back restore still finds a matching session).
    """
    path = save_checkpoint(directory, step, {"fact": session.fact},
                           extra={"session": session.meta()})
    if keep > 0:
        _gc(directory, keep)
    return path


def load_session_state(directory: str, step: int):
    """Load (factorization, session_meta) written by
    :func:`save_session_state`.  The factorization template is rebuilt
    from the manifest's recorded shapes/dtypes, so no geometry needs to be
    supplied; returns ``(None, meta)`` for a pre-first-solve session."""
    from repro.api.results import Factorization
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["extra"]["session"]
    if not manifest["leaves"]:
        return None, meta
    template_leaves = [
        np.zeros(leaf["shape"], dtype=leaf["dtype"])
        for leaf in manifest["leaves"]]
    if len(template_leaves) != len(_FACT_FIELDS):
        raise ValueError(
            f"session checkpoint {path} has {len(template_leaves)} leaves; "
            f"expected {len(_FACT_FIELDS)} (a Factorization)")
    template = {"fact": Factorization(*template_leaves,
                                      method=meta.get("method", "fsvd"))}
    tree, _ = load_checkpoint(directory, step, template)
    return tree["fact"], meta


class CheckpointManager:
    """Keep-N, optionally-async checkpoint writer."""

    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: PyTree,
             extra: Optional[dict] = None) -> None:
        self.wait()
        # synchronous device->host snapshot; disk I/O may be deferred
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                _gc(self.directory, self.keep)
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.wait()

    def restore_latest(self, template: PyTree,
                       sharding_fn: Optional[Callable] = None
                       ) -> Optional[tuple[int, PyTree, dict]]:
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, extra = load_checkpoint(self.directory, step, template,
                                      sharding_fn)
        return step, tree, extra
