"""Atomic, restart-safe checkpoint store.

Protocol (crash-safe at every point):
  1. write all array leaves + manifest into ``<dir>/tmp_step_N.XXXX``,
  2. fsync, then ``os.rename`` to ``<dir>/step_N``  (atomic on POSIX),
  3. GC old steps beyond ``keep``.

A checkpoint is *valid* iff its ``manifest.json`` exists and every leaf file
it lists is present with the right byte size — half-written directories are
ignored by ``latest_step`` and reaped by GC, so a training job killed
mid-write restarts from the previous valid step.

Reshard-on-restore: leaves are stored as host numpy arrays with their pytree
paths; ``load_checkpoint`` re-``device_put``s them under whatever sharding
the *current* mesh prescribes — restoring a 256-chip checkpoint onto 512
chips (or 8 test devices) is the same code path (elastic scaling).

Async: ``save_async`` snapshots leaves to host memory synchronously (cheap)
and runs the disk protocol on a daemon thread, overlapping I/O with the next
training steps; ``wait()`` joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _key_name(p) -> str:
    if hasattr(p, "key"):       # DictKey
        return str(p.key)
    if hasattr(p, "name"):      # GetAttrKey (NamedTuple fields)
        return str(p.name)
    return str(p.idx)           # SequenceKey


def _flatten(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(_key_name(p) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    leaves = _flatten(tree)
    tmp = tempfile.mkdtemp(prefix=f"tmp_step_{step}.", dir=directory)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": []}
    try:
        for i, (name, arr) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype),
                 "bytes": os.path.getsize(os.path.join(tmp, fname))})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(directory, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _is_valid(path: str) -> bool:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            fp = os.path.join(path, leaf["file"])
            if not os.path.exists(fp) or os.path.getsize(fp) != leaf["bytes"]:
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def latest_step(directory: str) -> Optional[int]:
    """Largest step with a *valid* checkpoint, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and _is_valid(os.path.join(directory, name)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template: PyTree,
                    sharding_fn: Optional[Callable[[str], Any]] = None
                    ) -> tuple[PyTree, dict]:
    """Restore into ``template``'s pytree structure.

    ``sharding_fn(leaf_name) -> Sharding | None`` places each leaf under the
    *current* mesh (reshard-on-restore); None leaves it on the default device.
    Returns (tree, manifest_extra).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {leaf["name"]: leaf for leaf in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in flat:
        name = "/".join(_key_name(p) for p in pth)
        if name not in by_name:
            raise KeyError(f"checkpoint {path} missing leaf {name!r}")
        arr = np.load(os.path.join(path, by_name[name]["file"]))
        expect = tuple(np.shape(leaf)) if leaf is not None else arr.shape
        if tuple(arr.shape) != tuple(expect):
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != {expect}")
        sh = sharding_fn(name) if sharding_fn else None
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef,
                                        [x for x in out]), manifest["extra"]


def _gc(directory: str, keep: int) -> None:
    if not os.path.isdir(directory):
        return
    valid = sorted(
        (int(m.group(1)), name)
        for name in os.listdir(directory)
        for m in [_STEP_RE.match(name)]
        if m and _is_valid(os.path.join(directory, name)))
    for _, name in valid[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    # reap stale tmp dirs (crashed writers)
    for name in os.listdir(directory):
        if name.startswith("tmp_step_"):
            full = os.path.join(directory, name)
            if time.time() - os.path.getmtime(full) > 300:
                shutil.rmtree(full, ignore_errors=True)


# ---------------------------------------------------------------------------
# Session state (repro.api.session): previous factorization + plan spec
# ---------------------------------------------------------------------------
# A Factorization flattens to exactly these children (results.py pytree
# registration order); the manifest stores them as indexed leaves, so a
# template can be rebuilt from shapes alone — restoring a session does not
# require the caller to know the factorization geometry up front.
_FACT_FIELDS = ("U", "s", "V", "iterations", "breakdown")


def save_session_state(directory: str, step: int, session,
                       keep: int = 0) -> str:
    """Atomic save of a ``repro.api.session.Session``'s tracking state.

    Array state (the previous :class:`Factorization`) goes through the
    leaf protocol; static state (plan spec, method, drift thresholds,
    history) rides in the manifest ``extra`` — the same crash-safety
    guarantees as any checkpoint.  ``keep > 0`` prunes to the newest
    ``keep`` valid session states (the tracking state only needs the
    latest, but keep-N matches the model-checkpoint retention so a
    rolled-back restore still finds a matching session).
    """
    path = save_checkpoint(directory, step, {"fact": session.fact},
                           extra={"session": session.meta()})
    if keep > 0:
        _gc(directory, keep)
    return path


def load_session_state(directory: str, step: int):
    """Load (factorization, session_meta) written by
    :func:`save_session_state`.  The factorization template is rebuilt
    from the manifest's recorded shapes/dtypes, so no geometry needs to be
    supplied; returns ``(None, meta)`` for a pre-first-solve session."""
    from repro.api.results import Factorization
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["extra"]["session"]
    if not manifest["leaves"]:
        return None, meta
    template_leaves = [
        np.zeros(leaf["shape"], dtype=leaf["dtype"])
        for leaf in manifest["leaves"]]
    if len(template_leaves) != len(_FACT_FIELDS):
        raise ValueError(
            f"session checkpoint {path} has {len(template_leaves)} leaves; "
            f"expected {len(_FACT_FIELDS)} (a Factorization)")
    template = {"fact": Factorization(*template_leaves,
                                      method=meta.get("method", "fsvd"))}
    tree, _ = load_checkpoint(directory, step, template)
    return tree["fact"], meta


class CheckpointManager:
    """Keep-N, optionally-async checkpoint writer."""

    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: PyTree,
             extra: Optional[dict] = None) -> None:
        self.wait()
        # synchronous device->host snapshot; disk I/O may be deferred
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                _gc(self.directory, self.keep)
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.wait()

    def restore_latest(self, template: PyTree,
                       sharding_fn: Optional[Callable] = None
                       ) -> Optional[tuple[int, PyTree, dict]]:
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, extra = load_checkpoint(self.directory, step, template,
                                      sharding_fn)
        return step, tree, extra
