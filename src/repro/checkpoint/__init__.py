"""Fault-tolerant checkpointing: atomic directories, keep-N GC, async
writes, reshard-on-restore (elastic mesh changes), and solver-session
state (``repro.api.session``)."""
from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    load_checkpoint, load_session_state,
                                    save_checkpoint, save_session_state,
                                    valid_steps)

__all__ = ["CheckpointManager", "latest_step", "load_checkpoint",
           "save_checkpoint", "save_session_state", "load_session_state",
           "valid_steps"]
