"""Fault-tolerant checkpointing: atomic directories, keep-N GC, async
writes, and reshard-on-restore (elastic mesh changes)."""
from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    load_checkpoint, save_checkpoint)

__all__ = ["CheckpointManager", "latest_step", "load_checkpoint",
           "save_checkpoint"]
