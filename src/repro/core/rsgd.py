"""Algorithm 4 — Riemannian mini-batch SGD for similarity learning (RSL).

Problem (paper eq. 21): learn W in M_r minimizing the mean pair loss of
``f_W(x, v) = x^T W v`` over labelled cross-domain pairs (x_i, v_i, y_i),
y in {-1, +1}.

Scale design: the mini-batch Euclidean gradient is

    Gr = (1/b) X_b^T diag(c) V_b  + wd * W,     c_i = dl/dyhat_i * ...,

i.e. rank <= b + r — it is carried as a pytree operator
(``LowRankOp`` / ``SumOp``) and *never* materialized,
so a 1e8-entry W (the paper's "huge matrix" regime) trains with O((d1+d2)
(b + r)) memory per step.  The tangent projection (Alg 4 line 8) needs Gr
only through r-column matmats, and the retraction (line 9) runs F-SVD on the
implicit rank-<=3r operator W - eta*Z.

Note on Alg 4 line 6: the paper writes ``Gr = Gr - lambda W``; for a descent
step on f + (lambda/2)||W||_F^2 the regularization gradient is ``+ lambda W``
(the paper's minus sign would make the decay term *ascend*).  We implement
the mathematically consistent ``+``; set ``weight_decay=0`` to reproduce the
unregularized runs.

Note on Alg 4 line 7/8: the paper projects Gr using the singular vectors *of
Gr itself*; the Riemannian gradient of §5.3 (eq. 27) projects with the
factors *of W*.  ``project_at="w"`` (default) implements eq. 27;
``project_at="grad"`` implements the literal Alg 4 lines 7-8.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

import repro.core.manifold as mf
from repro.api import SVDSpec, factorize
from repro.core.operators import LowRankOp, Operator

Array = jax.Array


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def hinge_loss(yhat: Array, y: Array) -> tuple[Array, Array]:
    """Returns (loss per pair, dl/dyhat per pair)."""
    margin = 1.0 - y * yhat
    loss = jnp.maximum(margin, 0.0)
    grad = jnp.where(margin > 0, -y, 0.0)
    return loss, grad


def logistic_loss(yhat: Array, y: Array) -> tuple[Array, Array]:
    z = y * yhat
    loss = jnp.logaddexp(0.0, -z)
    grad = -y * jax.nn.sigmoid(-z)
    return loss, grad


LOSSES: dict[str, Callable] = {"hinge": hinge_loss, "logistic": logistic_loss}


# ---------------------------------------------------------------------------
# batch gradient as an implicit operator
# ---------------------------------------------------------------------------

class BatchGrad(NamedTuple):
    loss: Array       # () mean batch loss (without the wd term)
    op: Operator      # implicit Euclidean gradient (d1, d2), a pytree


def batch_euclidean_grad(W: mf.FixedRankPoint, Xb: Array, Vb: Array, y: Array,
                         loss: str = "hinge", weight_decay: float = 0.0
                         ) -> BatchGrad:
    """Gr = (1/b) X_b^T diag(c) V_b + wd * W through the operator algebra.

    Xb: (b, d1), Vb: (b, d2), y: (b,) in {-1, +1}.
    ``f_W(x_i, v_i) = x_i^T W v_i`` evaluated through W's factors.  The
    data term is ``LowRankOp(Xbᵀ, c, Vb)`` (rank ≤ b); weight decay adds
    ``wd * LowRankOp(U, s, Vᵀ)`` (rank r) — the whole gradient is a pytree
    ``SumOp`` that crosses the jit boundary of the training step.
    """
    b = Xb.shape[0]
    loss_fn = LOSSES[loss]
    # yhat_i = x_i^T W v_i = (Xb U) diag(s) (V^T v_i) rowwise
    XU = Xb @ W.U                      # (b, r)
    VV = Vb @ W.V                      # (b, r)
    yhat = jnp.einsum("br,r,br->b", XU, W.s, VV)
    per_pair, dl = loss_fn(yhat, y)
    c = dl / b                         # (b,)

    op: Operator = LowRankOp(Xb.T, c, Vb)          # (d1, d2), rank <= b
    if weight_decay:
        op = op + weight_decay * LowRankOp(W.U, W.s, W.V.T)
    return BatchGrad(per_pair.mean(), op)


# ---------------------------------------------------------------------------
# the RSGD step (Alg 4 body)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RSGDOptions:
    lr: float = 1e-2
    weight_decay: float = 0.0
    loss: str = "hinge"
    fsvd_iters: int = 20          # Alg 2 inner iterations (paper: 20 / 35)
    retraction: str = "fsvd"      # fsvd (paper) | qr (closed-form baseline)
    project_at: str = "w"         # w (eq 27) | grad (literal Alg 4 line 7-8)
    reorth_passes: int = 2
    # tracking retraction: warm-start each step's F-SVD from the current
    # point's factors (the retraction operand W - eta*Z is a *drift* of W,
    # exactly the repro.api.Session situation, staged in-graph) instead of
    # a cold keyed start vector.  False = the paper's literal cold solve.
    track: bool = True


def rsgd_step(W: mf.FixedRankPoint, Xb: Array, Vb: Array, y: Array,
              opts: RSGDOptions, key: Optional[jax.Array] = None
              ) -> tuple[mf.FixedRankPoint, Array]:
    """One Alg-4 iteration. Returns (W_new, batch loss)."""
    bg = batch_euclidean_grad(W, Xb, Vb, y, opts.loss, opts.weight_decay)

    if opts.project_at == "grad":
        # literal Alg 4 lines 7-8: factor the gradient itself with F-SVD,
        # project Gr onto the tangent cone at its own top-r factors.
        r = W.rank
        g_out = factorize(
            bg.op, SVDSpec(method="fsvd", rank=r,
                           max_iters=max(opts.fsvd_iters, r + 2),
                           reorth_passes=opts.reorth_passes), key=key)
        Wg = mf.FixedRankPoint(g_out.U, g_out.s, g_out.V)
        xi = mf.project_tangent(Wg, bg.op)
        # re-express in the tangent space at W for the retraction step
        Zdense_op = mf.as_linop(Wg, xi, 1.0)     # still low-rank implicit
        xi = mf.project_tangent(W, Zdense_op)
    else:
        xi = mf.project_tangent(W, bg.op)        # eq. 27 at W

    if opts.retraction == "qr":
        W_new = mf.retract_qr(W, xi, -opts.lr)
    else:
        W_new = mf.retract_fsvd(W, xi, -opts.lr,
                                fsvd_iters=opts.fsvd_iters, key=key,
                                reorth_passes=opts.reorth_passes,
                                warm_start=opts.track)
    return W_new, bg.loss


def make_step(opts: RSGDOptions, jit: bool = True):
    """Jitted Alg-4 step: (W, Xb, Vb, y, key) -> (W_new, loss).

    ``opts`` is static (frozen dataclass); F-SVD inside uses the in-graph
    ``gk_bidiag`` (fori_loop, fixed shapes) so the whole update — gradient,
    tangent projection, Krylov retraction — is ONE compiled XLA program.
    """
    def step(W, Xb, Vb, y, key):
        return rsgd_step(W, Xb, Vb, y, opts, key=key)

    return jax.jit(step) if jit else step


def predict(W: mf.FixedRankPoint, Xb: Array, Vb: Array) -> Array:
    """yhat_i = x_i^T W v_i through the factors."""
    return jnp.einsum("br,r,br->b", Xb @ W.U, W.s, Vb @ W.V)


def accuracy(W: mf.FixedRankPoint, Xb: Array, Vb: Array, y: Array) -> Array:
    return (jnp.sign(predict(W, Xb, Vb)) == y).mean()
