"""Pytree-registered operator algebra.

The paper's algorithms touch A only through ``A @ p`` / ``A.T @ q``; the seed
expressed that as closure-based :class:`~repro.core.linop.LinOp` objects,
which work but cannot cross ``jit`` / ``vmap`` / ``shard_map`` boundaries
(closures are not pytrees).  This module replaces them with small
dataclass operators whose array fields are pytree leaves:

  * ``DenseOp(A, backend=...)``    — in-memory matrix; ``backend="pallas"``
    routes the fused Lanczos matvecs through ``repro.kernels`` (subsumes the
    old ``from_dense(use_kernels=True)`` flag).
  * ``LowRankOp(U, s, Vt, extra=..., scale=...)`` — ``scale * (U diag(s) Vt
    + Σ L_i R_i)`` never materialized (the RSL gradient / retraction
    operand).
  * ``SumOp``, ``ScaledOp``, ``TransposedOp`` — closure of the algebra under
    ``A + B``, ``alpha * A`` and ``A.T``.

Because operators are pytrees, ``jax.vmap(factorize_impl)`` over a stacked
``DenseOp`` yields a batched partial SVD with no extra code, and a sharded
operator (``repro.distributed.ShardedOp``) threads through ``jit`` whole.

All operators satisfy the same duck protocol as ``LinOp`` (``shape``,
``dtype``, ``mv``, ``rmv``, ``mv_fused``, ``rmv_fused``, ``matmat``,
``rmatmat``) so the GK / F-SVD / rank cores run unchanged on either.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_BACKENDS = ("xla", "pallas")


def register_operator(cls):
    """Register an operator dataclass as a pytree.

    ``_data_fields`` become children (traced/vmapped/sharded);
    ``_meta_fields`` become static aux data (must be hashable).  Unflatten
    bypasses no logic — constructors must stay dumb so tree transforms can
    pass placeholders.  Extensions (e.g. ``repro.distributed.ShardedOp``)
    use this too.
    """
    data = cls._data_fields
    meta = cls._meta_fields

    def flatten(op):
        return (tuple(getattr(op, f) for f in data),
                tuple(getattr(op, f) for f in meta))

    def unflatten(aux, children):
        kw = dict(zip(data, children))
        kw.update(zip(meta, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


class Operator:
    """Base class: linear-map protocol + algebra sugar.

    Subclasses define ``shape``, ``dtype``, ``mv``, ``rmv`` and may override
    the fused three-term forms, the block forms and ``T`` with cheaper
    specializations.
    """

    _data_fields: Tuple[str, ...] = ()
    _meta_fields: Tuple[str, ...] = ()

    # --- protocol -----------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        raise NotImplementedError

    @property
    def dtype(self):
        raise NotImplementedError

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def mv(self, p: Array) -> Array:
        raise NotImplementedError

    def rmv(self, q: Array) -> Array:
        raise NotImplementedError

    def mv_fused(self, p: Array, y: Array, alpha) -> Array:
        """Lanczos three-term form ``A p − alpha y``."""
        return self.mv(p) - alpha * y

    def rmv_fused(self, q: Array, y: Array, beta) -> Array:
        return self.rmv(q) - beta * y

    def matmat(self, V: Array) -> Array:
        return jax.vmap(self.mv, in_axes=1, out_axes=1)(V)

    def rmatmat(self, Q: Array) -> Array:
        return jax.vmap(self.rmv, in_axes=1, out_axes=1)(Q)

    def to_dense(self) -> Array:
        return self.matmat(jnp.eye(self.n, dtype=self.dtype))

    # --- algebra ------------------------------------------------------
    @property
    def T(self) -> "Operator":
        return TransposedOp(self)

    def __matmul__(self, x):
        if isinstance(x, Operator):
            return NotImplemented
        x = jnp.asarray(x)
        return self.mv(x) if x.ndim == 1 else self.matmat(x)

    def _check_same_shape(self, other: "Operator"):
        if tuple(self.shape) != tuple(other.shape):
            raise ValueError(
                f"operator shapes disagree: {tuple(self.shape)} + "
                f"{tuple(other.shape)}")
        return other

    def __add__(self, other):
        return SumOp((self, self._check_same_shape(as_operator(other))))

    def __radd__(self, other):
        return SumOp((self._check_same_shape(as_operator(other)), self))

    def __sub__(self, other):
        return SumOp((self, ScaledOp(
            -1.0, self._check_same_shape(as_operator(other)))))

    def __mul__(self, alpha):
        return ScaledOp(alpha, self)

    __rmul__ = __mul__

    def __neg__(self):
        return ScaledOp(-1.0, self)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class DenseOp(Operator):
    """In-memory (m, n) matrix.  ``backend="pallas"`` backs the fused
    Lanczos matvecs with the single-pass Pallas kernels (A streamed through
    VMEM once per half-iteration); ``"xla"`` composes plain GEMVs."""

    A: Array
    backend: str = "xla"

    _data_fields = ("A",)
    _meta_fields = ("backend",)

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.A.shape)

    @property
    def dtype(self):
        return self.A.dtype

    def mv(self, p):
        return self.A @ p

    def rmv(self, q):
        return self.A.T @ q

    def mv_fused(self, p, y, alpha):
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.matvec_fused(self.A, p, y, alpha)
        return self.A @ p - alpha * y

    def rmv_fused(self, q, y, beta):
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.rmatvec_fused(self.A, q, y, beta)
        return self.A.T @ q - beta * y

    def matmat(self, V):
        return self.A @ V

    def rmatmat(self, Q):
        return self.A.T @ Q

    def to_dense(self):
        return self.A

    @property
    def T(self):
        return DenseOp(self.A.T, backend=self.backend)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class LowRankOp(Operator):
    """``scale * (U diag(s) Vt + Σ_i L_i R_i)`` — never materialized.

    ``extra`` is a tuple of (L_i (m, k_i), R_i (k_i, n)) addend factor pairs;
    this expresses e.g. ``W − eta Z`` (manifold point minus tangent step) or
    the RSL batch gradient ``X_bᵀ diag(c) V_b + wd · W``.
    """

    U: Array                      # (m, r)
    s: Array                      # (r,)
    Vt: Array                     # (r, n)
    extra: Tuple[Tuple[Array, Array], ...] = ()
    scale: Any = 1.0              # python scalar or 0-d array (leaf)

    _data_fields = ("U", "s", "Vt", "extra", "scale")
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return (self.U.shape[0], self.Vt.shape[1])

    @property
    def dtype(self):
        return self.U.dtype

    def mv(self, p):
        y = self.U @ (self.s * (self.Vt @ p))
        for L, R in self.extra:
            y = y + L @ (R @ p)
        return self.scale * y

    def rmv(self, q):
        y = self.Vt.T @ (self.s * (self.U.T @ q))
        for L, R in self.extra:
            y = y + R.T @ (L.T @ q)
        return self.scale * y

    def matmat(self, V):
        y = self.U @ (self.s[:, None] * (self.Vt @ V))
        for L, R in self.extra:
            y = y + L @ (R @ V)
        return self.scale * y

    def rmatmat(self, Q):
        y = self.Vt.T @ (self.s[:, None] * (self.U.T @ Q))
        for L, R in self.extra:
            y = y + R.T @ (L.T @ Q)
        return self.scale * y

    @property
    def T(self):
        return LowRankOp(self.Vt.T, self.s, self.U.T,
                         extra=tuple((R.T, L.T) for L, R in self.extra),
                         scale=self.scale)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class SumOp(Operator):
    """A + B (+ ...): matvecs distribute over the terms."""

    terms: Tuple[Operator, ...]

    _data_fields = ("terms",)
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.terms[0].shape

    @property
    def dtype(self):
        return jnp.result_type(*(t.dtype for t in self.terms))

    def mv(self, p):
        y = self.terms[0].mv(p)
        for t in self.terms[1:]:
            y = y + t.mv(p)
        return y

    def rmv(self, q):
        y = self.terms[0].rmv(q)
        for t in self.terms[1:]:
            y = y + t.rmv(q)
        return y

    def matmat(self, V):
        y = self.terms[0].matmat(V)
        for t in self.terms[1:]:
            y = y + t.matmat(V)
        return y

    def rmatmat(self, Q):
        y = self.terms[0].rmatmat(Q)
        for t in self.terms[1:]:
            y = y + t.rmatmat(Q)
        return y

    @property
    def T(self):
        return SumOp(tuple(t.T for t in self.terms))

    def __add__(self, other):     # flatten nested sums
        other = self._check_same_shape(as_operator(other))
        more = other.terms if isinstance(other, SumOp) else (other,)
        return SumOp(self.terms + more)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class ScaledOp(Operator):
    """alpha * A (alpha a scalar leaf — may be traced)."""

    alpha: Any
    op: Operator

    _data_fields = ("alpha", "op")
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.op.shape

    @property
    def dtype(self):
        return self.op.dtype

    def mv(self, p):
        return self.alpha * self.op.mv(p)

    def rmv(self, q):
        return self.alpha * self.op.rmv(q)

    def matmat(self, V):
        return self.alpha * self.op.matmat(V)

    def rmatmat(self, Q):
        return self.alpha * self.op.rmatmat(Q)

    @property
    def T(self):
        return ScaledOp(self.alpha, self.op.T)

    def __mul__(self, a):
        return ScaledOp(a * self.alpha, self.op)

    __rmul__ = __mul__


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class TransposedOp(Operator):
    """A.T for operators without a cheaper specialized transpose."""

    inner: Operator

    _data_fields = ("inner",)
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        m, n = self.inner.shape
        return (n, m)

    @property
    def dtype(self):
        return self.inner.dtype

    def mv(self, p):
        return self.inner.rmv(p)

    def rmv(self, q):
        return self.inner.mv(q)

    def mv_fused(self, p, y, alpha):
        return self.inner.rmv_fused(p, y, alpha)

    def rmv_fused(self, q, y, beta):
        return self.inner.mv_fused(q, y, beta)

    def matmat(self, V):
        return self.inner.rmatmat(V)

    def rmatmat(self, Q):
        return self.inner.matmat(Q)

    @property
    def T(self):
        return self.inner


def as_operator(A, *, backend: str = "xla"):
    """Coerce to the operator protocol.

    Operators and legacy ``LinOp`` closures pass through (both satisfy the
    same duck protocol); raw arrays wrap into a :class:`DenseOp`.
    """
    if isinstance(A, Operator):
        return A
    if hasattr(A, "mv") and hasattr(A, "rmv"):   # LinOp & look-alikes
        return A
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    return DenseOp(jnp.asarray(A), backend=backend)


def to_dense(op) -> Array:
    """Materialize any protocol object (tests / small operands only)."""
    if isinstance(op, Operator):
        return op.to_dense()
    return op.matmat(jnp.eye(op.n, dtype=op.dtype))
