"""Pytree-registered operator algebra.

The paper's algorithms touch A only through ``A @ p`` / ``A.T @ q``; the seed
expressed that as closure-based :class:`~repro.core.linop.LinOp` objects,
which work but cannot cross ``jit`` / ``vmap`` / ``shard_map`` boundaries
(closures are not pytrees).  This module replaces them with small
dataclass operators whose array fields are pytree leaves:

  * ``DenseOp(A, backend=...)``    — in-memory matrix; ``backend="pallas"``
    routes the fused Lanczos matvecs through ``repro.kernels`` (subsumes the
    old ``from_dense(use_kernels=True)`` flag).
  * ``LowRankOp(U, s, Vt, extra=..., scale=...)`` — ``scale * (U diag(s) Vt
    + Σ L_i R_i)`` never materialized (the RSL gradient / retraction
    operand).
  * ``SumOp``, ``ScaledOp``, ``TransposedOp`` — closure of the algebra under
    ``A + B``, ``alpha * A`` and ``A.T``.
  * ``SparseOp(data, indices, spshape)`` — BCOO-backed sparse matrix;
    ``backend="pallas"`` routes matvecs through the row-blocked ELL kernel
    in ``repro.kernels.sparse_matvec`` (build via ``SparseOp.fromdense`` /
    ``SparseOp.from_bcoo`` so the ELL pack is precomputed).
  * ``KroneckerOp(a, b)`` — ``a ⊗ b`` applied through the reshape identity
    ``(A ⊗ B) vec(X) = vec(A X Bᵀ)``; the product is never materialized.
  * ``GramOp(inner, side)`` — ``AᵀA`` / ``AAᵀ`` as an operator (rank
    estimation / normal-equation solves without forming the Gram matrix).

Because operators are pytrees, ``jax.vmap(factorize_impl)`` over a stacked
``DenseOp`` yields a batched partial SVD with no extra code, and a sharded
operator (``repro.distributed.ShardedOp``) threads through ``jit`` whole.

All operators satisfy the same duck protocol as ``LinOp`` (``shape``,
``dtype``, ``mv``, ``rmv``, ``mv_fused``, ``rmv_fused``, ``matmat``,
``rmatmat``) so the GK / F-SVD / rank cores run unchanged on either.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_BACKENDS = ("xla", "pallas")


def cgs(v: Array, basis: Array, passes: int) -> Array:
    """Classical Gram-Schmidt of ``v`` against the (zero-padded) basis
    columns, ``passes`` times, with f32 accumulation.

    When the basis is stored in a narrower dtype than ``v`` (the
    mixed-precision bf16 policy), the products run with bf16 operands and
    f32 accumulation (``preferred_element_type``) — the basis is never
    upcast in memory, which is the whole point of storing it half-width.
    For matching dtypes this is exactly ``v − B (Bᵀ v)``, bit-for-bit.
    """
    if basis.dtype == v.dtype:
        for _ in range(passes):
            v = v - basis @ (basis.T @ v)
        return v
    for _ in range(passes):
        c = jnp.dot(basis.T, v.astype(basis.dtype),
                    preferred_element_type=jnp.float32)
        v = v - jnp.dot(basis, c.astype(basis.dtype),
                        preferred_element_type=jnp.float32)
    return v


def register_operator(cls):
    """Register an operator dataclass as a pytree.

    ``_data_fields`` become children (traced/vmapped/sharded);
    ``_meta_fields`` become static aux data (must be hashable).  Unflatten
    bypasses no logic — constructors must stay dumb so tree transforms can
    pass placeholders.  Extensions (e.g. ``repro.distributed.ShardedOp``)
    use this too.
    """
    data = cls._data_fields
    meta = cls._meta_fields

    def flatten(op):
        return (tuple(getattr(op, f) for f in data),
                tuple(getattr(op, f) for f in meta))

    def unflatten(aux, children):
        kw = dict(zip(data, children))
        kw.update(zip(meta, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


class Operator:
    """Base class: linear-map protocol + algebra sugar.

    Subclasses define ``shape``, ``dtype``, ``mv``, ``rmv`` and may override
    the fused three-term forms, the block forms and ``T`` with cheaper
    specializations.
    """

    _data_fields: Tuple[str, ...] = ()
    _meta_fields: Tuple[str, ...] = ()

    # Streaming hint: True means the operand can only afford ONE sweep
    # (out-of-core / streamed once) — ``resolve_method`` routes such
    # operands to the single-pass ``gnystrom`` solver.
    single_pass_only: bool = False

    # --- protocol -----------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        raise NotImplementedError

    @property
    def dtype(self):
        raise NotImplementedError

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def mv(self, p: Array) -> Array:
        raise NotImplementedError

    def rmv(self, q: Array) -> Array:
        raise NotImplementedError

    def mv_fused(self, p: Array, y: Array, alpha) -> Array:
        """Lanczos three-term form ``A p − alpha y``."""
        return self.mv(p) - alpha * y

    def rmv_fused(self, q: Array, y: Array, beta) -> Array:
        return self.rmv(q) - beta * y

    def lanczos_step(self, p: Array, y: Array, alpha, basis: Array, *,
                     passes: int = 2) -> tuple[Array, Array]:
        """One fused left GK half-step: ``u = A p − α y`` reorthogonalized
        CGS^passes against ``basis``, plus its norm → ``(u, ‖u‖)``.

        The default composes the fused matvec with :func:`cgs`; operators
        with a single-pass pipeline (``DenseOp(backend="pallas")``)
        override it with the ``kernels.gk_step`` kernels.
        """
        u = self.mv_fused(p, y, alpha)
        u = cgs(u, basis, passes)
        return u, jnp.linalg.norm(u)

    def lanczos_rstep(self, q: Array, y: Array, beta, basis: Array, *,
                      passes: int = 2) -> tuple[Array, Array]:
        """Right GK half-step: ``v = Aᵀ q − β y`` vs ``basis`` → (v, ‖v‖)."""
        v = self.rmv_fused(q, y, beta)
        v = cgs(v, basis, passes)
        return v, jnp.linalg.norm(v)

    def matmat(self, V: Array) -> Array:
        return jax.vmap(self.mv, in_axes=1, out_axes=1)(V)

    def rmatmat(self, Q: Array) -> Array:
        return jax.vmap(self.rmv, in_axes=1, out_axes=1)(Q)

    def sketch_pass(self, omega, psi) -> tuple[Array, Array]:
        """ONE sweep over the operator capturing both sketch directions:
        ``(A Ω, Aᵀ Ψ)`` for test matrices Ω (n, k) and Ψ (m, l) from
        ``repro.core.sketch`` — the single-pass seam ``gnystrom`` builds
        on (and the unit the pass-budget guards count as one touch).

        The default composes the block forms on the densified panels;
        operators with a fused path (``DenseOp(backend="pallas")`` via the
        sparse-sign sketch kernel, ``ShardedOp`` via one shard_map body
        with a single psum) override it.
        """
        return self.matmat(omega.dense()), self.rmatmat(psi.dense())

    def to_dense(self) -> Array:
        return self.matmat(jnp.eye(self.n, dtype=self.dtype))

    # --- algebra ------------------------------------------------------
    @property
    def T(self) -> "Operator":
        return TransposedOp(self)

    def __matmul__(self, x):
        if isinstance(x, Operator):
            return NotImplemented
        x = jnp.asarray(x)
        return self.mv(x) if x.ndim == 1 else self.matmat(x)

    def _check_same_shape(self, other: "Operator"):
        if tuple(self.shape) != tuple(other.shape):
            raise ValueError(
                f"operator shapes disagree: {tuple(self.shape)} + "
                f"{tuple(other.shape)}")
        return other

    def __add__(self, other):
        return SumOp((self, self._check_same_shape(as_operator(other))))

    def __radd__(self, other):
        return SumOp((self._check_same_shape(as_operator(other)), self))

    def __sub__(self, other):
        return SumOp((self, ScaledOp(
            -1.0, self._check_same_shape(as_operator(other)))))

    def __mul__(self, alpha):
        return ScaledOp(alpha, self)

    __rmul__ = __mul__

    def __neg__(self):
        return ScaledOp(-1.0, self)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class DenseOp(Operator):
    """In-memory (m, n) matrix.  ``backend="pallas"`` backs the fused
    Lanczos matvecs with the single-pass Pallas kernels (A streamed through
    VMEM once per half-iteration); ``"xla"`` composes plain GEMVs."""

    A: Array
    backend: str = "xla"

    _data_fields = ("A",)
    _meta_fields = ("backend",)

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.A.shape)

    @property
    def dtype(self):
        return self.A.dtype

    def mv(self, p):
        return self.A @ p

    def rmv(self, q):
        return self.A.T @ q

    def mv_fused(self, p, y, alpha):
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.matvec_fused(self.A, p, y, alpha)
        return self.A @ p - alpha * y

    def rmv_fused(self, q, y, beta):
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.rmatvec_fused(self.A, q, y, beta)
        return self.A.T @ q - beta * y

    def lanczos_step(self, p, y, alpha, basis, *, passes=2):
        if self.backend == "pallas" and self.A.dtype != jnp.float64:
            from repro.kernels import ops as kops
            return kops.gk_step_fused(self.A, p, y, alpha, basis, passes)
        return Operator.lanczos_step(self, p, y, alpha, basis,
                                     passes=passes)

    def lanczos_rstep(self, q, y, beta, basis, *, passes=2):
        if self.backend == "pallas" and self.A.dtype != jnp.float64:
            from repro.kernels import ops as kops
            return kops.gk_rstep_fused(self.A, q, y, beta, basis, passes)
        return Operator.lanczos_rstep(self, q, y, beta, basis,
                                      passes=passes)

    def matmat(self, V):
        return self.A @ V

    def rmatmat(self, Q):
        return self.A.T @ Q

    def sketch_pass(self, omega, psi):
        if self.backend == "pallas":
            # both directions through the gather-only sketch kernel:
            # (A Ω)ᵀ = Ωᵀ Aᵀ and (Aᵀ Ψ)ᵀ = Ψᵀ A are each one Tᵀ X apply.
            return omega.tapply(self.A.T).T, psi.tapply(self.A).T
        return self.A @ omega.dense(), self.A.T @ psi.dense()

    def to_dense(self):
        return self.A

    @property
    def T(self):
        return DenseOp(self.A.T, backend=self.backend)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class LowRankOp(Operator):
    """``scale * (U diag(s) Vt + Σ_i L_i R_i)`` — never materialized.

    ``extra`` is a tuple of (L_i (m, k_i), R_i (k_i, n)) addend factor pairs;
    this expresses e.g. ``W − eta Z`` (manifold point minus tangent step) or
    the RSL batch gradient ``X_bᵀ diag(c) V_b + wd · W``.
    """

    U: Array                      # (m, r)
    s: Array                      # (r,)
    Vt: Array                     # (r, n)
    extra: Tuple[Tuple[Array, Array], ...] = ()
    scale: Any = 1.0              # python scalar or 0-d array (leaf)

    _data_fields = ("U", "s", "Vt", "extra", "scale")
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return (self.U.shape[0], self.Vt.shape[1])

    @property
    def dtype(self):
        return self.U.dtype

    def mv(self, p):
        y = self.U @ (self.s * (self.Vt @ p))
        for L, R in self.extra:
            y = y + L @ (R @ p)
        return self.scale * y

    def rmv(self, q):
        y = self.Vt.T @ (self.s * (self.U.T @ q))
        for L, R in self.extra:
            y = y + R.T @ (L.T @ q)
        return self.scale * y

    def matmat(self, V):
        y = self.U @ (self.s[:, None] * (self.Vt @ V))
        for L, R in self.extra:
            y = y + L @ (R @ V)
        return self.scale * y

    def rmatmat(self, Q):
        y = self.Vt.T @ (self.s[:, None] * (self.U.T @ Q))
        for L, R in self.extra:
            y = y + R.T @ (L.T @ Q)
        return self.scale * y

    @property
    def T(self):
        return LowRankOp(self.Vt.T, self.s, self.U.T,
                         extra=tuple((R.T, L.T) for L, R in self.extra),
                         scale=self.scale)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class SumOp(Operator):
    """A + B (+ ...): matvecs distribute over the terms."""

    terms: Tuple[Operator, ...]

    _data_fields = ("terms",)
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.terms[0].shape

    @property
    def dtype(self):
        return jnp.result_type(*(t.dtype for t in self.terms))

    def mv(self, p):
        y = self.terms[0].mv(p)
        for t in self.terms[1:]:
            y = y + t.mv(p)
        return y

    def rmv(self, q):
        y = self.terms[0].rmv(q)
        for t in self.terms[1:]:
            y = y + t.rmv(q)
        return y

    def matmat(self, V):
        y = self.terms[0].matmat(V)
        for t in self.terms[1:]:
            y = y + t.matmat(V)
        return y

    def rmatmat(self, Q):
        y = self.terms[0].rmatmat(Q)
        for t in self.terms[1:]:
            y = y + t.rmatmat(Q)
        return y

    @property
    def T(self):
        return SumOp(tuple(t.T for t in self.terms))

    def __add__(self, other):     # flatten nested sums
        other = self._check_same_shape(as_operator(other))
        more = other.terms if isinstance(other, SumOp) else (other,)
        return SumOp(self.terms + more)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class ScaledOp(Operator):
    """alpha * A (alpha a scalar leaf — may be traced)."""

    alpha: Any
    op: Operator

    _data_fields = ("alpha", "op")
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.op.shape

    @property
    def dtype(self):
        return self.op.dtype

    def mv(self, p):
        return self.alpha * self.op.mv(p)

    def rmv(self, q):
        return self.alpha * self.op.rmv(q)

    def matmat(self, V):
        return self.alpha * self.op.matmat(V)

    def rmatmat(self, Q):
        return self.alpha * self.op.rmatmat(Q)

    @property
    def T(self):
        return ScaledOp(self.alpha, self.op.T)

    def __mul__(self, a):
        return ScaledOp(a * self.alpha, self.op)

    __rmul__ = __mul__


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class TransposedOp(Operator):
    """A.T for operators without a cheaper specialized transpose."""

    inner: Operator

    _data_fields = ("inner",)
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        m, n = self.inner.shape
        return (n, m)

    @property
    def dtype(self):
        return self.inner.dtype

    def mv(self, p):
        return self.inner.rmv(p)

    def rmv(self, q):
        return self.inner.mv(q)

    def mv_fused(self, p, y, alpha):
        return self.inner.rmv_fused(p, y, alpha)

    def rmv_fused(self, q, y, beta):
        return self.inner.mv_fused(q, y, beta)

    def lanczos_step(self, p, y, alpha, basis, *, passes=2):
        # Aᵀ's left half-step is A's right half-step: inherit the inner
        # operator's fused pipeline (Pallas tiles, sharded stacked-psum)
        # instead of falling back to the generic matvec + CGS composition.
        return self.inner.lanczos_rstep(p, y, alpha, basis, passes=passes)

    def lanczos_rstep(self, q, y, beta, basis, *, passes=2):
        return self.inner.lanczos_step(q, y, beta, basis, passes=passes)

    def matmat(self, V):
        return self.inner.rmatmat(V)

    def rmatmat(self, Q):
        return self.inner.matmat(Q)

    @property
    def T(self):
        return self.inner


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class SparseOp(Operator):
    """Sparse (m, n) matrix in COO triplet form — never densified on the
    solver path (the GK / F-SVD / rank cores only ever ask for matvecs).

    ``data`` (nnz,) and ``indices`` (nnz, 2) follow the BCOO convention
    (duplicate coordinates sum); ``spshape`` is static so the operator
    survives tracing (a traced ``indices`` cannot carry the shape).

    ``backend="pallas"`` routes matvecs through the row-blocked ELL kernel
    (``repro.kernels.sparse_matvec``); the ELL pack is precomputed from
    concrete coordinates by :meth:`fromdense` / :meth:`from_bcoo` /
    :meth:`from_coo` (its row widths are value-dependent, so it cannot be
    built under a trace) and rides along as pytree leaves.  ``backend="xla"``
    uses BCOO dot-general.
    """

    data: Array                   # (nnz,)
    indices: Array                # (nnz, 2) int — [row, col]
    spshape: Tuple[int, int] = (0, 0)
    ell: Any = None               # ((m,L) vals, (m,L) cols, (n,L') vals,
                                  #  (n,L') rows) — pallas pack, or None
    backend: str = "xla"

    _data_fields = ("data", "indices", "ell")
    _meta_fields = ("spshape", "backend")

    # --- constructors -------------------------------------------------
    @classmethod
    def fromdense(cls, A, *, backend: str = "xla", nse=None) -> "SparseOp":
        from jax.experimental import sparse as jsparse
        return cls.from_bcoo(jsparse.BCOO.fromdense(jnp.asarray(A), nse=nse),
                             backend=backend)

    @classmethod
    def from_bcoo(cls, mat, *, backend: str = "xla") -> "SparseOp":
        return cls.from_coo(mat.data, mat.indices, tuple(mat.shape),
                            backend=backend)

    @classmethod
    def from_coo(cls, data, indices, spshape, *,
                 backend: str = "xla") -> "SparseOp":
        if backend not in _BACKENDS:
            raise ValueError(
                f"backend must be one of {_BACKENDS}, got {backend!r}")
        data = jnp.asarray(data)
        indices = jnp.asarray(indices)
        ell = None
        if backend == "pallas":
            from repro.kernels.sparse_matvec import ell_pack
            ell = (ell_pack(data, indices, spshape)
                   + ell_pack(data, indices[:, ::-1], spshape[::-1]))
        return cls(data, indices, tuple(spshape), ell=ell, backend=backend)

    # --- protocol -----------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.spshape)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    @property
    def density(self) -> float:
        m, n = self.spshape
        return self.nnz / max(m * n, 1)

    def _bcoo(self):
        from jax.experimental import sparse as jsparse
        return jsparse.BCOO((self.data, self.indices), shape=self.spshape)

    def mv(self, p):
        if self.backend == "pallas" and self.ell is not None:
            from repro.kernels import ops as kops
            return kops.sparse_matvec(self.ell[0], self.ell[1], p)
        return self._bcoo() @ p

    def rmv(self, q):
        if self.backend == "pallas" and self.ell is not None:
            from repro.kernels import ops as kops
            return kops.sparse_matvec(self.ell[2], self.ell[3], q)
        return self.T._bcoo() @ q

    def matmat(self, V):
        if self.backend == "pallas" and self.ell is not None:
            return Operator.matmat(self, V)    # vmap over the ELL kernel
        return self._bcoo() @ V

    def rmatmat(self, Q):
        if self.backend == "pallas" and self.ell is not None:
            return Operator.rmatmat(self, Q)
        return self.T._bcoo() @ Q

    def to_dense(self):
        return self._bcoo().todense()

    @property
    def T(self):
        ell = None if self.ell is None else \
            (self.ell[2], self.ell[3], self.ell[0], self.ell[1])
        return SparseOp(self.data, self.indices[:, ::-1],
                        (self.spshape[1], self.spshape[0]),
                        ell=ell, backend=self.backend)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class KroneckerOp(Operator):
    """``a ⊗ b`` — shape (m_a m_b, n_a n_b), never materialized.

    Matvecs use the reshape identity ``(A ⊗ B) vec(X) = vec(A X Bᵀ)`` (vec
    row-major, matching ``jnp.kron`` index order ``[i·m_b + k, j·n_b + l]``),
    so cost is two small GEMMs instead of one huge GEMV.  Factors are
    operators themselves — ``KroneckerOp(SparseOp(...), DenseOp(...))``
    composes.
    """

    a: Operator
    b: Operator

    _data_fields = ("a", "b")
    _meta_fields = ()

    @property
    def shape(self) -> tuple[int, int]:
        (ma, na), (mb, nb) = self.a.shape, self.b.shape
        return (ma * mb, na * nb)

    @property
    def dtype(self):
        return jnp.result_type(self.a.dtype, self.b.dtype)

    def mv(self, x):
        (ma, na), (mb, nb) = self.a.shape, self.b.shape
        X = x.reshape(na, nb)
        AX = self.a.matmat(X)                # (ma, nb)
        Y = self.b.matmat(AX.T).T            # (ma, mb): rows i are B @ AX[i]
        return Y.reshape(ma * mb)

    def rmv(self, y):
        (ma, na), (mb, nb) = self.a.shape, self.b.shape
        Y = y.reshape(ma, mb)
        AY = self.a.rmatmat(Y)               # (na, mb)
        X = self.b.rmatmat(AY.T).T           # (na, nb)
        return X.reshape(na * nb)

    def to_dense(self):
        return jnp.kron(self.a.to_dense(), self.b.to_dense())

    @property
    def T(self):
        return KroneckerOp(self.a.T, self.b.T)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class SinglePassOp(Operator):
    """Marks an operand as affordable to sweep only ONCE (streamed from
    disk / network, or simply too large to touch twice) — pure forwarding
    otherwise.  ``resolve_method`` sees ``single_pass_only`` and routes to
    the ``gnystrom`` solver, whose whole contract is one ``sketch_pass``.
    """

    inner: Operator

    _data_fields = ("inner",)
    _meta_fields = ()

    single_pass_only = True

    @property
    def shape(self) -> tuple[int, int]:
        return self.inner.shape

    @property
    def dtype(self):
        return self.inner.dtype

    def mv(self, p):
        return self.inner.mv(p)

    def rmv(self, q):
        return self.inner.rmv(q)

    def matmat(self, V):
        return self.inner.matmat(V)

    def rmatmat(self, Q):
        return self.inner.rmatmat(Q)

    def sketch_pass(self, omega, psi):
        return self.inner.sketch_pass(omega, psi)

    def to_dense(self):
        return self.inner.to_dense()

    @property
    def T(self):
        return SinglePassOp(self.inner.T)


_GRAM_SIDES = ("ata", "aat")


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class GramOp(Operator):
    """``AᵀA`` (side="ata", n×n) or ``AAᵀ`` (side="aat", m×m) of ``inner``,
    applied as two matvecs — the Gram matrix itself is never formed.

    Symmetric by construction (``T`` is ``self``); its eigenvalues are
    ``σ(A)²``, which is what rank estimation on the normal equations needs.
    """

    inner: Operator
    side: str = "ata"

    _data_fields = ("inner",)
    _meta_fields = ("side",)

    @property
    def shape(self) -> tuple[int, int]:
        if self.side not in _GRAM_SIDES:
            raise ValueError(
                f"side must be one of {_GRAM_SIDES}, got {self.side!r}")
        d = self.inner.shape[1] if self.side == "ata" else self.inner.shape[0]
        return (d, d)

    @property
    def dtype(self):
        return self.inner.dtype

    def mv(self, p):
        if self.side == "ata":
            return self.inner.rmv(self.inner.mv(p))
        return self.inner.mv(self.inner.rmv(p))

    rmv = mv

    def matmat(self, V):
        if self.side == "ata":
            return self.inner.rmatmat(self.inner.matmat(V))
        return self.inner.matmat(self.inner.rmatmat(V))

    rmatmat = matmat

    @property
    def T(self):
        return self


def as_operator(A, *, backend: str = "xla"):
    """Coerce to the operator protocol.

    Operators and legacy ``LinOp`` closures pass through (both satisfy the
    same duck protocol); BCOO sparse matrices wrap into a :class:`SparseOp`;
    raw arrays wrap into a :class:`DenseOp`.
    """
    if isinstance(A, Operator):
        return A
    if hasattr(A, "mv") and hasattr(A, "rmv"):   # LinOp & look-alikes
        return A
    from jax.experimental import sparse as jsparse
    if isinstance(A, jsparse.BCOO):
        return SparseOp.from_bcoo(A, backend=backend)
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    return DenseOp(jnp.asarray(A), backend=backend)


def to_dense(op) -> Array:
    """Materialize any protocol object (tests / small operands only)."""
    if isinstance(op, Operator):
        return op.to_dense()
    return op.matmat(jnp.eye(op.n, dtype=op.dtype))


def sharding_mesh(op):
    """The mesh a (possibly wrapped) operator is sharded over, or None.

    Structural duck check — ``repro.distributed.ShardedOp`` exposes a
    ``sharding_mesh`` property; wrapper operators are walked through the
    ``_data_fields`` every Operator already declares, so any future
    wrapper participates without registering here.  Lives in core (not
    ``repro.distributed``) so solvers can pick distributed code paths
    without an import cycle.
    """
    from jax.sharding import Mesh
    mesh = getattr(op, "sharding_mesh", None)
    if isinstance(mesh, Mesh):
        return mesh
    if not isinstance(op, Operator):
        return None
    stack = [getattr(op, f, None) for f in op._data_fields]
    while stack:
        x = stack.pop()
        if isinstance(x, Operator):
            mesh = sharding_mesh(x)
            if mesh is not None:
                return mesh
        elif isinstance(x, (tuple, list)):
            stack.extend(x)
    return None
