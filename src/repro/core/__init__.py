"""Core numerics: the paper's contribution.

gk        — Algorithm 1 (GK bidiagonalization + breakdown rank detection)
fsvd      — Algorithm 2 (accurate & fast partial SVD)
rank      — Algorithm 3 (numerical rank determination)
rsvd      — Halko randomized-SVD baseline
manifold  — fixed-rank Riemannian geometry (eqs. 24-27)
rsgd      — Algorithm 4 (Riemannian mini-batch SGD for similarity learning)
linop     — matvec-closure operator abstraction
tridiag   — B^T B assembly + eigh
"""
from repro.core.fsvd import FSVDResult, fsvd
from repro.core.gk import GKResult, gk_bidiag, gk_bidiag_host
from repro.core.linop import LinOp, from_dense, from_factors
from repro.core.rank import RankResult, numerical_rank
from repro.core.rsvd import RSVDResult, rsvd

__all__ = [
    "FSVDResult", "fsvd", "GKResult", "gk_bidiag", "gk_bidiag_host",
    "LinOp", "from_dense", "from_factors", "RankResult", "numerical_rank",
    "RSVDResult", "rsvd",
]
