"""Core numerics: the paper's contribution.

gk        — Algorithm 1 (GK bidiagonalization + breakdown rank detection)
fsvd      — Algorithm 2 (accurate & fast partial SVD)
rank      — Algorithm 3 (numerical rank determination)
rsvd      — Halko randomized-SVD baseline
manifold  — fixed-rank Riemannian geometry (eqs. 24-27)
rsgd      — Algorithm 4 (Riemannian mini-batch SGD for similarity learning)
operators — pytree operator algebra (DenseOp, LowRankOp, SumOp, ...)
linop     — legacy matvec-closure operator abstraction (deprecated)
tridiag   — B^T B assembly + eigh

The per-solver entry points below (``fsvd``, ``rsvd``, ``numerical_rank``)
are kept as deprecated shims; new code should go through the
``repro.api`` facade (``factorize`` / ``estimate_rank`` + ``SVDSpec``).
"""
import functools
import warnings

from repro.core.fsvd import FSVDResult, fsvd as _fsvd_impl
from repro.core.gk import GKResult, gk_bidiag, gk_bidiag_host
from repro.core.linop import LinOp, from_dense, from_factors
from repro.core.gk_block import (BlockedFSVDResult, fsvd_block, fsvd_blocked,
                                 gk_block_host)
from repro.core.operators import (DenseOp, GramOp, KroneckerOp, LowRankOp,
                                  Operator, ScaledOp, SparseOp, SumOp,
                                  TransposedOp, as_operator,
                                  register_operator)
from repro.core.rank import RankResult, numerical_rank as _rank_impl
from repro.core.rsvd import RSVDResult, rsvd as _rsvd_impl


def _deprecated(fn, replacement: str):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        from repro.compat import ReproDeprecationWarning
        warnings.warn(
            f"repro.core.{fn.__name__}(...) is a deprecated entry point; "
            f"use {replacement} (repro.api).", ReproDeprecationWarning,
            stacklevel=2)
        return fn(*args, **kwargs)
    return wrapper


fsvd = _deprecated(_fsvd_impl, "factorize(A, SVDSpec(method='fsvd', ...))")
rsvd = _deprecated(_rsvd_impl, "factorize(A, SVDSpec(method='rsvd', ...))")
numerical_rank = _deprecated(_rank_impl, "estimate_rank(A, SVDSpec(...))")

__all__ = [
    "FSVDResult", "fsvd", "GKResult", "gk_bidiag", "gk_bidiag_host",
    "LinOp", "from_dense", "from_factors", "RankResult", "numerical_rank",
    "RSVDResult", "rsvd",
    "BlockedFSVDResult", "fsvd_block", "fsvd_blocked", "gk_block_host",
    "Operator", "DenseOp", "LowRankOp", "SumOp", "ScaledOp", "TransposedOp",
    "SparseOp", "KroneckerOp", "GramOp",
    "as_operator", "register_operator",
]
