"""Rank-k update / downdate of an existing factorization — zero Krylov
iterations.

The tracking and serving stacks (``Session``, ``repro.serve``) follow
operators that drift *structurally*: ``A' = beta * A + Delta`` with
``Delta`` low-rank (a momentum-style state update, a batch of new rows, a
rank-1 similarity edit).  A refine solve still runs a (reduced) GK
recurrence over the full operator; but when the drift itself is rank-k,
the drifted factorization is computable *exactly* from the previous one
(Brand's SVD update; Halko–Martinsson–Tropp / Tropp–Webber in PAPERS.md)
with no matvecs against ``A'`` at all:

    A' = beta * U diag(s) Vt + C Dt          (C: (m, k), D: (n, k))

  1. project the delta factors onto/off the current bases:
     ``UtC = Ut C``, ``Qc Rc = qr((I − U Ut) C)`` (CGS-reorthogonalized),
     and symmetrically for D against V;
  2. assemble the small dense (r+k, r+k) core
     ``K = beta * diag(s ⊕ 0) + [UtC; Rc] [VtD; Rd]^T``;
  3. SVD the core and rotate the augmented bases
     ``U' = [U | Qc] Uk``, ``V' = [V | Qd] Vk``; truncate back to r.

Cost is ``O((m + n)(r + k)^2)`` — independent of the GK iteration count
and of ``min(m, n)`` beyond the thin-QR — which is why the update path is
the serving stack's biggest latency lever (``benchmarks/update_bench.py``).
The result is *exact* when the previous factorization captured the
operator exactly (rank-r operand); for noisy operands the unabsorbed tail
shows up in the residual, which is what ``Session``'s update gate
measures.

Downdating removes rows or columns: zeroing rows ``S`` of the *factored*
operator is itself a rank-|S| update ``Delta = −1_S (U[S] diag(s) Vt)``
derived from the factorization alone, so the same core routine serves
both directions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.results import Factorization
from repro.core.operators import LowRankOp, cgs

Array = jax.Array


def delta_rank(delta: LowRankOp) -> int:
    """Total factored rank of a ``LowRankOp`` (main triplet + extras)."""
    k = delta.s.shape[0]
    for L, _ in delta.extra:
        k += L.shape[1]
    return k


def delta_factors(delta: LowRankOp, compute=jnp.float32
                  ) -> tuple[Array, Array]:
    """``(C, D)`` with ``Delta = C @ D.T`` — the op's ``scale`` and ``s``
    fold into C so D stays orthonormal-friendly."""
    Cs = [delta.U.astype(compute) * delta.s.astype(compute)[None, :]]
    Ds = [delta.Vt.T.astype(compute)]
    for L, R in delta.extra:
        Cs.append(L.astype(compute))
        Ds.append(R.T.astype(compute))
    C = Cs[0] if len(Cs) == 1 else jnp.concatenate(Cs, axis=1)
    D = Ds[0] if len(Ds) == 1 else jnp.concatenate(Ds, axis=1)
    return delta.scale * C, D


def _core_outer(Chat: Array, Dhat: Array, backend: str) -> Array:
    """``Chat @ Dhat.T`` for the (r+k, r+k) dense core.  On the Pallas
    backend this reuses the low-rank materialization kernel (a single
    (r+k, k) x (k, r+k) tile); XLA composes a plain GEMM."""
    if backend == "pallas":
        from repro.kernels.lowrank_update import lowrank_matmul
        rk = Chat.shape[0]
        ones = jnp.ones((Chat.shape[1],), Chat.dtype)
        return lowrank_matmul(Chat, ones, Dhat.T, bm=rk, bn=rk)
    return Chat @ Dhat.T


def update_factorization(fact: Factorization, delta: LowRankOp, *,
                         beta=1.0, rank: Optional[int] = None,
                         passes: int = 2,
                         backend: str = "xla") -> Factorization:
    """Factorization of ``beta * (U diag(s) Vt) + delta`` — no GK.

    ``rank=None`` keeps the previous rank (the tracking contract); any
    ``rank <= fact.rank + delta_rank(delta)`` is valid.  ``beta`` may be a
    traced scalar, so one staged executable covers every decay factor.
    The returned ``Factorization`` has ``iterations == 0`` and
    ``method == "update"``.
    """
    compute = jnp.promote_types(fact.U.dtype, jnp.float32)
    U = fact.U.astype(compute)
    V = fact.V.astype(compute)
    s = fact.s.astype(compute)
    C, D = delta_factors(delta, compute)
    r = s.shape[0]
    k = C.shape[1]
    if rank is None:
        rank = r
    rank = min(int(rank), r + k)

    # split each delta factor into its component in the current basis and
    # an orthonormal complement (CGS^passes keeps the complement clean
    # even when the delta nearly lies in the tracked subspace).
    UtC = U.T @ C
    Qc, Rc = jnp.linalg.qr(cgs(C, U, passes))
    VtD = V.T @ D
    Qd, Rd = jnp.linalg.qr(cgs(D, V, passes))

    Chat = jnp.concatenate([UtC, Rc], axis=0)          # (r+k, k)
    Dhat = jnp.concatenate([VtD, Rd], axis=0)          # (r+k, k)
    pad = jnp.zeros((k,), compute)
    K = beta * jnp.diag(jnp.concatenate([s, pad])) \
        + _core_outer(Chat, Dhat, backend)
    Uk, sk, Vkt = jnp.linalg.svd(K.astype(compute), full_matrices=False)

    U2 = jnp.concatenate([U, Qc], axis=1) @ Uk[:, :rank]
    V2 = jnp.concatenate([V, Qd], axis=1) @ Vkt[:rank, :].T
    return Factorization(U2.astype(fact.U.dtype),
                         sk[:rank].astype(fact.s.dtype),
                         V2.astype(fact.V.dtype),
                         iterations=jnp.zeros((), jnp.int32),
                         breakdown=jnp.zeros((), bool),
                         method="update")


# ---------------------------------------------------------------------------
# downdates: row / column removal as self-derived low-rank deltas
# ---------------------------------------------------------------------------

def row_removal_delta(fact: Factorization, rows) -> LowRankOp:
    """The rank-|rows| delta that zeroes ``rows`` of the factored
    operator: ``Delta = −1_rows (U[rows] diag(s) Vt)``."""
    compute = jnp.promote_types(fact.U.dtype, jnp.float32)
    rows = jnp.asarray(rows, jnp.int32)
    m = fact.U.shape[0]
    C = -jax.nn.one_hot(rows, m, dtype=compute).T             # (m, j)
    Vt = (fact.U[rows, :].astype(compute)
          * fact.s.astype(compute)[None, :]) @ fact.V.T.astype(compute)
    return LowRankOp(C, jnp.ones((rows.shape[0],), compute), Vt)


def col_removal_delta(fact: Factorization, cols) -> LowRankOp:
    """The rank-|cols| delta that zeroes ``cols`` of the factored
    operator: ``Delta = −(U diag(s) Vt e_cols) e_cols^T``."""
    compute = jnp.promote_types(fact.U.dtype, jnp.float32)
    cols = jnp.asarray(cols, jnp.int32)
    n = fact.V.shape[0]
    U = -(fact.U.astype(compute)
          * fact.s.astype(compute)[None, :]) @ fact.V[cols, :].T.astype(
              compute)                                         # (m, j)
    Vt = jax.nn.one_hot(cols, n, dtype=compute)                # (j, n)
    return LowRankOp(U, jnp.ones((cols.shape[0],), compute), Vt)


def downdate_rows(fact: Factorization, rows, *, passes: int = 2,
                  backend: str = "xla") -> Factorization:
    """Factorization of the operator with ``rows`` removed (zeroed).
    Exact when ``fact`` is: removing rows cannot raise the rank, so the
    truncation back to r loses nothing."""
    return update_factorization(fact, row_removal_delta(fact, rows),
                                passes=passes, backend=backend)


def downdate_cols(fact: Factorization, cols, *, passes: int = 2,
                  backend: str = "xla") -> Factorization:
    """Factorization of the operator with ``cols`` removed (zeroed)."""
    return update_factorization(fact, col_removal_delta(fact, cols),
                                passes=passes, backend=backend)


def materialize_lowrank(delta: LowRankOp, *, backend: str = "xla",
                        dtype=None) -> Array:
    """Densify a ``LowRankOp`` (for folding a drift into a dense operand).

    The Pallas backend routes the main triplet through the
    output-stationary materialization kernel when the shape tiles evenly;
    extras and the scale compose on top.
    """
    from repro.kernels.lowrank_update import materialize as _kmat
    m, n = delta.shape
    if backend == "pallas":
        W = _kmat(delta.U, delta.s, delta.Vt)
    else:
        W = (delta.U * delta.s[None, :]) @ delta.Vt
    for L, R in delta.extra:
        W = W + L @ R
    W = delta.scale * W
    return W if dtype is None else W.astype(dtype)


__all__ = ["col_removal_delta", "delta_factors", "delta_rank",
           "downdate_cols", "downdate_rows", "materialize_lowrank",
           "row_removal_delta", "update_factorization"]
