"""Linear-operator abstraction.

The whole point of the paper's Krylov approach is that it only touches the
input matrix through ``A @ p`` and ``A.T @ q``.  Representing A as a pair of
matvec closures lets the same GK / F-SVD code run on:

  * dense in-memory matrices (benchmarks, tests),
  * implicitly-factored matrices (the RSL driver's 1e8-entry W = U S V^T
    minus a step of rank-<=2r tangent direction — never materialized),
  * pod-sharded matrices (``repro.distributed.matvec``) where each matvec is a
    local GEMV + a psum over one mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LinOp:
    """A (m x n) linear operator given by matvec closures.

    ``mv(p)``  : (n,) -> (m,)   computes  A @ p
    ``rmv(q)`` : (m,) -> (n,)   computes  A.T @ q

    ``mv_fused(p, y, a)`` / ``rmv_fused(q, y, b)`` compute the Lanczos
    three-term forms ``A p − a y`` / ``Aᵀ q − b y``; the defaults compose
    the plain matvec, the Pallas-backed dense operator overrides them with
    single-pass kernels (A streamed through VMEM exactly once).
    """

    shape: tuple[int, int]
    mv: Callable[[Array], Array]
    rmv: Callable[[Array], Array]
    dtype: jnp.dtype = jnp.float32
    _mv_fused: Optional[Callable] = None
    _rmv_fused: Optional[Callable] = None

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def mv_fused(self, p: Array, y: Array, alpha) -> Array:
        if self._mv_fused is not None:
            return self._mv_fused(p, y, alpha)
        return self.mv(p) - alpha * y

    def rmv_fused(self, q: Array, y: Array, beta) -> Array:
        if self._rmv_fused is not None:
            return self._rmv_fused(q, y, beta)
        return self.rmv(q) - beta * y

    def matmat(self, V: Array) -> Array:
        """A @ V for a block of column vectors, via vmap over columns."""
        return jax.vmap(self.mv, in_axes=1, out_axes=1)(V)

    def rmatmat(self, Q: Array) -> Array:
        return jax.vmap(self.rmv, in_axes=1, out_axes=1)(Q)


def from_dense(A: Array, use_kernels: bool = False):
    """Deprecated: use ``repro.core.operators.DenseOp`` (or pass the raw
    array straight to the solvers / ``repro.api.factorize``).

    ``use_kernels=True`` maps to ``DenseOp(..., backend="pallas")``.
    """
    import warnings

    from repro.compat import ReproDeprecationWarning
    from repro.core.operators import DenseOp
    warnings.warn(
        "from_dense() is deprecated; construct repro.core.operators.DenseOp"
        "(A, backend='pallas'|'xla') instead (operators are pytrees and "
        "cross jit/vmap boundaries).", ReproDeprecationWarning, stacklevel=2)
    return DenseOp(jnp.asarray(A),
                   backend="pallas" if use_kernels else "xla")


def from_factors(U: Array, s: Array, Vt: Array,
                 extra: Optional[list[tuple[Array, Array]]] = None,
                 scale: float | Array = 1.0):
    """Deprecated: use ``repro.core.operators.LowRankOp``.

    Operator  scale * (U @ diag(s) @ Vt  +  sum_i  L_i @ R_i)  where
    ``extra`` is a list of (L_i (m,k_i), R_i (k_i,n)) low-rank addends.
    """
    import warnings

    from repro.compat import ReproDeprecationWarning
    from repro.core.operators import LowRankOp
    warnings.warn(
        "from_factors() is deprecated; construct repro.core.operators."
        "LowRankOp(U, s, Vt, extra=..., scale=...) instead.",
        ReproDeprecationWarning, stacklevel=2)
    return LowRankOp(jnp.asarray(U), jnp.asarray(s), jnp.asarray(Vt),
                     extra=tuple(extra or ()), scale=scale)


def to_dense(op) -> Array:
    """Materialize (tests only).  Works for LinOp and Operator alike."""
    eye = jnp.eye(op.n, dtype=op.dtype)
    return op.matmat(eye)
