"""Assembly and eigendecomposition of T = B_{k+1,k}^T B_{k+1,k}.

B is lower-bidiagonal (eq. 9), so T is symmetric tridiagonal:

    T[i, i]   = alpha_{i+1}^2 + beta_{i+2}^2
    T[i, i+1] = alpha_{i+2} * beta_{i+2}

(with ``alphas[i] = alpha_{i+1}``, ``betas[i] = beta_{i+2}`` as stored by
``gk.GKResult``).  k' <= a few hundred, so a dense eigh on the k' x k' matrix
is negligible next to the O(mnk') Lanczos work — the paper's complexity
argument (Section 3.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def btb_tridiagonal(alphas: Array, betas: Array) -> Array:
    """Dense (k, k) assembly of the tridiagonal B^T B from the GK scalars."""
    diag = alphas**2 + betas**2
    off = alphas[1:] * betas[:-1]
    return jnp.diag(diag) + jnp.diag(off, 1) + jnp.diag(off, -1)


def btb_eigh(alphas: Array, betas: Array, kprime: Array | int | None = None
             ) -> tuple[Array, Array]:
    """Eigendecomposition of B^T B, eigenvalues DESCENDING.

    Columns of the eigenvector matrix beyond ``kprime`` correspond to the
    zero-masked part of the buffers; their eigenvalues are pushed to -inf so
    any top-r selection skips them.
    """
    T = btb_tridiagonal(alphas, betas)
    theta, G = jnp.linalg.eigh(T)              # ascending
    theta = theta[::-1]
    G = G[:, ::-1]
    if kprime is not None:
        k = alphas.shape[0]
        valid = jnp.arange(k) < kprime
        # eigenvalues of the zero-padded block are (numerically) ~0; mask them
        # out explicitly so selection logic never picks a padding Ritz pair.
        theta = jnp.where(valid, theta, -jnp.inf)
    return theta, G
