"""Block Golub-Kahan bidiagonalization (beyond-paper TPU adaptation).

The paper's Alg 1 advances one Lanczos vector per pass over A: arithmetic
intensity ~1 FLOP/byte — hopeless against a 197 TFLOP/s MXU behind
819 GB/s of HBM.  The block variant advances ``b`` vectors per pass:

    A P_j   : (m, n) @ (n, b)   — intensity ~b FLOP/byte
    Aᵀ Q_j  : same on the way back

so b = 128-256 turns the GK loop from bandwidth-bound GEMV streaming into
MXU-shaped GEMM streaming (the Pallas matvec kernels in ``repro.kernels``
then apply with the vector dimension widened to b).  The projected matrix
is block-bidiagonal; its small dense SVD gives Ritz triplets exactly as in
Alg 2.  Convergence per *iteration* is faster than vector Lanczos (each
step captures a b-dimensional Krylov slab) at the cost of b× more flops
per step — on TPU those flops are nearly free, which is the whole trade.

Used as an alternative backend for F-SVD (``fsvd_block``) and validated
against dense SVD + the vector path in ``tests/test_gk_block.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core._keys import resolve_key
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator

Array = jax.Array


class BlockGKResult(NamedTuple):
    Q: Array          # (m, (s+1) b) left basis
    P: Array          # (n, s b) right basis
    K: Array          # ((s+1) b, s b) projected block-bidiagonal Qᵀ A P
    steps: int        # completed block steps s
    breakdown: bool


def _reorth(W: Array, basis: Array, passes: int) -> Array:
    for _ in range(passes):
        W = W - basis @ (basis.T @ W)
    return W


def gk_block_host(
    op: Operator | LinOp | Array,
    block: int,
    steps: int,
    *,
    key: Optional[jax.Array] = None,
    eps: float = 1e-6,
    reorth_passes: int = 2,
) -> BlockGKResult:
    """Host-loop block bidiagonalization with full block reorthogonalization.

    Recurrences (block analogue of paper eq. 7-8):
        P_1 A_1ᵀ            = QR(Aᵀ Q_1)
        Q_{j+1} B_{j+1}     = QR(A P_j − Q_j A_j)
        P_{j+1} A_{j+1}ᵀ    = QR(Aᵀ Q_{j+1} − P_j B_{j+1}ᵀ)
    K = Qᵀ A P is block-bidiagonal with diagonal blocks A_j and subdiagonal
    blocks B_{j+1}.
    """
    op = as_operator(op)
    m, n = op.shape
    b = min(block, m, n)
    steps = min(steps, max(min(m, n) // b, 1))
    key = resolve_key(key, caller="gk_block_host")

    Q1, _ = jnp.linalg.qr(jax.random.normal(key, (m, b), jnp.float32))
    Z = op.rmatmat(Q1).astype(jnp.float32)               # (n, b)
    P1, A1t = jnp.linalg.qr(Z)
    Qs, Ps = [Q1], [P1]
    Adiag = [A1t.T]                                      # A_1 (b, b)
    Bsub: list[Array] = []
    Qmat, Pmat = Q1, P1
    scale = float(jnp.linalg.norm(A1t)) + 1e-30
    breakdown = False

    for j in range(1, steps):
        W = op.matmat(Ps[-1]).astype(jnp.float32) - Qs[-1] @ Adiag[-1]
        W = _reorth(W, Qmat, reorth_passes)
        Qj, Bj = jnp.linalg.qr(W)
        if float(jnp.linalg.norm(Bj)) < eps * scale:
            breakdown = True
            break
        Z = op.rmatmat(Qj).astype(jnp.float32) - Ps[-1] @ Bj.T
        Z = _reorth(Z, Pmat, reorth_passes)
        Pj, Ajt = jnp.linalg.qr(Z)
        if float(jnp.linalg.norm(Ajt)) < eps * scale:
            Qs.append(Qj)
            Bsub.append(Bj)
            Qmat = jnp.concatenate([Qmat, Qj], axis=1)
            breakdown = True
            break
        Qs.append(Qj)
        Ps.append(Pj)
        Adiag.append(Ajt.T)
        Bsub.append(Bj)
        Qmat = jnp.concatenate([Qmat, Qj], axis=1)
        Pmat = jnp.concatenate([Pmat, Pj], axis=1)

    s = len(Ps)
    K = jnp.zeros((Qmat.shape[1], Pmat.shape[1]), jnp.float32)
    for j in range(s):
        K = K.at[j * b:(j + 1) * b, j * b:(j + 1) * b].set(Adiag[j])
    for j, Bj in enumerate(Bsub[:Qmat.shape[1] // b - 1]):
        K = K.at[(j + 1) * b:(j + 2) * b, j * b:(j + 1) * b].set(Bj)
    return BlockGKResult(Qmat, Pmat, K, s, breakdown)


class FSVDBlockResult(NamedTuple):
    U: Array
    s: Array
    V: Array
    steps: int
    breakdown: bool


def fsvd_block(
    A: Operator | LinOp | Array,
    r: int,
    *,
    block: Optional[int] = None,
    steps: Optional[int] = None,
    key: Optional[jax.Array] = None,
    reorth_passes: int = 2,
) -> FSVDBlockResult:
    """Top-r singular triplets via block GK (Alg 2 with a block backend).

    ``block`` defaults to an MXU-friendly width ≥ r; ``steps`` to enough
    slab captures for the top-r Ritz values to converge.
    """
    A = as_operator(A)
    m, n = A.shape
    if block is None:
        block = min(max(r, 32), min(m, n))
    if steps is None:
        steps = max(min(min(m, n) // block, max(2, 3 * r // block + 2)), 1)
    res = gk_block_host(A, block, steps, key=key,
                        reorth_passes=reorth_passes)
    Uk, sk, Vkt = jnp.linalg.svd(res.K, full_matrices=False)
    r = min(r, sk.shape[0])
    U = res.Q @ Uk[:, :r]
    V = res.P @ Vkt[:r].T
    return FSVDBlockResult(U, sk[:r], V, res.steps, res.breakdown)
