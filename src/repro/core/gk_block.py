"""Block Golub-Kahan bidiagonalization (beyond-paper TPU adaptation).

The paper's Alg 1 advances one Lanczos vector per pass over A: arithmetic
intensity ~1 FLOP/byte — hopeless against a 197 TFLOP/s MXU behind
819 GB/s of HBM.  The block variant advances ``b`` vectors per pass:

    A P_j   : (m, n) @ (n, b)   — intensity ~b FLOP/byte
    Aᵀ Q_j  : same on the way back

so b = 128-256 turns the GK loop from bandwidth-bound GEMV streaming into
MXU-shaped GEMM streaming (the Pallas matvec kernels in ``repro.kernels``
then apply with the vector dimension widened to b).  The projected matrix
is block-bidiagonal; its small dense SVD gives Ritz triplets exactly as in
Alg 2.  Convergence per *iteration* is faster than vector Lanczos (each
step captures a b-dimensional Krylov slab) at the cost of b× more flops
per step — on TPU those flops are nearly free, which is the whole trade.

Used as an alternative backend for F-SVD (``fsvd_block``) and validated
against dense SVD + the vector path in ``tests/test_gk_block.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._keys import resolve_key
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator, sharding_mesh

Array = jax.Array


class BlockGKResult(NamedTuple):
    Q: Array          # (m, (s+1) b) left basis
    P: Array          # (n, s b) right basis
    K: Array          # ((s+1) b, s b) projected block-bidiagonal Qᵀ A P
    steps: int        # completed block steps s
    breakdown: bool


def _reorth(W: Array, basis: Array, passes: int) -> Array:
    for _ in range(passes):
        W = W - basis @ (basis.T @ W)
    return W


def gk_block_host(
    op: Operator | LinOp | Array,
    block: int,
    steps: int,
    *,
    key: Optional[jax.Array] = None,
    eps: float = 1e-6,
    reorth_passes: int = 2,
) -> BlockGKResult:
    """Host-loop block bidiagonalization with full block reorthogonalization.

    Recurrences (block analogue of paper eq. 7-8):
        P_1 A_1ᵀ            = QR(Aᵀ Q_1)
        Q_{j+1} B_{j+1}     = QR(A P_j − Q_j A_j)
        P_{j+1} A_{j+1}ᵀ    = QR(Aᵀ Q_{j+1} − P_j B_{j+1}ᵀ)
    K = Qᵀ A P is block-bidiagonal with diagonal blocks A_j and subdiagonal
    blocks B_{j+1}.
    """
    op = as_operator(op)
    m, n = op.shape
    b = min(block, m, n)
    steps = min(steps, max(min(m, n) // b, 1))
    key = resolve_key(key, caller="gk_block_host")

    Q1, _ = jnp.linalg.qr(jax.random.normal(key, (m, b), jnp.float32))
    Z = op.rmatmat(Q1).astype(jnp.float32)               # (n, b)
    P1, A1t = jnp.linalg.qr(Z)
    Qs, Ps = [Q1], [P1]
    Adiag = [A1t.T]                                      # A_1 (b, b)
    Bsub: list[Array] = []
    Qmat, Pmat = Q1, P1
    scale = float(jnp.linalg.norm(A1t)) + 1e-30
    breakdown = False

    for j in range(1, steps):
        W = op.matmat(Ps[-1]).astype(jnp.float32) - Qs[-1] @ Adiag[-1]
        W = _reorth(W, Qmat, reorth_passes)
        Qj, Bj = jnp.linalg.qr(W)
        if float(jnp.linalg.norm(Bj)) < eps * scale:
            breakdown = True
            break
        Z = op.rmatmat(Qj).astype(jnp.float32) - Ps[-1] @ Bj.T
        Z = _reorth(Z, Pmat, reorth_passes)
        Pj, Ajt = jnp.linalg.qr(Z)
        if float(jnp.linalg.norm(Ajt)) < eps * scale:
            Qs.append(Qj)
            Bsub.append(Bj)
            Qmat = jnp.concatenate([Qmat, Qj], axis=1)
            breakdown = True
            break
        Qs.append(Qj)
        Ps.append(Pj)
        Adiag.append(Ajt.T)
        Bsub.append(Bj)
        Qmat = jnp.concatenate([Qmat, Qj], axis=1)
        Pmat = jnp.concatenate([Pmat, Pj], axis=1)

    s = len(Ps)
    K = jnp.zeros((Qmat.shape[1], Pmat.shape[1]), jnp.float32)
    for j in range(s):
        K = K.at[j * b:(j + 1) * b, j * b:(j + 1) * b].set(Adiag[j])
    for j, Bj in enumerate(Bsub[:Qmat.shape[1] // b - 1]):
        K = K.at[(j + 1) * b:(j + 2) * b, j * b:(j + 1) * b].set(Bj)
    return BlockGKResult(Qmat, Pmat, K, s, breakdown)


class FSVDBlockResult(NamedTuple):
    U: Array
    s: Array
    V: Array
    steps: int
    breakdown: bool


def fsvd_block(
    A: Operator | LinOp | Array,
    r: int,
    *,
    block: Optional[int] = None,
    steps: Optional[int] = None,
    key: Optional[jax.Array] = None,
    reorth_passes: int = 2,
) -> FSVDBlockResult:
    """Top-r singular triplets via block GK (Alg 2 with a block backend).

    ``block`` defaults to an MXU-friendly width ≥ r; ``steps`` to enough
    slab captures for the top-r Ritz values to converge.
    """
    A = as_operator(A)
    m, n = A.shape
    if block is None:
        block = min(max(r, 32), min(m, n))
    if steps is None:
        steps = max(min(min(m, n) // block, max(2, 3 * r // block + 2)), 1)
    res = gk_block_host(A, block, steps, key=key,
                        reorth_passes=reorth_passes)
    Uk, sk, Vkt = jnp.linalg.svd(res.K, full_matrices=False)
    r = min(r, sk.shape[0])
    U = res.Q @ Uk[:, :r]
    V = res.P @ Vkt[:r].T
    return FSVDBlockResult(U, sk[:r], V, res.steps, res.breakdown)


# ---------------------------------------------------------------------------
# Streaming blocked GK with locking + thick restart (memory-budgeted)
# ---------------------------------------------------------------------------

class BlockedFSVDResult(NamedTuple):
    U: Array          # (m, r)
    s: Array          # (r,)    descending
    V: Array          # (n, r)
    restarts: int     # restart cycles consumed
    block_passes: int # streaming passes over A (block matvec round trips)
    converged: bool   # did r Ritz pairs lock before the restart budget?


def _orth_against(W: Array, bases, passes: int) -> Array:
    for _ in range(passes):
        for B in bases:
            if B.shape[1]:
                W = W - B @ (B.T @ W)
    return W


# a column whose norm drops by this factor under orthogonalization carries
# no new direction (f32 CGS2 noise floor), only roundoff — keeping it (or
# letting Householder QR substitute an arbitrary completion, which is NOT
# orthogonal to the deflation spaces) destroys basis orthonormality and
# with it the Ritz-value bound sigma_ritz <= sigma_max.
_MGS_DROP = 1e-5


def _mgs_block(W: Array, bases, passes: int = 2,
               drop: float = _MGS_DROP) -> Array:
    """Rank-revealing block orthonormalization (host-side MGS).

    Orthonormalizes W's columns against every basis in ``bases`` and each
    other, *dropping* columns that lose all their mass instead of
    completing them arbitrarily.  Returns (n, k≤W.cols) in f32; k == 0
    means W carried no direction outside the spans.  ``drop`` is the
    survival threshold — callers with narrow-storage (bf16) bases raise it
    to that storage's orthogonalization noise floor, since a spanned
    column can retain ~eps_bf16 of its mass against a rounded basis.
    """
    live = [B for B in bases if B.shape[1]]
    compute = jnp.promote_types(W.dtype, jnp.float32)
    cols: list[Array] = []
    for j in range(W.shape[1]):
        v = W[:, j].astype(compute)
        nv0 = float(jnp.linalg.norm(v))
        if nv0 == 0.0:
            continue
        for _ in range(passes):
            for B in live:
                v = v - B @ (B.T @ v)
            for c in cols:
                v = v - c * jnp.vdot(c, v)
        nv = float(jnp.linalg.norm(v))
        if nv > drop * nv0:
            cols.append(v / nv)
    if not cols:
        return jnp.zeros((W.shape[0], 0), compute)
    return jnp.stack(cols, axis=1)


def _block_project(W: Array, bases, passes: int) -> Array:
    """``W − Σ B (Bᵀ W)``, ``passes`` times — blocked CGS against every
    basis with f32 accumulation (narrow-storage bases stay narrow)."""
    for _ in range(passes):
        for B in bases:
            if B.shape[1]:
                C = jnp.dot(B.T, W.astype(B.dtype),
                            preferred_element_type=jnp.float32) \
                    if B.dtype != W.dtype else B.T @ W
                W = W - (jnp.dot(B, C.astype(B.dtype),
                                 preferred_element_type=jnp.float32)
                         if B.dtype != W.dtype else B @ C)
    return W


# the Gram route resolves column mass only down to ~sqrt(eps) of the block
# scale (eigenvalues of WᵀW carry eps·λ_max absolute noise), so its drop
# floor sits at the CholQR/eigQR limit rather than the per-column MGS one.
_GRAM_DROP = 4e-4


def _mgs_block_gram(W: Array, bases, passes: int = 2,
                    drop: float = _MGS_DROP) -> Array:
    """Distributed drop-in for :func:`_mgs_block`: blocked projection plus
    rank-revealing orthonormalization via the psum'd Gram matrix.

    The per-column host MGS syncs a scalar per column per block — fine on
    one device, a mesh-wide stall at scale.  Here every reduction is a
    *block* contraction (``BᵀW``, ``WᵀW``): on sharded operands GSPMD
    lowers each to one local GEMM + one psum.  Rank revelation comes from
    ``eigh(WᵀW)``: directions with ``sqrt(λ) ≤ drop · max‖w_j‖`` carry no
    direction outside the spans (Gram-resolution noise) and are dropped,
    never completed arbitrarily — the same contract as ``_mgs_block``.  A
    second project+eigh pass restores orthogonality to working precision
    (single-pass eigQR degrades as cond², the CholQR2 fix).
    """
    compute = jnp.promote_types(W.dtype, jnp.float32)
    W = W.astype(compute)
    live = [B for B in bases if B.shape[1]]
    eff_drop = max(drop, _GRAM_DROP)
    for _ in range(2):                      # project + eigQR, twice
        if W.shape[1] == 0:
            return jnp.zeros((W.shape[0], 0), compute)
        # the drop threshold is relative to THIS pass's input columns
        # (matching _mgs_block's post-vs-pre column-norm test); the second
        # pass sees unit columns, so a stale first-pass scale would
        # spuriously drop everything whenever the raw block is large.
        scale = float(jnp.max(jnp.linalg.norm(W, axis=0)))
        W = _block_project(W, live, passes)
        G = W.T @ W
        lam, E = jnp.linalg.eigh(G)         # ascending
        lam = np.asarray(jnp.sqrt(jnp.clip(lam, 0.0, None)))
        keep = np.nonzero(lam > eff_drop * max(scale, 1e-30))[0]
        if keep.size == 0:
            return jnp.zeros((W.shape[0], 0), compute)
        W = (W @ E[:, keep]) / jnp.asarray(lam[keep], compute)[None, :]
    return W


def _gram_rayleigh_ritz(AV: Array, basis: Array
                        ) -> tuple[Array, Array, Array]:
    """Ritz triplets of span(basis) from the psum'd (d, d) Gram matrix.

    ``svd(AV)`` on a row-sharded (m, d) block would gather the tall factor
    to one device; instead ``H = (AV)ᵀAV`` reduces to a replicated d×d
    problem (one local GEMM + one psum under GSPMD), ``eigh(H)`` runs
    replicated, and the big factors stay sharded: ``U = AV W Σ⁻¹`` is a
    local GEMM on the row shards.  Returns (U, s, V) with s descending.
    """
    H = AV.T @ AV                                       # (d, d) replicated
    theta, W = jnp.linalg.eigh(H)                       # ascending
    theta = theta[::-1]
    W = W[:, ::-1]
    s = jnp.sqrt(jnp.clip(theta, 0.0, None))
    U = (AV @ W) / jnp.where(s > 0, s, 1.0)[None, :]
    V = basis.astype(jnp.float32) @ W
    return U, s, V


def fsvd_blocked(
    A: Operator | LinOp | Array,
    r: int,
    *,
    block: Optional[int] = None,
    max_basis: Optional[int] = None,
    tol: float = 1e-8,
    relative_tol: bool = True,
    max_restarts: int = 40,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    reorth_passes: int = 2,
    dtype=None,
    precision: Optional[str] = None,
    callback=None,
) -> BlockedFSVDResult:
    """Top-r singular triplets by streaming block GK under a memory budget.

    The basis never exceeds ``max_basis`` right vectors: each cycle expands
    a block-Krylov chain ``P_{j+1} = orth(Aᵀ(A P_j))`` (the GK alternation,
    fused — only n-vectors are retained), Rayleigh–Ritz extracts candidate
    triplets from the accumulated span, pairs whose residual
    ``‖Aᵀu − σv‖ ≤ tol·σ_max`` are *locked* (deflated from all later
    cycles), and the basis restarts *thick* — re-seeded with the best
    unconverged Ritz vectors, so no Krylov information is thrown away.

    This is the Musco–Musco block-Krylov scheme with LOBPCG-style soft
    locking; A is touched only through block matvecs, so operators whose
    dense form would not fit memory (``SparseOp``, ``KroneckerOp``, pod-
    sharded) stream through unchanged.  Sharded operands additionally get
    the distributed stages: the block expansion runs row-sharded, the
    orthonormalization is blocked MGS via psum'd Gram matrices (no
    per-column device syncs), and Rayleigh-Ritz runs replicated on the
    small projected Gram problem — the (m, ·) factors never gather.

    ``relative_tol=True`` (default) scales the residual threshold by the
    running ``σ_max`` estimate with ``tol`` clamped to the dtype's Lanczos
    noise floor (same policy as ``core.gk``) — the paper's 1e-8 default
    remains meaningful in f64 and degrades gracefully to ~2e-5 in f32;
    ``relative_tol=False`` uses ``tol`` as an absolute residual bound.
    ``q1`` (an m-vector) warm-starts the first block via ``Aᵀq1``.
    ``precision="bf16"`` stores the retained bases (the memory-budgeted
    part) half-width; every expansion, orthogonalization and Rayleigh-Ritz
    extraction still accumulates in the compute dtype, and the locking
    threshold / MGS drop floor widen to the storage's noise floor.
    ``callback`` (``repro.api.callbacks.ConvergenceCallback``) gets
    ``on_step(cycle, residual=..., locked=...)`` per restart cycle — host
    scalars this loop computes anyway — and a final ``on_info`` whose
    residual trace is the per-cycle minimum Ritz residual.
    """
    from repro.core.gk import _store_dtype
    A = as_operator(A)
    # sharded operands swap the two dense-friendly stages for distributed
    # forms: per-column host MGS -> blocked psum'd-Gram orthonormalization
    # (no per-column device syncs), and svd(AV) -> replicated Rayleigh-Ritz
    # on the small projected Gram problem (the (m, d) factor stays
    # row-sharded end to end).
    distributed = sharding_mesh(A) is not None
    orth_block = _mgs_block_gram if distributed else _mgs_block
    m, n = A.shape
    r = min(r, min(m, n))
    b = block if block is not None else min(max(8, min(r, 32)), min(m, n))
    b = max(min(b, min(m, n)), 1)
    if max_basis is None:
        max_basis = min(min(m, n), max(3 * r, r + 2 * b))
    max_basis = min(max(max_basis, r + b, 2 * b), min(m, n))
    if dtype is None:
        dtype = jnp.promote_types(A.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)
    store_eps = float(jnp.finfo(store).eps)
    mgs_drop = max(_MGS_DROP, 8.0 * store_eps)
    eff_tol = max(tol, 200.0 * float(jnp.finfo(dtype).eps), 8.0 * store_eps)

    if q1 is None:
        key = resolve_key(key, caller="fsvd_blocked")
    else:
        key = key if key is not None else jax.random.PRNGKey(0)

    locked_V = jnp.zeros((n, 0), store)
    locked_U = jnp.zeros((m, 0), store)
    locked_s: list[float] = []

    key, k0 = jax.random.split(key)
    V = jax.random.normal(k0, (n, b), dtype)
    if q1 is not None:
        V = V.at[:, 0].set(A.rmv(q1.astype(dtype)))
    V = jnp.linalg.qr(V)[0]

    block_passes = 0
    restarts = 0
    converged = False
    sigma_max = 0.0
    cycle_res: list[float] = []             # per-cycle min Ritz residual
    Us = S = Vr = None                      # last Rayleigh-Ritz extraction

    for restart in range(max_restarts):
        restarts = restart + 1
        # --- expand the Krylov chain under the basis budget --------------
        # the seed block is capped one short of the budget so at least one
        # A(ᵀ)A application always fits: with zero applications the span
        # never grows and restarts would stagnate on the same subspace.
        budget = max_basis - locked_V.shape[1]
        if budget >= 2:
            V = V[:, :min(V.shape[1], budget - 1)]
        else:
            V = V[:, :max(budget, 1)]
        basis = orth_block(V, (locked_V,), reorth_passes,
                           drop=mgs_drop).astype(store)
        if basis.shape[1] == 0:
            key, kf = jax.random.split(key)
            basis = orth_block(jax.random.normal(kf, (n, min(b, budget)),
                                                 dtype),
                               (locked_V,), reorth_passes,
                               drop=mgs_drop).astype(store)
        last = basis
        while basis.shape[1] < budget and last.shape[1]:
            W = A.rmatmat(A.matmat(last)).astype(dtype)   # GK round trip
            block_passes += 1
            Qb = orth_block(W, (locked_V, basis), reorth_passes,
                            drop=mgs_drop)
            if Qb.shape[1] == 0:
                # chain exhausted the reachable subspace — refresh randomly
                key, kf = jax.random.split(key)
                Qb = orth_block(
                    jax.random.normal(kf, (n, last.shape[1]), dtype),
                    (locked_V, basis), reorth_passes, drop=mgs_drop)
                if Qb.shape[1] == 0:
                    break                     # whole space is spanned
            Qb = Qb[:, :budget - basis.shape[1]].astype(store)
            basis = jnp.concatenate([basis, Qb], axis=1)
            last = Qb
        # --- Rayleigh-Ritz on span(basis), deflated against locked -------
        AV = A.matmat(basis).astype(dtype)                # (m, d), d ≤ budget
        block_passes += 1
        if distributed:
            Us, S, Vr = _gram_rayleigh_ritz(AV, basis)
        else:
            Us, S, Wt = jnp.linalg.svd(AV, full_matrices=False)
            Vr = basis @ Wt.T
        sigma_max = max(sigma_max,
                        float(S[0]) if S.shape[0] else 0.0,
                        locked_s[0] if locked_s else 0.0)
        # residuals ‖Aᵀu_i − σ_i v_i‖ decide locking
        Rres = A.rmatmat(Us).astype(dtype) - Vr * S[None, :]
        resn = jnp.linalg.norm(Rres, axis=0)
        thresh = eff_tol * max(sigma_max, 1.0) if relative_tol else tol
        need = r - len(locked_s)
        lock_idx = []
        for i in range(S.shape[0]):
            if len(lock_idx) >= need:
                break
            if float(resn[i]) <= thresh:
                lock_idx.append(i)
            else:
                break          # lock a contiguous head: keeps order strict
        if lock_idx:
            sel = jnp.asarray(lock_idx)
            newV = _orth_against(Vr[:, sel], (locked_V,), 1)
            newV = newV / jnp.linalg.norm(newV, axis=0, keepdims=True)
            locked_V = jnp.concatenate([locked_V, newV.astype(store)],
                                       axis=1)
            locked_U = jnp.concatenate(
                [locked_U, Us[:, sel].astype(store)], axis=1)
            locked_s.extend(float(S[i]) for i in lock_idx)
        cycle_res.append(float(jnp.min(resn)) if S.shape[0] else 0.0)
        if callback is not None:
            callback.on_step(restart, residual=cycle_res[-1],
                             locked=len(locked_s))
        if len(locked_s) >= r:
            converged = True
            break
        # --- thick restart: best unconverged Ritz vectors seed the next
        # cycle (orthonormalized against the locked pairs at loop top) ---
        rest = [i for i in range(S.shape[0]) if i not in set(lock_idx)]
        keep = rest[:max(b, min(r - len(locked_s), len(rest)))]
        if keep:
            V = Vr[:, jnp.asarray(keep)]
        else:
            key, kf = jax.random.split(key)
            V = jax.random.normal(kf, (n, b), dtype)

    # --- assemble: locked pairs first, fill from the last extraction -----
    if len(locked_s) < r and S is not None:
        fill = r - len(locked_s)
        # take the best remaining Ritz pairs not yet locked
        taken = 0
        cols_u, cols_v, vals = [], [], []
        for i in range(S.shape[0]):
            if taken >= fill:
                break
            v_i = Vr[:, i]
            if locked_V.shape[1] and float(
                    jnp.max(jnp.abs(locked_V.T @ v_i))) > 0.5:
                continue       # this Ritz pair is (a copy of) a locked one
            cols_u.append(Us[:, i])
            cols_v.append(v_i)
            vals.append(float(S[i]))
            taken += 1
        if cols_u:
            locked_U = jnp.concatenate(
                [locked_U, jnp.stack(cols_u, axis=1).astype(store)], axis=1)
            locked_V = jnp.concatenate(
                [locked_V, jnp.stack(cols_v, axis=1).astype(store)], axis=1)
            locked_s.extend(vals)

    s_arr = jnp.asarray(locked_s, dtype)
    order = jnp.argsort(-s_arr)
    U = locked_U[:, order]
    V_out = locked_V[:, order]
    s_arr = s_arr[order]
    if s_arr.shape[0] < r:                      # exhausted rank-deficient A
        pad = r - s_arr.shape[0]
        U = jnp.concatenate([U, jnp.zeros((m, pad), store)], axis=1)
        V_out = jnp.concatenate([V_out, jnp.zeros((n, pad), store)], axis=1)
        s_arr = jnp.concatenate([s_arr, jnp.zeros((pad,), dtype)])
    if callback is not None:
        from repro.api.callbacks import ConvergenceInfo
        callback.on_info(ConvergenceInfo(
            jnp.asarray(cycle_res, jnp.float32),
            jnp.asarray(block_passes, jnp.int32),
            jnp.asarray(not converged), method="fsvd_blocked"))
    return BlockedFSVDResult(U[:, :r], s_arr[:r], V_out[:, :r],
                             restarts, block_passes, converged)
