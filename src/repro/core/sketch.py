"""Sketch-to-SVD solvers: randomized block Krylov and generalized Nyström.

Two points on the accuracy/pass-count frontier the GK family (paper Alg 2)
does not cover:

  * :func:`rbk` — Musco & Musco's randomized **block Krylov**: start from a
    sketched block, expand ``q`` passes of ``Aᵀ(A ·)``, Rayleigh–Ritz
    extract.  Gap-independent accuracy guarantees per pass where plain
    power-iterated R-SVD degrades on clustered spectra; ``q`` interpolates
    between one-shot sketching and the full Krylov accuracy of F-SVD.
  * :func:`gnystrom` — Halko–Martinsson–Tropp / Tropp–Webber's
    **generalized Nyström**: two independent sketches ``AΩ`` / ``ΨᵀA``
    captured in ONE sweep over the operator (the ``Operator.sketch_pass``
    seam), core solve via a stabilized pseudo-inverse.  The only solver in
    the registry that can factorize an operand it may touch exactly once
    (streaming / out-of-core — ``Operator.single_pass_only``).

Both are fully in-graph (jit / vmap-safe — no host round-trips), so they
stage through ``SolverPlan`` and batch through ``solve_batched`` like
``rsvd``; panel orthonormalization is Householder QR (backward-stable
under the cancellation of late Krylov blocks, where one-shot Gram-based
eigQR loses orthonormality like κ²·eps), and the sharded extraction path
reuses ``gk_block``'s psum'd Gram Rayleigh–Ritz so tall factors never
gather.

Test matrices come from :func:`make_sketch` — the sparse-sign ensemble
(ζ nonzeros per column, ±1/√ζ; Clarkson–Woodruff) packed in the static
(d, ζ) ELL layout of ``kernels/sketch_matvec.py``, or a dense Gaussian.
Unlike ``SparseOp``'s value-dependent ELL pack this one is built in-trace
from a PRNG key, so sketched solves survive ``jit`` whole.

Mixed precision follows the house policy (``core/gk.py``): sketch panels
and Krylov bases are *stored* in ``_store_dtype(precision, dtype)`` (bf16
under ``precision="bf16"``), every contraction accumulates in f32.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core._keys import resolve_key
from repro.core.gk import _store_dtype
from repro.core.gk_block import (_block_project, _gram_rayleigh_ritz)
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator, sharding_mesh
from repro.kernels.sketch_matvec import ZETA

Array = jax.Array

SKETCH_KINDS = ("sparse_sign", "gaussian")

# pseudo-inverse cutoff for the (l, k) Nyström core ΨᵀAΩ, relative to its
# top singular value — below this the core direction is sketch noise and
# inverting it would amplify it into the reconstruction.
_PINV_RCOND = 1e-5


# ---------------------------------------------------------------------------
# test matrices
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseSignSketch:
    """Sparse-sign test matrix T (N, d), ζ nonzeros per column at ±1/√ζ,
    held in the static ELL pack of ``kernels/sketch_matvec``: row i of
    ``idx``/``signs`` lists sketch coordinate i's ζ source rows and signed
    weights.  Coordinates are drawn with replacement (collisions sum —
    consistent between :meth:`dense` scatter and :meth:`tapply` gather).
    """

    idx: Array          # (d, ζ) int32 — source rows of the operand block
    signs: Array        # (d, ζ) — ±1/√ζ in the storage dtype
    n: int              # N, the sketched dimension
    backend: str = "xla"

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.idx.shape[0])

    def dense(self) -> Array:
        """Materialize T (N, d) — the fallback for operators without a
        fused ``sketch_pass`` (panel-sized, never operand-sized)."""
        d = self.idx.shape[0]
        T = jnp.zeros((self.n, d), self.signs.dtype)
        return T.at[self.idx, jnp.arange(d)[:, None]].add(self.signs)

    def tapply(self, X: Array) -> Array:
        """``Tᵀ X`` (d, b) — the matrix-free apply; ``backend="pallas"``
        routes through the gather-only sketch kernel."""
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.sketch_matmat(self.signs, self.idx, X)
        from repro.kernels import ref
        return ref.sketch_matmat(self.signs, self.idx, X)


@dataclasses.dataclass(frozen=True)
class GaussianSketch:
    """Dense N(0, 1) test matrix — the HMT classic; ``tapply`` is a GEMM."""

    T: Array            # (N, d)

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.T.shape)

    def dense(self) -> Array:
        return self.T

    def tapply(self, X: Array) -> Array:
        return jnp.dot(self.T.T, X.astype(self.T.dtype),
                       preferred_element_type=jnp.float32)


def make_sketch(key: Array, n: int, d: int, *, kind: str = "sparse_sign",
                zeta: int = ZETA, dtype=jnp.float32, backend: str = "xla"):
    """Draw a (n, d) test matrix of the given ensemble (in-trace)."""
    if kind not in SKETCH_KINDS:
        raise ValueError(
            f"sketch kind must be one of {SKETCH_KINDS}, got {kind!r}")
    if kind == "gaussian":
        return GaussianSketch(jax.random.normal(key, (n, d), jnp.float32)
                              .astype(dtype))
    ki, ks = jax.random.split(key)
    z = max(1, min(zeta, n))
    idx = jax.random.randint(ki, (d, z), 0, n, jnp.int32)
    signs = jax.random.rademacher(ks, (d, z), jnp.float32) / jnp.sqrt(
        jnp.asarray(float(z), jnp.float32))
    return SparseSignSketch(idx, signs.astype(dtype), n, backend=backend)


def nystrom_reconstruct(Y: Array, Zt: Array, C: Array
                        ) -> tuple[Array, Array, Array]:
    """Stabilized generalized-Nyström core solve: the SVD of
    ``Y C⁺ Zt ≈ A`` from the range panel ``Y = AΩ`` (m, k), the co-range
    panel ``Zt = ΨᵀA`` (l, n) and the core ``C = ΨᵀY`` (l, k).

    The core pseudo-inverse is stabilized by an SVD cutoff at
    ``_PINV_RCOND·σmax`` (sketch-noise core directions are dropped, not
    inverted), Y is Householder-QR orthonormalized (backward-stable even
    when the range panel is rank-deficient), and the small projected
    matrix is SVD'd.  Shared by :func:`gnystrom` (fresh one-sweep solve)
    and ``repro.sketchres.reconstruct`` (zero-sweep solve from maintained
    panels).  Returns ``(U (m, k), s (k,), Vt (k, n))`` in f32.
    """
    C = C.astype(jnp.float32)
    Zt = Zt.astype(jnp.float32)
    Uc, sc, Vtc = jnp.linalg.svd(C, full_matrices=False)
    keep = sc > _PINV_RCOND * sc[0]
    sci = jnp.where(keep, 1.0 / jnp.where(keep, sc, 1.0), 0.0)
    M = (Vtc.T * sci[None, :]) @ (Uc.T @ Zt)      # (k, n) = C⁺ Zt
    Qy, Ry = jnp.linalg.qr(Y.astype(jnp.float32))
    B = Ry @ M                                    # (k, n) projected core
    Ub, s, Vt = jnp.linalg.svd(B, full_matrices=False)
    return Qy @ Ub, s, Vt


def _panel_dims(r: int, oversample: int, sketch_dim: Optional[int],
                m: int, n: int) -> tuple[int, int]:
    """(k, l): right/left sketch widths for gnystrom — k defaults to the
    R-SVD rule ``r + oversample`` clamped to the small dimension, the
    co-range panel is twice as wide (Tropp's l ≈ 2k recommendation)
    clamped to m, never narrower than k."""
    k = min(sketch_dim or (r + oversample), min(m, n))
    l = max(k, min(2 * k, m))
    return k, l


# ---------------------------------------------------------------------------
# randomized block Krylov (Musco & Musco 2015)
# ---------------------------------------------------------------------------

class SketchSVDResult(NamedTuple):
    U: Array
    s: Array
    V: Array
    passes: Array       # operator sweeps actually spent (0-d int32)


def rbk(
    A: Operator | LinOp | Array,
    r: int,
    *,
    passes: int = 2,
    sketch_dim: Optional[int] = None,
    kind: str = "sparse_sign",
    oversample: int = 10,
    zeta: int = ZETA,
    key: Optional[jax.Array] = None,
    dtype=None,
    precision=None,
    backend: str = "xla",
    callback=None,
) -> SketchSVDResult:
    """Top-r triplets via randomized block Krylov iteration.

    Builds the right-space Krylov basis ``[V₀, (AᵀA)V₀, …, (AᵀA)^q V₀]``
    with V₀ an orthonormalized b-column sketch (no operator touch), each
    expansion CGS-projected against the accumulated basis
    (``_block_project``, f32 accumulation) and re-orthonormalized by
    Householder QR (backward-stable under the heavy cancellation of late
    Krylov blocks — and on a row-sharded mesh the *right*-space basis is
    replicated, so the QR runs replicated with no gather), then
    Rayleigh–Ritz extracts from ``A·basis``.  Operator cost is exactly
    ``2·q_eff + 1`` sweeps (two per expansion, one for extraction);
    ``q_eff`` is the requested ``passes`` statically capped so the basis
    never exceeds ``min(m, n)`` columns — on small operands the basis
    saturates the space and the extraction is (numerically) the exact
    truncated SVD.

    ``precision="bf16"`` stores the accumulated basis half-width; every
    projection/Gram accumulates in f32 (``_block_project``).
    """
    A = as_operator(A)
    m, n = A.shape
    if dtype is None:
        dtype = jnp.promote_types(A.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)
    key = resolve_key(key, caller="rbk")
    b = min(sketch_dim or (r + oversample), min(m, n))
    q_eff = min(max(passes, 0), max((min(m, n) - b) // b, 0))

    om = make_sketch(key, n, b, kind=kind, zeta=zeta, dtype=store,
                     backend=backend)
    block, _ = jnp.linalg.qr(om.dense().astype(jnp.float32))
    basis = block.astype(store)                       # (n, b)
    for _ in range(q_eff):
        W = A.rmatmat(A.matmat(block.astype(store)))  # 2 sweeps
        # full block reorthogonalization: a nearly-converged block leaves
        # a noise-level residual whose QR *normalization* amplifies any
        # surviving basis overlap to O(1) — so project, orthonormalize,
        # then project + orthonormalize once more (the second round sees
        # unit-norm columns and removes the amplified overlap for good).
        W = _block_project(W.astype(jnp.float32), [basis], 2)
        W, _ = jnp.linalg.qr(W)
        W = _block_project(W, [basis], 2)
        block, _ = jnp.linalg.qr(W)
        basis = jnp.concatenate([basis, block.astype(store)], axis=1)

    AV = A.matmat(basis).astype(jnp.float32)          # 1 sweep
    if sharding_mesh(A) is not None:
        # keep the tall factors sharded: d×d Gram + replicated eigh
        U, s, V = _gram_rayleigh_ritz(AV, basis)
    else:
        U, s, Wt = jnp.linalg.svd(AV, full_matrices=False)
        V = basis.astype(jnp.float32) @ Wt.T
    sweeps = jnp.asarray(2 * q_eff + 1, jnp.int32)
    if callback is not None:
        from repro.api.callbacks import ConvergenceInfo
        callback.on_info(ConvergenceInfo(
            jnp.zeros((0,), jnp.float32), sweeps,
            jnp.asarray(False), method="rbk"))
    return SketchSVDResult(U[:, :r], s[:r], V[:, :r], sweeps)


# ---------------------------------------------------------------------------
# generalized Nyström (HMT 2011 §5.5 / Tropp–Webber)
# ---------------------------------------------------------------------------

def gnystrom(
    A: Operator | LinOp | Array,
    r: int,
    *,
    sketch_dim: Optional[int] = None,
    kind: str = "sparse_sign",
    oversample: int = 10,
    zeta: int = ZETA,
    key: Optional[jax.Array] = None,
    dtype=None,
    precision=None,
    backend: str = "xla",
    callback=None,
) -> SketchSVDResult:
    """Top-r triplets from ONE sweep over the operator.

    Draws independent test matrices Ω (n, k) and Ψ (m, l), captures
    ``Y = AΩ`` and ``Z = AᵀΨ`` in a single :meth:`Operator.sketch_pass`,
    and reconstructs ``A ≈ Y (ΨᵀY)⁺ (ΨᵀA)`` — the generalized Nyström
    approximation.  Everything after the sweep touches only the panels:
    the (l, k) core ``ΨᵀY`` comes from ``Ψ.tapply(Y)``, its pseudo-inverse
    is stabilized by an SVD cutoff at ``1e-5·σmax`` (sketch-noise core
    directions are dropped, not inverted), Y is QR-orthonormalized and
    the small projected matrix SVD'd.

    This is the breaker's shed solver in the serving layer and the
    resolution target for ``Operator.single_pass_only`` operands.
    """
    A = as_operator(A)
    m, n = A.shape
    if dtype is None:
        dtype = jnp.promote_types(A.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)
    key = resolve_key(key, caller="gnystrom")
    k, l = _panel_dims(r, oversample, sketch_dim, m, n)
    ko, kp = jax.random.split(key)
    om = make_sketch(ko, n, k, kind=kind, zeta=zeta, dtype=store,
                     backend=backend)
    ps = make_sketch(kp, m, l, kind=kind, zeta=zeta, dtype=store,
                     backend=backend)

    Y, Z = A.sketch_pass(om, ps)                  # THE one operator sweep
    Y = Y.astype(store)                           # (m, k) range panel
    Zt = Z.astype(jnp.float32).T                  # (l, n) = ΨᵀA
    C = ps.tapply(Y).astype(jnp.float32)          # (l, k) = ΨᵀAΩ, no touch
    U, s, Vt = nystrom_reconstruct(Y, Zt, C)
    if callback is not None:
        from repro.api.callbacks import ConvergenceInfo
        callback.on_info(ConvergenceInfo(
            jnp.zeros((0,), jnp.float32), jnp.asarray(1, jnp.int32),
            jnp.asarray(False), method="gnystrom"))
    return SketchSVDResult(U[:, :r], s[:r], Vt[:r, :].T,
                           jnp.asarray(1, jnp.int32))
