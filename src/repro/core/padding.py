"""Zero-padding to canonical shapes — the one helper behind every layer
that needs operands on a coarser shape grid.

Two consumers share the same arithmetic:

  * ``repro.distributed.partition`` pads a dense operand up to the mesh
    tiling so every shard is full (``padded_operand_shape``);
  * ``repro.serve.bucket`` pads request operands up to a shape *bucket* so
    a heavy-traffic shape mix collapses onto a bounded set of canonical
    avals (bounded executable count, stackable request buffers).

Zero rows/columns are *mathematically* inert for every matvec / CGS
reduction the solvers issue — they contribute nothing to any dot — but
they are **not bitwise inert**: XLA picks a different reduction
association (and possibly a different dot emitter) for the padded width,
so ``A_pad @ p_pad`` generally differs from ``A @ p`` in the last ulp.
Layers that promise bit-identical results therefore must not feed padded
buffers to the solver; they slice the logical operand back out first
(:func:`unpad` — exact, it only moves bytes) and solve at the logical
shape.  ``repro.serve.bucket`` documents both modes.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def pad_dim(size: int, multiple: int) -> int:
    """Smallest ``s >= size`` with ``s % multiple == 0`` (multiple >= 1)."""
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    return size + (-size) % multiple


def padded_shape(shape: Sequence[int],
                 multiples: Sequence[int]) -> Tuple[int, ...]:
    """Per-dim :func:`pad_dim`: smallest shape >= ``shape`` whose dims are
    multiples of ``multiples`` (the mesh tiling or the bucket granularity)."""
    if len(shape) != len(multiples):
        raise ValueError(
            f"shape {tuple(shape)} and multiples {tuple(multiples)} must "
            "have equal length")
    return tuple(pad_dim(s, t) for s, t in zip(shape, multiples))


def pad_to(A, shape: Sequence[int]):
    """Zero-embed ``A`` in the top-left corner of ``shape``.

    A no-op (same array, no copy) when the shape already matches.  Numpy
    inputs stay numpy (``np.pad`` — no XLA compile per shape signature,
    which matters on the serve intake path); jax arrays go through
    ``jnp.pad`` so the distributed call sites stay traceable."""
    shape = tuple(shape)
    if tuple(A.shape) == shape:
        return A
    widths = []
    for have, want in zip(A.shape, shape):
        if want < have:
            raise ValueError(
                f"cannot pad {tuple(A.shape)} down to {shape}")
        widths.append((0, want - have))
    if isinstance(A, np.ndarray):
        return np.pad(A, widths)
    return jnp.pad(A, widths)


def unpad(A: Array, shape: Sequence[int]) -> Array:
    """Slice the logical top-left ``shape`` block back out of a padded
    buffer.  Exact — slicing moves bytes, it never rounds; this is the
    step that restores bit-identical solves after padded transport."""
    shape = tuple(shape)
    if tuple(A.shape) == shape:
        return A
    index = tuple(slice(0, s) for s in shape)
    return A[index]


__all__ = ["pad_dim", "padded_shape", "pad_to", "unpad"]
