"""Algorithm 1 — Golub-Kahan bidiagonalization with reorthogonalization and
breakdown-based numerical-rank detection.

Two execution styles share the same math:

  * ``gk_bidiag``      — in-graph ``lax.fori_loop`` with fixed-size buffers and
                         breakdown *masking* (XLA-static shapes; usable inside
                         jit / grad-compression / the RSGD retraction, and on
                         pod-sharded operators).
  * ``gk_bidiag_host`` — host-side Python loop with *real* early exit (what the
                         paper benchmarks: iteration count == numerical rank).

Index conventions (paper eq. 9): ``alphas[i] = alpha_{i+1}`` (diagonal of
B_{k+1,k}), ``betas[i] = beta_{i+2}`` (subdiagonal), ``beta1`` is the
normalization of the start vector (not part of B).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core._keys import resolve_key
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator

Array = jax.Array


class GKResult(NamedTuple):
    alphas: Array      # (k,)   diag of B_{k+1,k}; zero-masked beyond kprime
    betas: Array       # (k,)   subdiag beta_{2..k+1}; zero-masked beyond kprime
    beta1: Array       # ()     norm of the start vector
    P: Array           # (n, k)   right Lanczos basis, zero cols beyond kprime
    Q: Array           # (m, k+1) left Lanczos basis
    kprime: Array      # ()  int32: number of valid columns (== rank estimate
                       #     when breakdown fired before k iterations)
    breakdown: Array   # ()  bool: did ||q_{k'+1}|| < eps fire?


def _reorth(v: Array, basis: Array, passes: int) -> Array:
    """Classical Gram-Schmidt against the (zero-padded) basis, ``passes`` times.

    Zero-padded columns contribute nothing, so the fixed-size buffer needs no
    masking here.  CGS2 ("twice is enough") restores orthogonality to machine
    precision — the paper's lines 6/13 with the standard stabilization.
    """
    for _ in range(passes):
        v = v - basis @ (basis.T @ v)
    return v


def start_vector(key: jax.Array, m: int, dtype=jnp.float32) -> Array:
    """Paper Alg 1 line 1: q1 ~ N(2, 1)^{m x 1}."""
    return (2.0 + jax.random.normal(key, (m,))).astype(dtype)


def gk_bidiag(
    op: Operator | LinOp | Array,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    reorth_passes: int = 2,
    dtype=None,
) -> GKResult:
    """In-graph GK bidiagonalization (fixed k iterations, breakdown masking)."""
    op = as_operator(op)
    m, n = op.shape
    if k > min(m, n):
        k = min(m, n)
    if dtype is None:
        dtype = jnp.promote_types(op.dtype, jnp.float32)

    if q1 is None:
        key = resolve_key(key, caller="gk_bidiag")
        q1 = start_vector(key, m, dtype)
    q1 = q1.astype(dtype)

    beta1 = jnp.linalg.norm(q1)
    q = q1 / beta1
    p = op.rmv(q).astype(dtype)
    alpha1 = jnp.linalg.norm(p)
    p = p / jnp.where(alpha1 > 0, alpha1, 1.0)

    Q = jnp.zeros((m, k + 1), dtype).at[:, 0].set(q)
    P = jnp.zeros((n, k), dtype).at[:, 0].set(p)
    alphas = jnp.zeros((k,), dtype).at[0].set(alpha1)
    betas = jnp.zeros((k,), dtype)

    # breakdown threshold: the paper uses an absolute eps=1e-8 (float64
    # NumPy, where the CGS2 residual floor is ~1e-15).  In float32 the floor
    # is ~40*eps_f32 ~ 5e-6 relative, so `relative_eps` scales by alpha1
    # (~||A||) AND clamps eps to the dtype's reorthogonalization noise floor
    # — in f64 this preserves the paper's 1e-8 semantics exactly.
    eff_eps = max(eps, 40.0 * float(jnp.finfo(dtype).eps))
    thresh = jnp.where(relative_eps, eff_eps * jnp.maximum(alpha1, 1.0), eps)

    class Carry(NamedTuple):
        Q: Array
        P: Array
        alphas: Array
        betas: Array
        q: Array
        p: Array
        kprime: Array
        done: Array

    def body(i, c: Carry):
        # --- left vector: u = A p_i - alpha_i q_i  (paper line 5) ---
        u = op.mv_fused(c.p, c.q, c.alphas[i - 1]).astype(dtype)
        u = _reorth(u, c.Q, reorth_passes)                      # line 6
        beta = jnp.linalg.norm(u)                               # line 7
        hit = beta < thresh                                     # line 9
        newly_done = jnp.logical_and(hit, jnp.logical_not(c.done))
        done = jnp.logical_or(c.done, hit)
        safe_beta = jnp.where(beta > 0, beta, 1.0)
        qn = u / safe_beta                                      # line 8
        # --- right vector: v = A^T q_{i+1} - beta_{i+1} p_i  (line 12) ---
        v = op.rmv_fused(qn, c.p, beta).astype(dtype)
        v = _reorth(v, c.P, reorth_passes)                      # line 13
        alpha = jnp.linalg.norm(v)                              # line 14
        hit_a = alpha < thresh
        done2 = jnp.logical_or(done, hit_a)
        safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
        pn = v / safe_alpha

        keep = jnp.logical_not(done)        # was active at loop entry
        keep2 = jnp.logical_not(done2)
        Qn = jnp.where(keep, c.Q.at[:, i].set(qn).astype(dtype), c.Q)
        Pn = jnp.where(keep2, c.P.at[:, i].set(pn), c.P)
        alphas_n = jnp.where(keep2, c.alphas.at[i].set(alpha), c.alphas)
        betas_n = jnp.where(keep, c.betas.at[i - 1].set(beta), c.betas)
        kprime_n = jnp.where(done2, c.kprime, c.kprime + 1)
        return Carry(Qn, Pn, alphas_n, betas_n,
                     jnp.where(keep, qn, c.q), jnp.where(keep2, pn, c.p),
                     kprime_n, done2)

    init = Carry(Q, P, alphas, betas, q, p,
                 jnp.asarray(1, jnp.int32), jnp.asarray(False))
    c = jax.lax.fori_loop(1, k, body, init)

    # final half-iteration (paper lines 5-8 at i=k): beta_{k+1} / q_{k+1}
    # complete B_{k+1,k} — without them the last tridiagonal entry and the
    # identity A P_k = Q_{k+1} B_{k+1,k} are truncated.
    u = op.mv_fused(c.p, c.q, c.alphas[c.kprime - 1]).astype(dtype)
    u = _reorth(u, c.Q, reorth_passes)
    beta = jnp.linalg.norm(u)
    valid = jnp.logical_not(c.done) & (beta >= thresh)
    qn = u / jnp.where(beta > 0, beta, 1.0)
    Qf = jnp.where(valid, c.Q.at[:, c.kprime].set(qn.astype(dtype)), c.Q)
    betas_f = jnp.where(valid, c.betas.at[c.kprime - 1].set(beta), c.betas)
    return GKResult(c.alphas, betas_f, beta1, c.P, Qf,
                    c.kprime, c.done)


def gk_bidiag_host(
    op: Operator | LinOp | Array,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    reorth_passes: int = 2,
    dtype=None,
) -> GKResult:
    """Host-loop GK with real early exit (paper-style wall-time behaviour)."""
    op = as_operator(op)
    m, n = op.shape
    if k > min(m, n):
        k = min(m, n)
    if dtype is None:
        dtype = jnp.promote_types(op.dtype, jnp.float32)

    if q1 is None:
        key = resolve_key(key, caller="gk_bidiag_host")
        q1 = start_vector(key, m, dtype)
    q1 = q1.astype(dtype)

    beta1 = jnp.linalg.norm(q1)
    q = q1 / beta1
    p = op.rmv(q).astype(dtype)
    alpha1 = float(jnp.linalg.norm(p))
    p = p / (alpha1 if alpha1 > 0 else 1.0)
    eff_eps = max(eps, 40.0 * float(jnp.finfo(dtype).eps))
    thresh = eff_eps * max(alpha1, 1.0) if relative_eps else eps

    qs = [q]
    ps = [p]
    al = [alpha1]
    be = []
    breakdown = False
    Qm = q[:, None]
    Pm = p[:, None]

    for _ in range(1, k):
        u = op.mv_fused(ps[-1], qs[-1], al[-1]).astype(dtype)
        for _ in range(reorth_passes):
            u = u - Qm @ (Qm.T @ u)
        beta = float(jnp.linalg.norm(u))
        if beta < thresh:
            breakdown = True
            break
        qn = u / beta
        v = op.rmv_fused(qn, ps[-1], beta).astype(dtype)
        for _ in range(reorth_passes):
            v = v - Pm @ (Pm.T @ v)
        alpha = float(jnp.linalg.norm(v))
        if alpha < thresh:
            be.append(beta)
            qs.append(qn)
            Qm = jnp.concatenate([Qm, qn[:, None]], axis=1)
            breakdown = True
            break
        pn = v / alpha
        qs.append(qn)
        ps.append(pn)
        al.append(alpha)
        be.append(beta)
        Qm = jnp.concatenate([Qm, qn[:, None]], axis=1)
        Pm = jnp.concatenate([Pm, pn[:, None]], axis=1)

    if not breakdown and len(al) == k:
        # final half-iteration: beta_{k+1}, q_{k+1} complete B_{k+1,k}
        u = op.mv_fused(ps[-1], qs[-1], al[-1]).astype(dtype)
        for _ in range(reorth_passes):
            u = u - Qm @ (Qm.T @ u)
        beta = float(jnp.linalg.norm(u))
        if beta >= thresh:
            be.append(beta)
            Qm = jnp.concatenate([Qm, (u / beta)[:, None]], axis=1)

    kp = len(al)
    alphas = jnp.zeros((k,), dtype).at[:kp].set(jnp.asarray(al, dtype))
    betas = jnp.zeros((k,), dtype).at[:len(be)].set(jnp.asarray(be, dtype))
    P = jnp.zeros((n, k), dtype).at[:, :Pm.shape[1]].set(Pm)
    Q = jnp.zeros((m, k + 1), dtype).at[:, :Qm.shape[1]].set(Qm)
    return GKResult(alphas, betas, jnp.asarray(beta1, dtype), P, Q,
                    jnp.asarray(kp, jnp.int32), jnp.asarray(breakdown))
