"""Algorithm 1 — Golub-Kahan bidiagonalization with reorthogonalization and
breakdown-based numerical-rank detection.

Two execution styles share the same math:

  * ``gk_bidiag``      — in-graph ``lax.fori_loop`` with fixed-size buffers and
                         breakdown *masking* (XLA-static shapes; usable inside
                         jit / grad-compression / the RSGD retraction, and on
                         pod-sharded operators).
  * ``gk_bidiag_host`` — host-side Python loop with *real* early exit (what the
                         paper benchmarks: iteration count == numerical rank).

Both route every half-iteration through the operator's fused
``lanczos_step`` / ``lanczos_rstep`` pipeline (matvec + CGS + norm in one
seam; single-pass Pallas kernels for ``DenseOp(backend="pallas")``), and
both support a mixed-precision mode: ``precision="bf16"`` stores the P/Q
bases half-width in HBM while every reduction/accumulation stays f32.  The
in-graph carry writes one masked *column* per iteration
(``dynamic_update_slice``) instead of re-selecting the whole (m, k+1)
buffer — O(m) instead of O(mk) traffic per step.

Index conventions (paper eq. 9): ``alphas[i] = alpha_{i+1}`` (diagonal of
B_{k+1,k}), ``betas[i] = beta_{i+2}`` (subdiagonal), ``beta1`` is the
normalization of the start vector (not part of B).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core._keys import resolve_key
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator, cgs

Array = jax.Array

PRECISIONS = (None, "f32", "bf16")


class GKResult(NamedTuple):
    alphas: Array      # (k,)   diag of B_{k+1,k}; zero-masked beyond kprime
    betas: Array       # (k,)   subdiag beta_{2..k+1}; zero-masked beyond kprime
    beta1: Array       # ()     norm of the start vector
    P: Array           # (n, k)   right Lanczos basis, zero cols beyond kprime
    Q: Array           # (m, k+1) left Lanczos basis
    kprime: Array      # ()  int32: number of valid columns (== rank estimate
                       #     when breakdown fired before k iterations)
    breakdown: Array   # ()  bool: did ||q_{k'+1}|| < eps fire?


def _store_dtype(precision, compute_dtype):
    """Basis storage dtype for a ``precision`` knob value.

    ``None`` keeps the compute dtype; ``"f32"`` / ``"bf16"`` pin the basis
    storage width (reductions always accumulate in the compute dtype).
    """
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}")
    if precision is None:
        return compute_dtype
    return jnp.bfloat16 if precision == "bf16" else jnp.float32


def _eff_eps(eps: float, dtype, store) -> float:
    """Breakdown epsilon clamped to the reorthogonalization noise floor.

    The paper uses an absolute eps=1e-8 (float64 NumPy, where the CGS2
    residual floor is ~1e-15).  In float32 the floor is ~40*eps_f32 ~ 5e-6
    relative, so ``relative_eps`` scales by alpha1 (~||A||) AND clamps eps
    to the compute dtype's noise floor — in f64 this preserves the paper's
    1e-8 semantics exactly.  A narrower *storage* dtype raises the floor
    again: CGS2 against a rounded basis bottoms out at ~eps_store² relative
    (one eps_store of overlap survives each pass), so without the clamp a
    bf16 run never detects breakdown and the unprotected three-term
    recurrence amplifies the junk directions until overflow.
    """
    return max(eps, 40.0 * float(jnp.finfo(dtype).eps),
               40.0 * float(jnp.finfo(store).eps) ** 2)


def _notify(callback, alphas, betas, kprime, breakdown):
    """Assemble a ``ConvergenceInfo`` and hand it to ``callback.on_info``.

    The residual proxy per iteration is ``beta_{i+1}`` — the recurrence
    coupling whose collapse under the breakdown threshold is the paper's
    Alg-1 convergence event.  Lazy import: ``repro.api`` imports this
    module at load time, so the reverse edge must stay call-time only.
    """
    if callback is None:
        return
    from repro.api.callbacks import ConvergenceInfo
    callback.on_info(ConvergenceInfo(betas, kprime, breakdown, method="gk"))


def _step(op, p, y, alpha, basis, passes):
    """Dispatch one fused left half-step (LinOp closures lack the method)."""
    fn = getattr(op, "lanczos_step", None)
    if fn is not None:
        return fn(p, y, alpha, basis, passes=passes)
    u = cgs(op.mv_fused(p, y, alpha), basis, passes)
    return u, jnp.linalg.norm(u)


def _rstep(op, q, y, beta, basis, passes):
    fn = getattr(op, "lanczos_rstep", None)
    if fn is not None:
        return fn(q, y, beta, basis, passes=passes)
    v = cgs(op.rmv_fused(q, y, beta), basis, passes)
    return v, jnp.linalg.norm(v)


def _set_col(buf: Array, idx, col: Array, keep) -> Array:
    """Masked write of ``col`` into ``buf[:, idx]`` — O(m) select on the
    column only, never a whole-buffer copy."""
    cur = jax.lax.dynamic_slice_in_dim(buf, idx, 1, axis=1)
    new = jnp.where(keep, col.astype(buf.dtype)[:, None], cur)
    return jax.lax.dynamic_update_slice_in_dim(buf, new, idx, axis=1)


def _set_elt(vec: Array, idx, val, keep) -> Array:
    """Masked write of a scalar into ``vec[idx]``."""
    cur = jax.lax.dynamic_slice(vec, (idx,), (1,))
    new = jnp.where(keep, jnp.asarray(val, vec.dtype)[None], cur)
    return jax.lax.dynamic_update_slice(vec, new, (idx,))


def start_vector(key: jax.Array, m: int, dtype=jnp.float32) -> Array:
    """Paper Alg 1 line 1: q1 ~ N(2, 1)^{m x 1}."""
    return (2.0 + jax.random.normal(key, (m,))).astype(dtype)


def gk_bidiag(
    op: Operator | LinOp | Array,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    reorth_passes: int = 2,
    dtype=None,
    precision: Optional[str] = None,
    callback=None,
) -> GKResult:
    """In-graph GK bidiagonalization (fixed k iterations, breakdown masking).

    ``precision="bf16"`` stores the P/Q bases in bfloat16 (half the HBM
    bytes of the bandwidth-bound reorthogonalization streams) while the
    recurrence scalars, carried vectors and all accumulations stay in the
    compute dtype.  The breakdown threshold widens to the storage's CGS2
    noise floor (see :func:`_eff_eps`), so bf16 is a throughput mode for
    fixed-k factorization; rank detection wants full precision.
    """
    op = as_operator(op)
    m, n = op.shape
    if k > min(m, n):
        k = min(m, n)
    if dtype is None:
        dtype = jnp.promote_types(op.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)

    if q1 is None:
        key = resolve_key(key, caller="gk_bidiag")
        q1 = start_vector(key, m, dtype)
    q1 = q1.astype(dtype)

    beta1 = jnp.linalg.norm(q1)
    q = q1 / beta1
    p = op.rmv(q).astype(dtype)
    alpha1 = jnp.linalg.norm(p)
    p = p / jnp.where(alpha1 > 0, alpha1, 1.0)

    Q = jnp.zeros((m, k + 1), store).at[:, 0].set(q.astype(store))
    P = jnp.zeros((n, k), store).at[:, 0].set(p.astype(store))
    # sharded operators lay the basis buffers out on their vector sharding
    # up front, so the carried buffers match the fused step's layout
    # instead of being re-sharded on the first iteration.
    place = getattr(op, "place_basis", None)
    if place is not None:
        Q = place(Q, "left")
        P = place(P, "right")
    alphas = jnp.zeros((k,), dtype).at[0].set(alpha1)
    betas = jnp.zeros((k,), dtype)

    eff_eps = _eff_eps(eps, dtype, store)
    thresh = jnp.where(relative_eps, eff_eps * jnp.maximum(alpha1, 1.0), eps)

    class Carry(NamedTuple):
        Q: Array
        P: Array
        alphas: Array
        betas: Array
        q: Array
        p: Array
        kprime: Array
        done: Array

    def body(i, c: Carry):
        # --- left vector: u = A p_i - alpha_i q_i, CGS2, norm (lines 5-7)
        u, beta = _step(op, c.p, c.q, c.alphas[i - 1], c.Q, reorth_passes)
        u = u.astype(dtype)
        beta = beta.astype(dtype)
        hit = beta < thresh                                     # line 9
        done = jnp.logical_or(c.done, hit)
        safe_beta = jnp.where(beta > 0, beta, 1.0)
        qn = u / safe_beta                                      # line 8
        # --- right vector: v = A^T q_{i+1} - beta_{i+1} p_i (lines 12-14)
        v, alpha = _rstep(op, qn, c.p, beta, c.P, reorth_passes)
        v = v.astype(dtype)
        alpha = alpha.astype(dtype)
        hit_a = alpha < thresh
        done2 = jnp.logical_or(done, hit_a)
        safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
        pn = v / safe_alpha

        keep = jnp.logical_not(done)        # was active at loop entry
        keep2 = jnp.logical_not(done2)
        Qn = _set_col(c.Q, i, qn, keep)
        Pn = _set_col(c.P, i, pn, keep2)
        alphas_n = _set_elt(c.alphas, i, alpha, keep2)
        betas_n = _set_elt(c.betas, i - 1, beta, keep)
        kprime_n = jnp.where(done2, c.kprime, c.kprime + 1)
        return Carry(Qn, Pn, alphas_n, betas_n,
                     jnp.where(keep, qn, c.q), jnp.where(keep2, pn, c.p),
                     kprime_n, done2)

    init = Carry(Q, P, alphas, betas, q, p,
                 jnp.asarray(1, jnp.int32), jnp.asarray(False))
    c = jax.lax.fori_loop(1, k, body, init)

    # final half-iteration (paper lines 5-8 at i=k): beta_{k+1} / q_{k+1}
    # complete B_{k+1,k} — without them the last tridiagonal entry and the
    # identity A P_k = Q_{k+1} B_{k+1,k} are truncated.
    u, beta = _step(op, c.p, c.q, c.alphas[c.kprime - 1], c.Q, reorth_passes)
    u = u.astype(dtype)
    beta = beta.astype(dtype)
    valid = jnp.logical_not(c.done) & (beta >= thresh)
    qn = u / jnp.where(beta > 0, beta, 1.0)
    Qf = _set_col(c.Q, c.kprime, qn, valid)
    betas_f = _set_elt(c.betas, c.kprime - 1, beta, valid)
    # in-graph diagnostics: the betas buffer IS the per-iteration residual
    # trace — no extra device work, and under jit the info pytree holds
    # tracers the caller can return as compiled-program outputs.
    _notify(callback, c.alphas, betas_f, c.kprime, c.done)
    return GKResult(c.alphas, betas_f, beta1, c.P, Qf,
                    c.kprime, c.done)


def gk_bidiag_host(
    op: Operator | LinOp | Array,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    reorth_passes: int = 2,
    dtype=None,
    precision: Optional[str] = None,
    callback=None,
) -> GKResult:
    """Host-loop GK with real early exit (paper wall-time behaviour).

    One device→host sync per iteration: the right half-step is issued
    speculatively against the device-resident ``beta`` and both recurrence
    scalars come back in a single ``device_get`` — the old per-scalar
    ``float(norm)`` pattern stalled the pipeline twice per step.
    """
    op = as_operator(op)
    m, n = op.shape
    if k > min(m, n):
        k = min(m, n)
    if dtype is None:
        dtype = jnp.promote_types(op.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)

    if q1 is None:
        key = resolve_key(key, caller="gk_bidiag_host")
        q1 = start_vector(key, m, dtype)
    q1 = q1.astype(dtype)

    beta1 = jnp.linalg.norm(q1)
    q = q1 / beta1
    p = op.rmv(q).astype(dtype)
    alpha1 = float(jnp.linalg.norm(p))
    p = p / (alpha1 if alpha1 > 0 else 1.0)
    eff_eps = _eff_eps(eps, dtype, store)
    thresh = eff_eps * max(alpha1, 1.0) if relative_eps else eps

    qs = [q]
    ps = [p]
    al = [alpha1]
    be = []
    breakdown = False
    # fixed-width zero-padded basis buffers: zero columns contribute
    # nothing to CGS (exact), and a constant shape means the jitted fused
    # step compiles ONCE instead of retracing per appended column.
    Qm = jnp.zeros((m, k + 1), store).at[:, 0].set(q.astype(store))
    Pm = jnp.zeros((n, k), store).at[:, 0].set(p.astype(store))
    place = getattr(op, "place_basis", None)
    if place is not None:
        # one placement up front: every eager fused step then consumes the
        # buffer in its own layout instead of re-sharding per iteration.
        Qm = place(Qm, "left")
        Pm = place(Pm, "right")

    for _ in range(1, k):
        u, beta_d = _step(op, ps[-1], qs[-1], al[-1], Qm, reorth_passes)
        u = u.astype(dtype)
        # speculative right half-step: normalize/advance against the
        # device scalar so beta and alpha arrive in ONE host round-trip
        qn = u / jnp.where(beta_d > 0, beta_d, 1.0).astype(dtype)
        v, alpha_d = _rstep(op, qn, ps[-1], beta_d, Pm, reorth_passes)
        v = v.astype(dtype)
        beta, alpha = (float(x) for x in jax.device_get((beta_d, alpha_d)))
        if callback is not None:
            # the loop just synced these scalars anyway — observing them
            # costs nothing extra.
            callback.on_step(len(al), alpha=alpha, beta=beta)
        if beta < thresh:
            breakdown = True
            break
        if alpha < thresh:
            be.append(beta)
            Qm = Qm.at[:, len(qs)].set(qn.astype(store))
            qs.append(qn)
            breakdown = True
            break
        pn = v / alpha
        Qm = Qm.at[:, len(qs)].set(qn.astype(store))
        Pm = Pm.at[:, len(ps)].set(pn.astype(store))
        qs.append(qn)
        ps.append(pn)
        al.append(alpha)
        be.append(beta)

    if not breakdown and len(al) == k:
        # final half-iteration: beta_{k+1}, q_{k+1} complete B_{k+1,k}
        u, beta_d = _step(op, ps[-1], qs[-1], al[-1], Qm, reorth_passes)
        u = u.astype(dtype)
        beta = float(beta_d)
        if beta >= thresh:
            be.append(beta)
            Qm = Qm.at[:, k].set((u / beta).astype(store))

    kp = len(al)
    alphas = jnp.zeros((k,), dtype).at[:kp].set(jnp.asarray(al, dtype))
    betas = jnp.zeros((k,), dtype).at[:len(be)].set(jnp.asarray(be, dtype))
    _notify(callback, alphas, betas, jnp.asarray(kp, jnp.int32),
            jnp.asarray(breakdown))
    return GKResult(alphas, betas, jnp.asarray(beta1, dtype), Pm, Qm,
                    jnp.asarray(kp, jnp.int32), jnp.asarray(breakdown))
