"""Randomized SVD baseline (Halko, Martinsson & Tropp 2011) — the paper's
comparison algorithm ("R-SVD"), with the default (p=10) and oversampled
variants used in Tables 1b/2 and Figure 1.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core._keys import resolve_key
from repro.core.linop import LinOp
from repro.core.operators import Operator, as_operator

Array = jax.Array


class RSVDResult(NamedTuple):
    U: Array
    s: Array
    V: Array


def rsvd(
    A: Operator | LinOp | Array,
    k: int,
    *,
    p: int = 10,
    power_iters: int = 0,
    key: Optional[jax.Array] = None,
    dtype=None,
    precision=None,
    callback=None,
) -> RSVDResult:
    """Top-k triplets via Gaussian range sketching (HMT Algorithms 4.3/5.1).

    ``p`` is the oversampling parameter (paper default 10; "oversampled"
    experiments push it to hundreds when the spectrum decays slowly).
    ``power_iters`` = q subspace/power iterations with QR re-orthonormalization.
    ``precision="bf16"`` stores the sketch/range bases half-width between
    passes over A (the QR factorizations and the small SVD stay f32).
    ``callback`` gets a single ``on_info`` — sketching has no per-iteration
    residual signal (a residual estimate would cost extra passes over A),
    so the info carries an empty residual trace and the pass count.
    """
    from repro.core.gk import _store_dtype
    A = as_operator(A)
    m, n = A.shape
    if dtype is None:
        dtype = jnp.promote_types(A.dtype, jnp.float32)
    store = _store_dtype(precision, dtype)
    key = resolve_key(key, caller="rsvd")
    l = min(k + p, min(m, n))

    Omega = jax.random.normal(key, (n, l), dtype).astype(store)
    Y = A.matmat(Omega).astype(dtype)         # (m, l)
    Q, _ = jnp.linalg.qr(Y)
    for _ in range(power_iters):
        Z = A.rmatmat(Q.astype(store)).astype(dtype)   # (n, l)
        Z, _ = jnp.linalg.qr(Z)
        Y = A.matmat(Z.astype(store)).astype(dtype)
        Q, _ = jnp.linalg.qr(Y)
    Qs = Q.astype(store)
    B = A.rmatmat(Qs).T.astype(dtype)         # (l, n) = Q^T A
    Ub, s, Vt = jnp.linalg.svd(B, full_matrices=False)
    U = Q @ Ub
    if callback is not None:
        from repro.api.callbacks import ConvergenceInfo
        callback.on_info(ConvergenceInfo(
            jnp.zeros((0,), jnp.float32),
            jnp.asarray(power_iters, jnp.int32),
            jnp.asarray(False), method="rsvd"))
    return RSVDResult(U[:, :k], s[:k], Vt[:k, :].T)
