"""One PRNG-key policy for every solver (re-exported by ``repro.api``).

The seed handled implicit keys inconsistently: ``rsvd`` silently fell back
to ``PRNGKey(0)`` while ``gk_bidiag`` did the same only when no warm-start
vector was given, with no signal either way.  Every entry point now funnels
through :func:`resolve_key`, which keeps the deterministic default (exact
reproducibility of the paper tables) but *warns* so implicit seeding is
always visible.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax

IMPLICIT_KEY_MSG = (
    "{caller}: no PRNG key was supplied; falling back to "
    "jax.random.PRNGKey(0). Pass key= explicitly (or a warm-start q1) to "
    "silence this warning and control reproducibility."
)


class ImplicitKeyWarning(UserWarning):
    """Raised (as a warning) when a solver self-seeds with PRNGKey(0)."""


def resolve_key(key: Optional[jax.Array], *, caller: str = "solver",
                warn: bool = True) -> jax.Array:
    """Return ``key`` or the deterministic default, warning on the latter."""
    if key is None:
        if warn:
            warnings.warn(IMPLICIT_KEY_MSG.format(caller=caller),
                          ImplicitKeyWarning, stacklevel=3)
        return jax.random.PRNGKey(0)
    return key
