"""Algorithm 3 — fast numerical rank determination.

Run GK bidiagonalization with the breakdown criterion (Alg 1); the iteration
count at breakdown is the *first* rank estimate; the *accurate* rank is the
number of eigenvalues of B^T B above epsilon (Alg 3 line 4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

import repro.core.gk as gk_mod
from repro.core.linop import LinOp
from repro.core.operators import (GramOp, Operator, TransposedOp, as_operator)
from repro.core.tridiag import btb_eigh

Array = jax.Array


class RankResult(NamedTuple):
    rank: Array          # () int32 — accurate numerical rank (Alg 3)
    gk_iterations: Array  # () int32 — Alg 1 iteration count at termination
    eigenvalues: Array   # (k,) Ritz values of B^T B, descending (−inf padded)


def numerical_rank(
    A: Operator | LinOp | Array,
    *,
    max_iters: Optional[int] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    sigma_tol: Optional[float] = None,
    key: Optional[jax.Array] = None,
    host_loop: bool = True,
    reorth_passes: int = 2,
    dtype=None,
) -> RankResult:
    """Estimate rank(A).

    ``eps`` is the breakdown threshold of Alg 1.  ``sigma_tol`` is the Alg-3
    counting threshold applied to the Ritz values of B^T B; it defaults to a
    spectrum-relative tolerance ``(max theta) * tol_dtype`` which is the
    float32-safe reading of the paper's absolute 1e-8 (the paper ran float64
    NumPy where absolute thresholds are meaningful).
    """
    A = as_operator(A)
    # Matrix-free unwrapping: rank(Aᵀ) == rank(A) and rank(AᵀA) ==
    # rank(AAᵀ) == rank(A), so run GK on the innermost operand — never on
    # the composed chain (GramOp matvecs square the condition number,
    # σ(AᵀA) = σ(A)², which pushes small-but-nonzero singular values under
    # the breakdown threshold and *under*-counts rank; a TransposedOp adds
    # an indirection per half-iteration for no information).  Neither wrapper
    # is ever densified.  For a GramOp input the returned ``eigenvalues``
    # are therefore the Ritz values of the *inner* operator's BᵀB — the
    # rank they count is identical.
    while isinstance(A, (TransposedOp, GramOp)):
        A = A.inner
        A = as_operator(A)
    if max_iters is None:
        max_iters = min(A.shape)
    max_iters = min(max_iters, min(A.shape))
    runner = gk_mod.gk_bidiag_host if host_loop else gk_mod.gk_bidiag
    res = runner(A, max_iters, key=key, eps=eps, relative_eps=relative_eps,
                 reorth_passes=reorth_passes, dtype=dtype)
    theta, _ = btb_eigh(res.alphas, res.betas, res.kprime)
    finite = jnp.where(jnp.isfinite(theta), theta, 0.0)
    if sigma_tol is None:
        big = jnp.max(finite)
        eps_dt = jnp.finfo(finite.dtype).eps
        # theta ~ sigma^2: tolerance on the squared scale, with generous
        # headroom over roundoff accumulated across k' Lanczos steps.
        sigma_tol_arr = big * eps_dt * res.kprime.astype(finite.dtype) * 10.0
    else:
        sigma_tol_arr = jnp.asarray(sigma_tol, finite.dtype)
    rank = jnp.sum(finite > sigma_tol_arr).astype(jnp.int32)
    return RankResult(rank, res.kprime, theta)
