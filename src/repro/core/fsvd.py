"""Algorithm 2 — Accurate and fast partial SVD (F-SVD).

Pipeline (paper Alg 2):
  1. GK-bidiagonalize A for (at most) k iterations -> B_{k'+1,k'}, P_{k'}, Q.
  2. eigh of the small tridiagonal B^T B -> Ritz pairs (theta_i, g_i).
  3. Right singular vectors  V = P @ g   (Ritz vectors of A^T A).
  4. sigma = sqrt(theta);  U = A V Sigma^{-1}   (line 7).

Only matvecs with A are ever needed, so the same code serves dense matrices,
implicitly-factored operators and pod-sharded operators.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

import repro.core.gk as gk_mod
from repro.core.linop import LinOp
from repro.core.operators import DenseOp, Operator, as_operator
from repro.core.tridiag import btb_eigh

Array = jax.Array


class FSVDResult(NamedTuple):
    U: Array        # (m, r)
    s: Array        # (r,)    descending
    V: Array        # (n, r)
    kprime: Array   # () int32 — GK iterations actually used (rank estimate)
    breakdown: Array


def _mixed_matmul(B: Array, X: Array) -> Array:
    """``B @ X`` with f32 accumulation when B is a narrow-storage basis
    (bf16 B stays bf16 in memory; X is rounded to B's dtype at the MXU)."""
    if B.dtype == X.dtype:
        return B @ X
    return jnp.dot(B, X.astype(B.dtype), preferred_element_type=jnp.float32)


def _assemble(op, res: gk_mod.GKResult, r: int) -> FSVDResult:
    theta, G = btb_eigh(res.alphas, res.betas, res.kprime)
    r = min(r, res.alphas.shape[0])
    theta_r = theta[:r]
    G_r = G[:, :r]
    # padding Ritz values were masked to -inf; clamp for sqrt and zero the
    # corresponding singular values.
    pad = ~jnp.isfinite(theta_r)
    s = jnp.sqrt(jnp.clip(jnp.where(pad, 0.0, theta_r), 0.0, None))
    V = _mixed_matmul(res.P, G_r)                       # line 3: V2 = P V1
    AV = op.matmat(V)                                   # lines 6-8
    U = AV / jnp.where(s > 0, s, 1.0)[None, :]
    U = jnp.where(pad[None, :], 0.0, U)
    V = jnp.where(pad[None, :], 0.0, V)
    return FSVDResult(U, s, V, res.kprime, res.breakdown)


def default_k(r: int, shape) -> int:
    """The Krylov budget a cold F-SVD uses when ``k`` is omitted:
    ``min(4 r, min(m, n))`` — the space needs slack beyond r for the top-r
    Ritz values to converge.  Shared with the session layer, whose refine
    cap must track what cold solves actually run."""
    return min(4 * r, min(shape))


def fsvd(
    A: Operator | LinOp | Array,
    r: int,
    k: Optional[int] = None,
    *,
    key: Optional[jax.Array] = None,
    q1: Optional[Array] = None,
    eps: float = 1e-8,
    relative_eps: bool = True,
    reorth_passes: int = 2,
    host_loop: bool = False,
    dtype=None,
    precision=None,
    callback=None,
) -> FSVDResult:
    """Top-r singular triplets of A via k-step GK bidiagonalization.

    ``k`` defaults to ``min(4 r, min(m, n))`` — the Krylov space needs some
    slack beyond r for the top-r Ritz values to converge (paper uses e.g.
    k=550 for r=100).  ``host_loop=True`` uses the early-exit host loop.
    ``precision="bf16"`` stores the Lanczos bases half-width (see
    :func:`repro.core.gk.gk_bidiag`); the Ritz extraction stays f32.
    ``callback`` is a ``repro.api.callbacks.ConvergenceCallback``: host-loop
    runs get ``on_step`` per iteration, every run gets the final
    ``on_info`` (in-graph: a pytree of device arrays / tracers).
    """
    A = as_operator(A)
    if k is None:
        k = default_k(r, A.shape)
    k = max(k, r)
    runner = gk_mod.gk_bidiag_host if host_loop else gk_mod.gk_bidiag
    res = runner(A, k, key=key, q1=q1, eps=eps, relative_eps=relative_eps,
                 reorth_passes=reorth_passes, dtype=dtype,
                 precision=precision, callback=callback)
    return _assemble(A, res, r)


def fsvd_dense_reconstruct(out: FSVDResult) -> Array:
    """U diag(s) V^T (tests / retraction materialization)."""
    return (out.U * out.s[None, :]) @ out.V.T


def truncated_svd_errors(A: Operator | LinOp | Array, out) -> dict:
    """The paper's Table-2 error metrics for a computed partial SVD.

    ``out`` is any (U, s, V, ...) result — FSVDResult, RSVDResult or an
    ``repro.api`` Factorization.
    """
    Aop = as_operator(A)
    dense = Aop.A if isinstance(Aop, DenseOp) else None
    # relative error: ||A^T U - V Sigma||_F / ||Sigma||_F
    ATU = Aop.rmatmat(out.U)
    rel = jnp.linalg.norm(ATU - out.V * out.s[None, :]) / jnp.linalg.norm(out.s)
    res = None
    if dense is not None:
        res = jnp.linalg.norm(dense - fsvd_dense_reconstruct(out))
    return {"relative": rel, "residual": res}
