"""Fixed-rank manifold geometry (paper §5.2-5.3).

A point on the rank-r manifold M_r = {W : rank(W) = r} is carried in factored
form ``(U, s, V)`` with ``W = U diag(s) V^T``, U (m,r) and V (n,r) with
orthonormal columns.  Tangent vectors at W (eq. 26) are

    T_W M = { U M V^T + U_p V^T + U V_p^T :  U_p^T U = 0, V_p^T V = 0 }

and are carried as the triple ``(M, U_p, V_p)`` — never dense.  The
Riemannian gradient (eq. 27) is the tangent projection of the Euclidean
gradient; the retraction (eq. 25) is the rank-r truncated SVD of W + xi,
computed by F-SVD on an *implicit* operator (paper Alg 4 line 9): the sum
``U diag(s) V^T + U M V^T + U_p V^T + U V_p^T`` is rank <= 3r, so every
matvec costs O((m+n) r) — the 1e8-entry W of the RSL driver is never
materialized.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.operators import LowRankOp, Operator

Array = jax.Array


class FixedRankPoint(NamedTuple):
    """W = U diag(s) V^T with orthonormal U (m,r), V (n,r)."""

    U: Array
    s: Array
    V: Array

    @property
    def rank(self) -> int:
        return self.s.shape[0]

    @property
    def shape(self) -> tuple[int, int]:
        return self.U.shape[0], self.V.shape[0]


class TangentVector(NamedTuple):
    """xi = U M V^T + U_p V^T + U V_p^T at a FixedRankPoint."""

    M: Array    # (r, r)
    Up: Array   # (m, r), columns orthogonal to U
    Vp: Array   # (n, r), columns orthogonal to V


def random_point(key: jax.Array, m: int, n: int, r: int,
                 dtype=jnp.float32) -> FixedRankPoint:
    """Random rank-r point (paper Alg 4 line 1, then projected to M_r)."""
    ku, kv, ks = jax.random.split(key, 3)
    U, _ = jnp.linalg.qr(jax.random.normal(ku, (m, r), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(kv, (n, r), dtype))
    s = jnp.sort(jnp.abs(jax.random.normal(ks, (r,), dtype)))[::-1] + 0.1
    return FixedRankPoint(U, s, V)


def to_dense(W: FixedRankPoint) -> Array:
    return (W.U * W.s[None, :]) @ W.V.T


def as_linop(W: FixedRankPoint, tangent: Optional[TangentVector] = None,
             tangent_scale: float | Array = 1.0) -> LowRankOp:
    """Pytree operator of W (+ tangent_scale * xi) without densifying.

    ``W + c xi = U (diag(s) + c M) V^T + c U_p V^T + c U V_p^T`` — each term
    is an explicit low-rank factor pair, carried as a ``LowRankOp`` so the
    retraction threads through jit/vmap whole.  (Name kept from the closure
    era; ``as_operator`` is an alias.)
    """
    if tangent is None:
        return LowRankOp(W.U, W.s, W.V.T)
    c = tangent_scale
    mid = jnp.diag(W.s) + c * tangent.M
    ones = jnp.ones_like(W.s)
    return LowRankOp(W.U @ mid, ones, W.V.T,
                     extra=((c * tangent.Up, W.V.T),
                            (W.U, c * tangent.Vp.T)))


as_operator = as_linop


def project_tangent(W: FixedRankPoint, G: Operator | Array) -> TangentVector:
    """Riemannian gradient / tangent projection (eq. 27).

    ``P_W(G) = UU^T G VV^T + (I-UU^T) G VV^T + UU^T G (I-VV^T)`` carried as
    (M, U_p, V_p):  M = U^T G V;  U_p = G V - U M;  V_p = G^T U - V M^T.
    Only needs G through matmats with r columns — G may be any operator
    (e.g. the sparse-sampled Euclidean gradient of the RSL loss, carried as
    a ``LowRankOp``/``SumOp``) or a dense array.
    """
    if hasattr(G, "matmat"):          # Operator / legacy LinOp
        GV = G.matmat(W.V)            # (m, r)
        GtU = G.rmatmat(W.U)          # (n, r)
    else:
        GV = G @ W.V
        GtU = G.T @ W.U
    M = W.U.T @ GV                    # (r, r)
    Up = GV - W.U @ M
    Vp = GtU - W.V @ M.T
    return TangentVector(M, Up, Vp)


def tangent_to_dense(W: FixedRankPoint, xi: TangentVector) -> Array:
    return W.U @ xi.M @ W.V.T + xi.Up @ W.V.T + W.U @ xi.Vp.T


def inner(xi: TangentVector, zeta: TangentVector) -> Array:
    """Riemannian metric <xi, zeta> = tr(xi^T zeta) in the factored carry.

    Cross terms vanish by the orthogonality constraints, so the metric is the
    sum of Frobenius inners of the three components.
    """
    return (jnp.vdot(xi.M, zeta.M) + jnp.vdot(xi.Up, zeta.Up)
            + jnp.vdot(xi.Vp, zeta.Vp))


def norm(xi: TangentVector) -> Array:
    return jnp.sqrt(inner(xi, xi))


def scale(xi: TangentVector, c: float | Array) -> TangentVector:
    return TangentVector(c * xi.M, c * xi.Up, c * xi.Vp)


def add(xi: TangentVector, zeta: TangentVector) -> TangentVector:
    return TangentVector(xi.M + zeta.M, xi.Up + zeta.Up, xi.Vp + zeta.Vp)


def retract_fsvd(W: FixedRankPoint, xi: TangentVector, step: float | Array,
                 *, fsvd_iters: int = 20, key: Optional[jax.Array] = None,
                 reorth_passes: int = 2,
                 warm_start: bool = True) -> FixedRankPoint:
    """Metric-projection retraction (eq. 24/25): rank-r SVD of W + step*xi
    via F-SVD on the implicit rank-<=3r operator — the paper's Alg 4 line 9.

    ``fsvd_iters`` is the paper's inner-iteration knob ("lower iter" 20 vs
    "higher iter" 35, Fig 2).

    ``warm_start=True`` (default) is the *tracking* retraction: the
    operand ``W + step*xi`` is a drift of W, and W's own singular factors
    are sitting in the carry — so the GK solve starts from the
    sigma-weighted blend ``U diag(s)·1`` instead of a fresh random vector.
    The Krylov space then opens inside the already-converged subspace
    (the in-graph analogue of ``repro.api.Session`` tracking), the solve
    is deterministic (no key consumed), and per-step cost drops because
    ``fsvd_iters`` can sit near r instead of 4r.  ``warm_start=False``
    restores the cold keyed start (the paper's literal Alg 4).
    """
    from repro.api import SVDSpec, factorize
    r = W.rank
    op = as_linop(W, xi, step)
    k = min(max(fsvd_iters, r + 2), min(op.shape))
    q1 = (W.U @ W.s) if warm_start else None
    out = factorize(op, SVDSpec(method="fsvd", rank=r, max_iters=k,
                                reorth_passes=reorth_passes), key=key,
                    q1=q1)
    return FixedRankPoint(out.U, out.s, out.V)


def retract_qr(W: FixedRankPoint, xi: TangentVector, step: float | Array
               ) -> FixedRankPoint:
    """Closed-form rank-2r retraction (Vandereycken 2013 §A) — the exact
    baseline for tests.  Builds the 2r x 2r core and does a small dense SVD:

        W + t xi = [U  Q_u] K [V  Q_v]^T,
        K = [[diag(s) + t M,  t R_v^T], [t R_u, 0]]
    """
    t = step
    r = W.rank
    Qu, Ru = jnp.linalg.qr(xi.Up)
    Qv, Rv = jnp.linalg.qr(xi.Vp)
    K = jnp.block([
        [jnp.diag(W.s) + t * xi.M, t * Rv.T],
        [t * Ru, jnp.zeros((r, r), W.s.dtype)],
    ])
    Uk, sk, Vkt = jnp.linalg.svd(K)
    U = jnp.concatenate([W.U, Qu], axis=1) @ Uk[:, :r]
    V = jnp.concatenate([W.V, Qv], axis=1) @ Vkt.T[:, :r]
    return FixedRankPoint(U, sk[:r], V)
