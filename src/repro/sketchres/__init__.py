"""Sketch-resident operators: maintained count/range sketches that turn
unstructured drift into zero-iteration factorizations.

PR 7's update path needs drift as explicit low-rank factors; everything
else (dense or entrywise drift) used to force a refine/restart solve.
This package keeps a :class:`~repro.sketchres.state.SketchState` resident
next to the operand — the Tropp–Webber sketch pair ``Y = AΩ`` /
``Z = ΨᵀA`` plus the test matrices' seeds — and exploits the linearity of
both sketches in ``A``: a COO entry stream folds in at O(nnz·ζ) through
the ``kernels/count_sketch`` scatter-add kernel, dense or factored block
drift at one panel GEMM, and :func:`~repro.sketchres.state.reconstruct`
re-derives the factorization from the panels alone (the PR 9 stabilized-
pinv generalized-Nyström core) without ever touching the operator —
``iterations=0, method="sketch"``.
"""
from repro.sketchres.state import (BUDGET, SketchState, apply_dense_delta,
                                   apply_entries, apply_lowrank_delta,
                                   is_stale, pad_entries, reconstruct,
                                   sketch_operand, staleness_ratio)

__all__ = [
    "BUDGET", "SketchState", "apply_dense_delta", "apply_entries",
    "apply_lowrank_delta", "is_stale", "pad_entries", "reconstruct",
    "sketch_operand", "staleness_ratio",
]
