"""SketchState — a resident generalized-Nyström sketch pair, maintained
incrementally under drift.

Both panels are *linear* in the operand: ``Y = AΩ`` and ``Z = ΨᵀA``, so
for any drift ``A → A + Δ`` the panels of the new operand are exactly
``Y + ΔΩ`` and ``Z + ΨᵀΔ`` — no approximation in the fold itself.  For a
COO entry stream that is a hashed scatter-add: entry ``(i, j, v)`` lands
``v·Ω[j, :]`` on row i of Y and ``v·Ψ[i, :]`` on column j of Z, and with
the hashed-sign ensemble below each of those is ζ signed slot updates —
``kernels/count_sketch.scatter_add`` territory, O(nnz·ζ) per fold.

Test matrices here are the **hashed-sign** (count-sketch / Clarkson–
Woodruff) ensemble: each *source* coordinate ``j`` owns ζ hash slots
``slots[j, s] ∈ [0, d)`` with signs ±1/√ζ.  This is the transpose layout
of ``core.sketch.SparseSignSketch`` (which packs ζ source rows per
*sketch* coordinate, the gather-friendly direction): streaming folds need
to answer "which sketch slots does source j touch?" in O(ζ), which is
exactly what the per-source layout stores.  ``E[TTᵀ] = I`` still holds
(independent signs), so the ensemble is an oblivious subspace embedding
like its gather twin.  Slots/signs are regenerated **in-trace from the
stored PRNG seeds** on every fold/reconstruct — the state ships two keys
instead of two index tables, so checkpoints and cross-process transport
stay panel-sized.

Why a staleness trip at all, when the folds are exact?  Three reasons the
maintained panels can stop being as good as a fresh sketch: (i) the
obliviousness argument needs Ω/Ψ independent of the data — a long
*adaptive* entry stream is correlated with the realized test matrices and
can concentrate mass in directions they under-sample; (ii) under bf16
storage every fold re-rounds the panels, so panel noise grows with folded
mass; (iii) drift can raise the effective rank past what the ``k = r+p``
oversampling covers.  All three grow with the cumulative folded Frobenius
mass, so the state tracks ``folded_mass`` (an upper bound/estimate of
``Σ‖Δ‖_F``) against ``budget·base_norm`` — when it trips, the owner must
re-sketch from the operand (one sweep) instead of trusting the panels.
The per-answer accuracy gate stays the residual probe; the trip is the
a-priori guard that keeps un-probe-able garbage from ever being built.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api.results import Factorization
from repro.core.gk import _store_dtype
from repro.core.operators import as_operator, register_operator
from repro.core.sketch import _panel_dims, nystrom_reconstruct
from repro.kernels.sketch_matvec import ZETA

Array = jax.Array

# default staleness budget: re-sketch once the cumulative folded Frobenius
# mass reaches half the operand's mass at sketch time.  Conservative for
# (i)/(iii) above and far below where bf16 re-rounding (ii) accumulates.
BUDGET = 0.5

# fold batches are padded up to a multiple of this (and then to the next
# power of two) so the plan cache sees O(log E) distinct entry shapes.
_ENTRY_QUANTUM = 64


# ---------------------------------------------------------------------------
# hashed-sign ensemble (per-source-coordinate layout)
# ---------------------------------------------------------------------------

def _hashed(key: Array, n: int, d: int, zeta: int
            ) -> tuple[Array, Array]:
    """slots (n, ζ) in [0, d) and signs (n, ζ) = ±1/√ζ, in-trace."""
    z = max(1, min(zeta, d))
    ki, ks = jax.random.split(key)
    slots = jax.random.randint(ki, (n, z), 0, d, jnp.int32)
    signs = jax.random.rademacher(ks, (n, z), jnp.float32) / jnp.sqrt(
        jnp.asarray(float(z), jnp.float32))
    return slots, signs


def _dense(slots: Array, signs: Array, d: int) -> Array:
    """Materialize T (n, d) f32 — collisions sum, matching the fold."""
    n, z = slots.shape
    T = jnp.zeros((n, d), jnp.float32)
    return T.at[jnp.arange(n)[:, None], slots].add(signs)


@dataclasses.dataclass(frozen=True)
class _HashedSketch:
    """Duck-types ``core.sketch``'s test matrices (shape/dense/tapply) so
    ``Operator.sketch_pass`` — including DenseOp's fused path — accepts
    the streaming ensemble for the initial one-sweep capture."""

    slots: Array
    signs: Array
    d: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.slots.shape[0], self.d)

    def dense(self) -> Array:
        return _dense(self.slots, self.signs, self.d)

    def tapply(self, X: Array) -> Array:
        return jnp.dot(self.dense().T, X.astype(jnp.float32),
                       preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# the state
# ---------------------------------------------------------------------------

@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class SketchState:
    """Resident sketch pair + seeds + staleness odometer (a pytree).

    Y           (m, k) range panel ``AΩ``, storage dtype (bf16 under
                ``precision="bf16"``; every fold accumulates f32).
    Z           (l, n) co-range panel ``ΨᵀA``, storage dtype.
    okey/pkey   PRNG seeds of the hashed-sign Ω (n→k) / Ψ (m→l); the
                slot/sign tables are re-derived in-trace per fold.
    folded_mass () f32 — cumulative ‖Δ‖_F folded since the last sweep
                (exact ℓ2 of the values for entry folds, the ‖ΨᵀΔ‖_F
                sketch estimate for block folds).
    base_norm   () f32 — ‖A‖_F estimate at sweep time (``‖Z‖_F``, the
                same unbiased sketch estimator).
    """

    Y: Array
    Z: Array
    okey: Array
    pkey: Array
    folded_mass: Array
    base_norm: Array
    zeta: int = ZETA
    budget: float = BUDGET
    backend: str = "xla"

    _data_fields = ("Y", "Z", "okey", "pkey", "folded_mass", "base_norm")
    _meta_fields = ("zeta", "budget", "backend")

    @property
    def shape(self) -> tuple[int, int]:
        return (self.Y.shape[0], self.Z.shape[1])

    @property
    def panel_dims(self) -> tuple[int, int]:
        """(k, l) — range / co-range sketch widths."""
        return (self.Y.shape[1], self.Z.shape[0])

    def sketches(self) -> tuple[_HashedSketch, _HashedSketch]:
        """(Ω, Ψ) re-derived from the stored seeds."""
        m, n = self.shape
        k, l = self.panel_dims
        return (_HashedSketch(*_hashed(self.okey, n, k, self.zeta), k),
                _HashedSketch(*_hashed(self.pkey, m, l, self.zeta), l))


def sketch_operand(A, spec, *, key: Array, budget: float = BUDGET,
                   backend: str | None = None) -> SketchState:
    """ONE sweep over the operand → a resident :class:`SketchState` sized
    by the spec's gnystrom panel rule (``k = rank+oversample`` or
    ``sketch_dim``, ``l ≈ 2k``)."""
    A = as_operator(A)
    m, n = A.shape
    k, l = _panel_dims(spec.rank, spec.oversample, spec.sketch_dim, m, n)
    store = _store_dtype(spec.precision,
                         jnp.promote_types(A.dtype, jnp.float32))
    okey, pkey = jax.random.split(key)
    om = _HashedSketch(*_hashed(okey, n, k, ZETA), k)
    ps = _HashedSketch(*_hashed(pkey, m, l, ZETA), l)
    Y, Z = A.sketch_pass(om, ps)                  # the one operator sweep
    Zt = Z.astype(jnp.float32).T                  # (l, n) = ΨᵀA
    base = jnp.linalg.norm(Zt)                    # E‖ΨᵀA‖_F² = ‖A‖_F²
    return SketchState(Y=Y.astype(store), Z=Zt.astype(store),
                       okey=okey, pkey=pkey,
                       folded_mass=jnp.zeros((), jnp.float32),
                       base_norm=base, zeta=ZETA, budget=budget,
                       backend=backend or spec.backend)


# ---------------------------------------------------------------------------
# incremental folds
# ---------------------------------------------------------------------------

def _scatter(rows: Array, cols: Array, vals: Array,
             shape: tuple[int, int], backend: str) -> Array:
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.scatter_add(rows, cols, vals, shape)
    return jnp.zeros(shape, jnp.float32).at[rows, cols].add(
        vals.astype(jnp.float32))


def pad_entries(rows, cols, vals, *, quantum: int = _ENTRY_QUANTUM
                ) -> tuple[Array, Array, Array]:
    """Pad a COO batch to a compile-friendly length (next power-of-two
    multiple of ``quantum``) with (0, 0, 0.0) entries — exact no-ops for
    both the fold and the mass odometer — so streaming callers hit the
    plan cache O(log E) times instead of once per distinct batch size."""
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    cols = jnp.asarray(cols, jnp.int32).reshape(-1)
    vals = jnp.asarray(vals, jnp.float32).reshape(-1)
    E = rows.shape[0]
    target = quantum
    while target < E:
        target *= 2
    pad = target - E
    if pad:
        rows = jnp.pad(rows, (0, pad))
        cols = jnp.pad(cols, (0, pad))
        vals = jnp.pad(vals, (0, pad))
    return rows, cols, vals


def apply_entries(state: SketchState, rows: Array, cols: Array,
                  vals: Array) -> SketchState:
    """Fold a COO entry stream of the drift into both panels, O(nnz·ζ).

    Entry ``(i, j, v)`` contributes ``v·Ω[j, :]`` to ``Y[i, :]`` and
    ``v·Ψ[i, :]`` to ``Z[:, j]`` — with the hashed-sign ensemble each is
    ζ signed slot updates, landed by the count-sketch scatter-add kernel
    (duplicate destinations sum, so repeated coordinates in the stream
    are folded faithfully).  Zero-value entries are exact no-ops, which
    makes :func:`pad_entries` padding safe.
    """
    m, n = state.shape
    k, l = state.panel_dims
    om, ps = state.sketches()
    z = om.slots.shape[1]
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    cols = jnp.asarray(cols, jnp.int32).reshape(-1)
    vals = jnp.asarray(vals, jnp.float32).reshape(-1)
    # ΔY: destination rows are the entries' rows, columns their hashed
    # Ω slots (slot-major concatenation over the ζ expansions).
    dY = _scatter(jnp.tile(rows, z), om.slots[cols].T.reshape(-1),
                  (vals[None, :] * om.signs[cols].T).reshape(-1),
                  (m, k), state.backend)
    # ΔZ: destination rows are the entries' hashed Ψ slots, columns the
    # entries' columns.
    dZ = _scatter(ps.slots[rows].T.reshape(-1), jnp.tile(cols, z),
                  (vals[None, :] * ps.signs[rows].T).reshape(-1),
                  (l, n), state.backend)
    Y = (state.Y.astype(jnp.float32) + dY).astype(state.Y.dtype)
    Z = (state.Z.astype(jnp.float32) + dZ).astype(state.Z.dtype)
    mass = state.folded_mass + jnp.linalg.norm(vals)
    return dataclasses.replace(state, Y=Y, Z=Z, folded_mass=mass)


def _apply_block(state: SketchState, dop, mass: Array) -> SketchState:
    om, ps = state.sketches()
    dY = dop.matmat(om.dense())                       # (m, k) = ΔΩ
    dZ = dop.rmatmat(ps.dense()).astype(jnp.float32).T  # (l, n) = ΨᵀΔ
    Y = (state.Y.astype(jnp.float32) + dY.astype(jnp.float32)
         ).astype(state.Y.dtype)
    Z = (state.Z.astype(jnp.float32) + dZ).astype(state.Z.dtype)
    return dataclasses.replace(state, Y=Y, Z=Z,
                               folded_mass=state.folded_mass + mass)


def apply_dense_delta(state: SketchState, D: Array) -> SketchState:
    """Fold a dense (m, n) drift block: one panel GEMM per sketch, exact
    Frobenius mass on the odometer."""
    D = jnp.asarray(D)
    return _apply_block(state, as_operator(D),
                        jnp.linalg.norm(D.astype(jnp.float32)))


def apply_lowrank_delta(state: SketchState, dop) -> SketchState:
    """Fold a factored drift (``LowRankOp`` or any operator) without
    materializing it: two factored panel products.  The mass odometer
    takes the ``‖ΨᵀΔ‖_F`` sketch estimate (same estimator as
    ``base_norm``, no materialization)."""
    dop = as_operator(dop)
    om, ps = state.sketches()
    dY = dop.matmat(om.dense())
    dZ = dop.rmatmat(ps.dense()).astype(jnp.float32).T
    Y = (state.Y.astype(jnp.float32) + dY.astype(jnp.float32)
         ).astype(state.Y.dtype)
    Z = (state.Z.astype(jnp.float32) + dZ).astype(state.Z.dtype)
    return dataclasses.replace(
        state, Y=Y, Z=Z,
        folded_mass=state.folded_mass + jnp.linalg.norm(dZ))


# ---------------------------------------------------------------------------
# staleness + reconstruction
# ---------------------------------------------------------------------------

def staleness_ratio(state: SketchState) -> Array:
    """Folded mass over the coverage budget — ≥ 1.0 means stale."""
    return state.folded_mass / jnp.maximum(
        jnp.asarray(state.budget, jnp.float32) * state.base_norm, 1e-30)


def is_stale(state: SketchState) -> Array:
    """True once the cumulative folded mass exceeds the coverage budget;
    owners must re-sketch from the operand instead of reconstructing."""
    return staleness_ratio(state) >= 1.0


def reconstruct(state: SketchState, spec) -> Factorization:
    """Zero-sweep factorization from the maintained panels: the PR 9
    stabilized-pinv generalized-Nyström core solve on ``(Y, Z, ΨᵀY)``.
    Returns ``iterations=0, method="sketch"`` — by construction nothing
    here touches the operator, so callers MUST gate the answer (residual
    probe + :func:`is_stale`) before serving it."""
    _, ps = state.sketches()
    Yf = state.Y.astype(jnp.float32)
    C = ps.tapply(Yf)                             # (l, k) = ΨᵀY, no touch
    U, s, Vt = nystrom_reconstruct(Yf, state.Z, C)
    r = min(spec.rank, s.shape[0])
    return Factorization(U[:, :r], s[:r], Vt[:r, :].T,
                         iterations=jnp.asarray(0, jnp.int32),
                         breakdown=jnp.asarray(False), method="sketch")


__all__ = [
    "BUDGET", "SketchState", "apply_dense_delta", "apply_entries",
    "apply_lowrank_delta", "is_stale", "pad_entries", "reconstruct",
    "sketch_operand", "staleness_ratio",
]
