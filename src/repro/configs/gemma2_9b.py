"""gemma2-9b — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local+global alternating attention, attention/final logit softcaps, GeGLU,
sandwich (post) norms, tied embeddings.  [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_theta=10000.0,
    sliding_window=4096,
    attn_pattern=("local", "global"),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="gelu",            # GeGLU
    norm="rmsnorm",
    post_norm=True,
    tie_embeddings=True,
    embedding_scale=True,
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
)
