"""olmoe-1b-7b — 16L d_model=2048 16H (kv=16) vocab=50304, MoE 64 experts top-8.

64 experts, top-8 token-choice routing, d_ff_expert=1024, SwiGLU experts.
[arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                 # = d_ff_expert (all layers MoE)
    vocab_size=50304,
    rope_theta=10000.0,
    attn_pattern=("global",),
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=64,
        top_k=8,
        d_ff_expert=1024,
        num_shared_experts=0,
        capacity_factor=1.25,
    ),
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)
