"""Config registry: every assigned architecture is selectable by id."""
from __future__ import annotations

from repro.configs.base import (
    CheckpointConfig,
    EncDecConfig,
    FsvdConfig,
    HybridConfig,
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OptimConfig,
    RunConfig,
    RuntimeConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    VLMConfig,
)

from repro.configs.gemma2_9b import CONFIG as _gemma2_9b
from repro.configs.gemma_7b import CONFIG as _gemma_7b
from repro.configs.stablelm_1_6b import CONFIG as _stablelm_1_6b
from repro.configs.starcoder2_15b import CONFIG as _starcoder2_15b
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe_1b_7b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.llava_next_34b import CONFIG as _llava_next_34b
from repro.configs.whisper_base import CONFIG as _whisper_base
from repro.configs.mamba2_780m import CONFIG as _mamba2_780m
from repro.configs.zamba2_1_2b import CONFIG as _zamba2_1_2b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _gemma2_9b,
        _gemma_7b,
        _stablelm_1_6b,
        _starcoder2_15b,
        _olmoe_1b_7b,
        _deepseek_v2_236b,
        _llava_next_34b,
        _whisper_base,
        _mamba2_780m,
        _zamba2_1_2b,
    ]
}

# Shape-cell applicability (see DESIGN.md §4).  long_500k requires
# sub-quadratic sequence mixing -> SSM / hybrid only.
SUBQUADRATIC = {"mamba2-780m", "zamba2-1.2b"}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Return (applicable, reason-if-not) for an (arch, shape) dry-run cell."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{arch} has full/global attention layers")
    return True, ""


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS", "SHAPES", "SUBQUADRATIC", "cell_applicable", "get_arch", "get_shape",
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "EncDecConfig", "VLMConfig", "ShapeConfig", "FsvdConfig", "OptimConfig",
    "CheckpointConfig", "RuntimeConfig", "MeshConfig", "RunConfig",
]
