"""mamba2-780m — 48L d_model=1536 attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality) blocks: expand=2 (d_inner=3072), head_dim=64
(48 ssm heads), chunked matmul scan. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_pattern=(),
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m; unverified",
)
