"""Configuration dataclasses for the KrylovLR framework.

Every assigned architecture is expressed as a ``ModelConfig``; the training /
serving / dry-run drivers consume ``RunConfig`` which composes the model with
mesh, optimizer, data and fault-tolerance settings.  Configs are plain frozen
dataclasses so they hash, repr and serialize (``to_dict``) trivially.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def _asdict(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj):
        return {f.name: _asdict(getattr(obj, f.name)) for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_asdict(x) for x in obj]
    return obj


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block settings (token-choice top-k routing)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # layers [moe_start, num_layers) use MoE every `moe_every` layers
    moe_start_layer: int = 0
    moe_every: int = 1


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention settings."""

    kv_lora_rank: int
    q_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block settings."""

    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone with a SHARED attention block woven in."""

    attn_every: int = 6          # apply the shared attn+mlp block every N ssm layers
    shared_attn_d_ff: int = 8192


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder settings (frontend is a stub)."""

    encoder_layers: int = 6
    # the conv frontend is stubbed: input_specs() provides precomputed frame
    # embeddings of shape (batch, frames, d_model)
    frontend: str = "stub"


@dataclass(frozen=True)
class VLMConfig:
    """LLaVA-style VLM settings (vision tower is a stub)."""

    num_image_tokens: int = 576   # anyres base tile -> stubbed patch embeddings
    frontend: str = "stub"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default: d_model // num_heads
    # --- attention ---
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0
    sliding_window: Optional[int] = None
    # pattern of layer attention kinds, tiled over depth, e.g. ("local","global")
    attn_pattern: Tuple[str, ...] = ("global",)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    # --- mlp / norm / embedding ---
    mlp_act: str = "silu"          # silu -> SwiGLU, gelu -> GeGLU, gelu_mlp -> plain
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    post_norm: bool = False        # gemma2 sandwich norms
    tie_embeddings: bool = True
    embedding_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # --- attention / loss memory knobs (hillclimb levers; see §Perf) ---
    attn_impl: str = "auto"        # full | chunked | online | auto
    q_chunk: int = 1024            # query/kv-chunk size for chunked/online
    ce_chunk: int = 1024           # seq-chunk for the cross-entropy/LM head
    cache_update: str = "blend"    # blend | dus (decode-bandwidth lever)
    # pin the residual stream to batch sharding at every block boundary —
    # without this GSPMD may silently replicate activations over "data"
    # inside attention (observed: 16x logits blow-up; see §Perf)
    pin_activations: bool = False
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat_policy: str = "nothing"  # nothing | dots | none  (hillclimb knob)
    source: str = ""               # provenance of the config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def to_dict(self) -> dict:
        return _asdict(self)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: dict[str, Any] = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // max(self.num_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                num_shared_experts=min(self.moe.num_shared_experts, 1), d_ff_shared=64)
        if self.mla is not None:
            small["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                     qk_nope_head_dim=16, qk_rope_head_dim=16,
                                     v_head_dim=32)
            small["head_dim"] = None
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                               chunk_size=32)
        if self.hybrid is not None:
            small["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2,
                                                  shared_attn_d_ff=256)
        if self.encdec is not None:
            small["encdec"] = dataclasses.replace(self.encdec, encoder_layers=2)
        if self.vlm is not None:
            small["vlm"] = dataclasses.replace(self.vlm, num_image_tokens=8)
        if self.sliding_window is not None:
            small["sliding_window"] = 16
        small["dtype"] = "float32"
        small["param_dtype"] = "float32"
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class FsvdConfig:
    """Settings for the paper's technique inside the framework."""

    max_iters: int = 64            # k in Alg 1/2
    breakdown_eps: float = 1e-8    # epsilon in Alg 1/3
    reorth: int = 2                # CGS passes (2 = "twice is enough")
    # gradient compression
    compress_gradients: bool = False
    compression_rank: int = 8
    compression_min_dim: int = 256   # only compress 2D grads with min(m,n) >= this
    error_feedback: bool = True
    # telemetry
    rank_telemetry: bool = False
    rank_telemetry_every: int = 100


@dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"       # cosine | linear | constant


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/krylovlr_ckpt"
    every_steps: int = 50
    keep: int = 3
    async_write: bool = True


@dataclass(frozen=True)
class RuntimeConfig:
    nan_guard: bool = True
    max_nan_skips: int = 10
    straggler_zscore: float = 3.0
    straggler_window: int = 50
    log_every: int = 10


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # overridable for tests / elastic runs; None -> production shape
    shape: Optional[Tuple[int, ...]] = None
    axes: Optional[Tuple[str, ...]] = None


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    fsvd: FsvdConfig = field(default_factory=FsvdConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    checkpoint: CheckpointConfig = field(default_factory=CheckpointConfig)
    runtime: RuntimeConfig = field(default_factory=RuntimeConfig)
    seed: int = 0

    def to_dict(self) -> dict:
        return _asdict(self)
