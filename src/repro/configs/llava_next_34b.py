"""llava-next-34b — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

VLM: Yi-34B-like language backbone; anyres vision tower is a STUB — input_specs
provides precomputed patch embeddings (batch, num_image_tokens, d_model).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
    attn_pattern=("global",),
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    vlm=VLMConfig(num_image_tokens=576, frontend="stub"),
    source="hf:llava-hf/llava-v1.6-34b-hf; unverified",
)
