"""stablelm-1.6b — 24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.

SwiGLU MLP, partial rotary (25%), LayerNorm, untied embeddings.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_theta=10000.0,
    partial_rotary_factor=0.25,
    attn_pattern=("global",),
    qkv_bias=True,
    mlp_act="silu",            # SwiGLU
    norm="layernorm",
    tie_embeddings=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
