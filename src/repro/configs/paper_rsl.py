"""The paper's own application config: Riemannian similarity learning (RSL).

Learns W in R^{d1 x d2} with rank(W) = r between two data domains (the paper
uses MNIST d1=784 and USPS d2=256); scaled variants up to d1=d2=10000
(W = 1e8 params) are used by the end-to-end example driver.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class RSLConfig:
    d1: int = 784              # MNIST pixel dim
    d2: int = 256              # USPS pixel dim
    rank: int = 5              # manifold rank (paper: 5)
    batch_size: int = 64
    lr: float = 1e-2
    weight_decay: float = 1e-4  # lambda in Alg 4 line 6
    steps: int = 2000
    fsvd_iters: int = 20       # "lower iter" = 20, "higher iter" = 35 (paper Fig 2)
    loss: str = "hinge"        # hinge | logistic
    seed: int = 0


CONFIG = RSLConfig()
CONFIG_100M = RSLConfig(d1=10000, d2=10000, rank=5, batch_size=32, steps=300,
                        fsvd_iters=20)
