"""gemma-7b — 28L d_model=3072 16H (MHA kv=16) d_ff=24576 vocab=256000.

GeGLU, head_dim=256, tied embeddings, embedding scaling. [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10000.0,
    attn_pattern=("global",),
    mlp_act="gelu",            # GeGLU
    norm="rmsnorm",
    tie_embeddings=True,
    embedding_scale=True,
    source="arXiv:2403.08295; hf:google/gemma-7b",
)
