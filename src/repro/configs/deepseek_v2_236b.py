"""deepseek-v2-236b — 60L d_model=5120 128H vocab=102400, MLA + MoE 160e top-6.

MLA with kv_lora_rank=512 (q_lora_rank=1536, qk nope/rope head dims 128/64,
v_head_dim=128); MoE: 2 shared + 160 routed experts, top-6, d_ff_expert=1536;
first layer dense with d_ff=12288. [arXiv:2405.04434; hf]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: kv heads == heads after up-projection
    d_ff=12288,                # dense layers (layer 0)
    vocab_size=102400,
    rope_theta=10000.0,
    attn_pattern=("global",),
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=False,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        d_ff_shared=1536,
        capacity_factor=1.25,
        moe_start_layer=1,     # layer 0 is dense in DeepSeek-V2
        moe_every=1,
    ),
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)
