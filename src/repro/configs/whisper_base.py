"""whisper-base — enc-dec, 6L each, d_model=512 8H d_ff=2048 vocab=51865.

Conv frontend is a STUB: input_specs provides precomputed frame embeddings
(batch, frames, d_model).  Sinusoidal-free simplification: learned positions
replaced by RoPE-free absolute embeddings in this backbone reproduction.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    attn_pattern=("global",),
    mlp_act="gelu_mlp",
    norm="layernorm",
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=6, frontend="stub"),
    source="arXiv:2212.04356; hf:openai/whisper-base; unverified",
)
