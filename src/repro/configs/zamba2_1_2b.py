"""zamba2-1.2b — 38L d_model=2048 hybrid Mamba2 + shared attention, vocab=32000.

Mamba2 backbone (ssm_state=64) with a single SHARED attention+MLP block
(32H kv=32, d_ff=8192) applied every 6 SSM layers. [arXiv:2411.15242; hf]
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    rope_theta=10000.0,
    attn_pattern=("global",),
    mlp_act="gelu_mlp",
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_attn_d_ff=8192),
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)
