"""starcoder2-15b — 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.

GQA, RoPE, plain-GELU MLP, LayerNorm, biases. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100000.0,
    attn_pattern=("global",),
    qkv_bias=True,
    mlp_act="gelu_mlp",        # plain 2-matrix GELU MLP
    norm="layernorm",
    tie_embeddings=False,
    source="arXiv:2402.19173; hf:bigcode/starcoder2-15b",
)
