"""JAX version-compat shims.

The repo targets the modern sharding API (``jax.sharding.AxisType``,
``jax.shard_map``, ``jax.sharding.get_abstract_mesh``); older installs
(<= 0.4.x) spell these differently or lack them entirely.  Everything
version-sensitive funnels through this module so call sites stay on the
modern spelling:

  * ``AxisType``            — ``None`` when the install has no axis types.
  * ``make_mesh(shape, axes)`` — passes ``axis_types=(Auto, ...)`` only when
                              the installed ``jax.make_mesh`` accepts it.
  * ``shard_map(...)``      — modern kwargs (``check_vma``, ``axis_names``)
                              translated to the legacy ``check_rep`` /
                              ``auto`` spelling when needed.
  * ``manual_axis_names()`` — axis names Manual in the current trace context
                              (empty set when the install can't tell).
"""
from __future__ import annotations

import functools
import inspect
from typing import Optional, Sequence, Tuple

import jax


class ReproDeprecationWarning(DeprecationWarning):
    """Deprecation of a *repro* entry point (shims kept for API compat).

    A dedicated subclass so CI can escalate exactly the in-repo shims to
    errors (``-W error::repro.compat.ReproDeprecationWarning``) without
    also erroring on third-party DeprecationWarnings — the plain-category
    ``module`` filter cannot do this, because our shims warn with
    ``stacklevel=2`` and therefore attribute the warning to the *caller's*
    module, not ``repro.*``.
    """

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


@functools.lru_cache(maxsize=None)
def _make_mesh_takes_axis_types() -> bool:
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover
        return False


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              *, devices=None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types when the install supports them,
    a plain ``Mesh`` otherwise."""
    kw = {} if devices is None else {"devices": devices}
    if AxisType is not None and _make_mesh_takes_axis_types():
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes), **kw)
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across the constructor-signature change
    (new: ``(axis_sizes, axis_names)``; old: one ``((name, size), ...)``
    tuple)."""
    from jax.sharding import AbstractMesh  # noqa: PLC0415
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def manual_axis_names() -> set:
    """Axis names that are Manual in the current tracing context."""
    if AxisType is None:
        return set()
    try:
        cur = jax.sharding.get_abstract_mesh()
        return {name for name, t in zip(cur.axis_names, cur.axis_types)
                if t == AxisType.Manual}
    except Exception:  # noqa: BLE001 - absent API / not tracing
        return set()


_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Optional[set] = None):
    """Modern ``jax.shard_map`` signature on any supported jax.

    ``axis_names`` is the set of mesh axes the body is Manual over (all axes
    when omitted); legacy installs express the same thing through the
    complementary ``auto`` set and spell ``check_vma`` as ``check_rep``.
    """
    if _NATIVE_SHARD_MAP is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _NATIVE_SHARD_MAP(f, **kw)
    from jax.experimental.shard_map import shard_map as _legacy  # noqa: PLC0415
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _legacy(f, **kw)


def _polyfill_shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                        check_vma=None, check_rep=None, auto=None,
                        axis_names=None, **ignored):
    """Signature-tolerant ``jax.shard_map`` polyfill: accepts positional
    (f, mesh, in_specs, out_specs), the modern ``check_vma``/``axis_names``
    kwargs AND the legacy ``check_rep``/``auto`` spellings, so external
    feature-detection of ``hasattr(jax, 'shard_map')`` keeps working."""
    if check_vma is None:
        check_vma = True if check_rep is None else check_rep
    if axis_names is None and auto is not None:
        axis_names = frozenset(mesh.axis_names) - frozenset(auto)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=check_vma, axis_names=axis_names)


if _NATIVE_SHARD_MAP is None:
    # polyfill the modern top-level spelling so downstream code (and tests)
    # can uniformly write ``jax.shard_map(f, mesh=..., check_vma=...)``.
    jax.shard_map = _polyfill_shard_map
