"""Fused Lanczos matvec Pallas kernels: ``u = A p − α q`` and ``v = Aᵀ q − β p``.

TPU adaptation of the GK half-iteration (paper Alg 1 lines 5 / 12).  The
operation is HBM-bandwidth-bound (arithmetic intensity ≈ 1 FLOP/byte of A),
so the kernel's job is: stream A through VMEM exactly once, in MXU-aligned
``(bm, bn)`` tiles, accumulate in f32, and *fuse* the three-term-recurrence
subtraction so the result vector is written once (no separate axpy pass over
HBM).

Vectors are carried as ``(len, 1)`` columns — TPU Pallas wants ≥2-D refs and
the lane dimension maps onto the 128-wide VPU.

Grid convention: ``(m/bm, n/bn)`` with the contraction axis *innermost* so a
single output tile stays resident in VMEM across its accumulation steps
(sequential TPU grid).  For ``Aᵀ q`` the grid is ``(n/bn, m/bm)`` and each
A tile is transposed *inside* VMEM (free on the MXU via dimension numbers) —
A keeps one layout in HBM for both directions, which is what lets the GK
loop stream the same matrix forward and backward without a stored transpose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# default tiles: (256, 512) f32 = 512 KiB of A per step — comfortably inside
# a ~16 MiB VMEM alongside the vector tiles and accumulator.
BM, BN = 256, 512


def _mv_kernel(a_ref, p_ref, y_ref, alpha_ref, o_ref):
    """One (i, j) step of u = A p − α y; j is the contraction index."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = -alpha_ref[0, 0] * y_ref[...].astype(jnp.float32)

    o_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                          p_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def _rmv_kernel(a_ref, q_ref, y_ref, beta_ref, o_ref):
    """One (i, j) step of v = Aᵀ q − β y; grid is (n/bn, m/bm)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = -beta_ref[0, 0] * y_ref[...].astype(jnp.float32)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), q_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),   # contract A rows: Aᵀ q
        preferred_element_type=jnp.float32)


def matvec_fused(A: Array, p: Array, y: Array, alpha: Array, *,
                 bm: int = BM, bn: int = BN, interpret: bool = True) -> Array:
    """u = A @ p − alpha * y.  A: (m, n); p: (n, 1); y: (m, 1) — f32 out.

    m, n must be multiples of (bm, bn); ``ops.py`` pads.
    """
    m, n = A.shape
    assert m % bm == 0 and n % bn == 0, (A.shape, bm, bn)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _mv_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(A, p, y, alpha)


def rmatvec_fused(A: Array, q: Array, y: Array, beta: Array, *,
                  bm: int = BM, bn: int = BN, interpret: bool = True) -> Array:
    """v = Aᵀ @ q − beta * y.  A: (m, n); q: (m, 1); y: (n, 1) — f32 out."""
    m, n = A.shape
    assert m % bm == 0 and n % bn == 0, (A.shape, bm, bn)
    beta = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _rmv_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (j, i)),
            pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(A, q, y, beta)
