"""COO scatter-add Pallas kernel — the repo's first scatter kernel.

Every prior kernel here is gather-only by construction: the ELL packs of
``sparse_matvec``/``sketch_matvec`` pin each *destination* to a static row
so grid steps only ever read at data-dependent indices.  A streamed COO
delta breaks that trick — entry ``e`` lands at ``(rows[e], cols[e])``,
the destination itself is data, and duplicate coordinates must **sum**
(count-sketch semantics: hash collisions accumulate, they don't clobber).

The kernel therefore owns the whole accumulator panel across the grid and
lowers the scatter to an on-chip one-hot contraction: each grid step takes
a block of ``be`` entries, expands the destination coordinates against a
broadcasted iota into one-hot matrices ``R`` (be, m) and ``H`` (be, d),
folds the values into ``H``, and accumulates ``o += Rᵀ H`` — one MXU
matmul per block instead of ``be`` serialized dynamic-index writes, which
TPUs cannot vectorize.  Duplicates inside a block meet in the contraction
over the entry axis; duplicates across blocks meet in the ``+=`` on the
resident output (TPU grids are sequential, so the accumulation is sound).

This is the fold primitive of ``repro.sketchres``: a hashed count-sketch
update expands each operand entry into ζ signed slot entries and lands
them here.  Padding entries are (row 0, col 0, value 0) — exactly zero
contribution — so the ``ops.py`` wrapper's block-multiple padding is exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# default entries-per-grid-step; the one-hot expansions are (be, m) and
# (be, d), so be also sets the sublane extent of the MXU contraction.
BE = 128


def _scatter_kernel(r_ref, c_ref, v_ref, o_ref):
    """One entry block: o += Σ_e vals[e] · e_rows[e] e_cols[e]ᵀ.

    The output panel maps to the same block at every grid step (index map
    ``lambda i: (0, 0)``); step 0 zero-initializes it and every step
    accumulates, so the kernel is a reduction over entry blocks.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = r_ref[...].reshape(-1)                       # (be,) int32
    cols = c_ref[...].reshape(-1)
    vals = v_ref[...].reshape(-1).astype(jnp.float32)
    be = rows.shape[0]
    m, d = o_ref.shape
    # destination one-hots: R[e, i] = [rows[e] == i], H[e, j] likewise with
    # the entry value folded in — duplicates sum in the e-contraction.
    R = (rows[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (be, m), 1)).astype(jnp.float32)
    H = (cols[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (be, d), 1)).astype(jnp.float32) * vals[:, None]
    o_ref[...] += jnp.dot(R.T, H, preferred_element_type=jnp.float32)


def scatter_add(rows: Array, cols: Array, vals: Array,
                shape: tuple[int, int], *, be: int = BE,
                interpret: bool = True) -> Array:
    """Dense (m, d) f32 accumulation of a COO entry stream.

    rows/cols: (E,) int32 in [0, m) / [0, d); vals: (E,).  E must be a
    multiple of ``be`` (``ops.py`` pads with zero-value entries at (0, 0),
    which contribute exactly 0); duplicate coordinates sum.
    """
    E = rows.shape[0]
    assert E % be == 0, (E, be)
    m, d = shape
    return pl.pallas_call(
        _scatter_kernel,
        grid=(E // be,),
        in_specs=[
            pl.BlockSpec((be, 1), lambda i: (i, 0)),
            pl.BlockSpec((be, 1), lambda i: (i, 0)),
            pl.BlockSpec((be, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(rows.reshape(E, 1), cols.reshape(E, 1), vals.reshape(E, 1))
