"""Fused GK iteration-step Pallas kernels (paper Alg 1 lines 5-8 / 12-14).

One GK half-iteration is ``u = A p − α y`` followed by CGS2 against the
basis ``Q`` and a norm.  The unfused composition (``gk_matvec`` +
``reorth`` + a jnp norm) round-trips the candidate vector through HBM
between every stage and reads Q four times per CGS2 step (two ``Qᵀv``
products, two ``v − Qc`` projections).  These kernels pipeline the step so
the candidate never leaves VMEM between the matvec and the first CGS
product, and Q is read the theoretical minimum three times per CGS2 step:

  stage 1  ``mv_qtv``     streams A row-block-wise, accumulates the matvec
                          into the resident output tile and — on the last
                          contraction step, while the tile is still in
                          VMEM — accumulates the first CGS coefficient
                          product ``c₁ = Qᵀu``.          (reads A once, Q once)
  stage 2  ``proj_qtv``   one pass over Q: applies ``w = u − Q c₁`` and
                          accumulates ``c₂ = Qᵀw`` from the tile just
                          computed.                       (reads Q once)
  stage 3  ``proj_norm``  one pass over Q: applies ``v = w − Q c₂`` and
                          accumulates ``‖v‖²`` in the epilogue, so the
                          normalization scalar needs no extra pass.

CGS^p generalizes as stage1 → (p−1)× stage2 → stage3.  The reverse
half-iteration (``v = Aᵀ q − β y`` against the right basis P) shares
stages 2/3; only stage 1 differs (``rmv_qtv`` transposes A tiles in VMEM,
same trick as ``gk_matvec.rmatvec_fused``).

Mixed precision falls out for free: bases and A may be stored bf16 in HBM
(half the bytes of the bandwidth-bound streams); every tile is upcast in
VMEM and all dots/reductions accumulate f32 (``preferred_element_type``).

Vectors ride as ``(len, 1)`` columns; coefficient vectors as ``(k, 1)``
with a constant output index so they stay VMEM-resident across the whole
grid (same convention as ``reorth.qtv``).  ``ops.py`` pads shapes to tile
multiples — zero rows/cols are exact for every stage here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# The fused pipeline is the only kernel in flight, so it takes a much
# taller row block than gk_matvec's (256, 512): fewer grid steps amortize
# per-step overhead and the basis row-block is reused across the whole
# contraction.  (2048, 512) f32 = 4 MiB of A per step + a (2048, k≤512)
# basis block ≤ 4 MiB — inside a ~16 MiB VMEM with double buffering.
# Drop ``bm`` when k pushes past ~512 columns.
BM, BN = 2048, 512


def _rows_dot(a: Array, b: Array) -> Array:
    """aᵀ b contracting the row (sublane) axis, f32 accumulate."""
    return jax.lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _mv_qtv_kernel(a_ref, p_ref, y_ref, alpha_ref, q_ref, u_ref, c_ref):
    """Grid (m/bm, n/bn), contraction j innermost: u tile stays resident."""
    i, j = pl.program_id(0), pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init_u():
        u_ref[...] = -alpha_ref[0, 0] * y_ref[...].astype(jnp.float32)

    u_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                          p_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    # the finished u tile is still in VMEM — take its CGS contribution now
    @pl.when(j == nj - 1)
    def _acc_c():
        c_ref[...] += _rows_dot(q_ref[...], u_ref[...])


def _rmv_qtv_kernel(a_ref, q_ref, y_ref, beta_ref, pb_ref, v_ref, c_ref):
    """Reverse direction: grid (n/bn, m/bm); A tiles transpose in VMEM."""
    i, j = pl.program_id(0), pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init_v():
        v_ref[...] = -beta_ref[0, 0] * y_ref[...].astype(jnp.float32)

    v_ref[...] += _rows_dot(a_ref[...], q_ref[...])        # Aᵀ q tile

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(j == nj - 1)
    def _acc_c():
        c_ref[...] += _rows_dot(pb_ref[...], v_ref[...])


def _proj_qtv_kernel(u_ref, q_ref, cin_ref, w_ref, cout_ref):
    """w = u − Q c (applied) and c' = Qᵀ w (accumulated) in one Q pass."""
    i = pl.program_id(0)
    w = (u_ref[...].astype(jnp.float32)
         - jnp.dot(q_ref[...].astype(jnp.float32), cin_ref[...],
                   preferred_element_type=jnp.float32))
    w_ref[...] = w

    @pl.when(i == 0)
    def _init_c():
        cout_ref[...] = jnp.zeros_like(cout_ref)

    cout_ref[...] += _rows_dot(q_ref[...], w)


def _proj_norm_kernel(u_ref, q_ref, cin_ref, v_ref, nrm_ref):
    """v = u − Q c and the ‖v‖² epilogue in one Q pass."""
    i = pl.program_id(0)
    v = (u_ref[...].astype(jnp.float32)
         - jnp.dot(q_ref[...].astype(jnp.float32), cin_ref[...],
                   preferred_element_type=jnp.float32))
    v_ref[...] = v

    @pl.when(i == 0)
    def _init_n():
        nrm_ref[...] = jnp.zeros_like(nrm_ref)

    nrm_ref[0, 0] += jnp.sum(v * v)


def mv_qtv(A: Array, p: Array, y: Array, alpha: Array, Q: Array, *,
           bm: int = BM, bn: int = BN,
           interpret: bool = True) -> tuple[Array, Array]:
    """(u, c) = (A p − α y, Qᵀ u) in one streaming pass over A and Q.

    A: (m, n); p: (n, 1); y: (m, 1); Q: (m, k) → u (m, 1), c (k, 1) f32.
    m, n must be tile multiples (``ops.py`` pads); k is never tiled.
    """
    m, n = A.shape
    k = Q.shape[1]
    assert m % bm == 0 and n % bn == 0, (A.shape, bm, bn)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _mv_qtv_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(A, p, y, alpha, Q)


def rmv_qtv(A: Array, q: Array, y: Array, beta: Array, P: Array, *,
            bm: int = BM, bn: int = BN,
            interpret: bool = True) -> tuple[Array, Array]:
    """(v, c) = (Aᵀ q − β y, Pᵀ v).  A: (m, n); q: (m, 1); y, v: (n, 1);
    P: (n, k) → v (n, 1), c (k, 1) f32."""
    m, n = A.shape
    k = P.shape[1]
    assert m % bm == 0 and n % bn == 0, (A.shape, bm, bn)
    beta = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _rmv_qtv_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (j, i)),
            pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(A, q, y, beta, P)


def proj_qtv(u: Array, Q: Array, c: Array, *, bm: int = BM,
             interpret: bool = True) -> tuple[Array, Array]:
    """(w, c') = (u − Q c, Qᵀ w) in one pass over Q.
    u: (m, 1); Q: (m, k); c: (k, 1)."""
    m, k = Q.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _proj_qtv_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, Q, c)


def proj_norm(u: Array, Q: Array, c: Array, *, bm: int = BM,
              interpret: bool = True) -> tuple[Array, Array]:
    """(v, ‖v‖²) = (u − Q c, Σ v²) in one pass over Q.
    u: (m, 1); Q: (m, k); c: (k, 1) → v (m, 1), nrm2 (1, 1)."""
    m, k = Q.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _proj_norm_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, Q, c)
