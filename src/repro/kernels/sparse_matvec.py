"""Row-blocked ELL sparse matvec Pallas kernel: ``y = A x`` for sparse A.

Sparse GK matvecs are gather-bound, not FLOP-bound, so the kernel's job is
layout: pack the COO triplets into padded ELL rows — ``vals``/``cols`` of
shape (m, L) with L = max row population, zero-padded (slot value 0 at
column 0 contributes exactly 0) — then stream row blocks through VMEM while
the dense vector x stays resident.  Each grid step owns ``bm`` rows:

    y[i] = Σ_s vals[i, s] * x[cols[i, s]]

i.e. a VPU multiply + lane reduction over an (bm, L) tile with a gather from
the resident x.  The transpose direction reuses the same kernel on the ELL
pack of Aᵀ (built once, host-side) — scatter never appears, which is what
keeps the kernel TPU-shaped.

The pack is value-dependent (L = max nnz per row), so ``ell_pack`` runs
host-side on concrete coordinates (NumPy) — done once at ``SparseOp``
construction, never under a trace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array

# default tile: 128 rows per grid step; the slot dimension is lane-padded by
# ops.py to a multiple of BL so (bm, L) tiles sit on f32 layout boundaries.
BM, BL = 128, 128


def ell_pack(data, indices, spshape) -> tuple[Array, Array]:
    """Pack COO triplets into padded ELL rows (host-side, concrete arrays).

    Returns ``(vals (m, L), cols (m, L))`` with L = max row population
    (min 1).  Empty slots carry (value 0, column 0) — exact, because
    ``0 * x[0] == 0``.  Duplicate coordinates keep separate slots (sum
    semantics, matching BCOO).
    """
    m, _ = spshape
    d = np.asarray(data)
    idx = np.asarray(indices)
    rows, cols = idx[:, 0].astype(np.int64), idx[:, 1].astype(np.int64)
    counts = np.bincount(rows, minlength=m)
    L = max(int(counts.max(initial=0)), 1)
    vals = np.zeros((m, L), d.dtype)
    colp = np.zeros((m, L), np.int32)
    order = np.argsort(rows, kind="stable")
    r_sorted = rows[order]
    offsets = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(r_sorted.shape[0]) - offsets[r_sorted]
    vals[r_sorted, slot] = d[order]
    colp[r_sorted, slot] = cols[order]
    return jnp.asarray(vals), jnp.asarray(colp)


def _spmv_kernel(v_ref, c_ref, x_ref, o_ref):
    """One row block: o = Σ_slots vals ⊙ x[cols]  (f32 accumulate)."""
    x = x_ref[...][:, 0].astype(jnp.float32)
    gathered = jnp.take(x, c_ref[...], axis=0)          # (bm, L)
    o_ref[...] = jnp.sum(v_ref[...].astype(jnp.float32) * gathered,
                         axis=1, keepdims=True)


def sparse_matvec(vals: Array, cols: Array, x: Array, *,
                  bm: int = BM, interpret: bool = True) -> Array:
    """y = A @ x with A in padded-ELL rows.  vals/cols: (m, L); x: (n, 1).

    m must be a multiple of bm (``ops.py`` pads rows with empty slots).
    """
    m, L = vals.shape
    assert m % bm == 0, (vals.shape, bm)
    return pl.pallas_call(
        _spmv_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(vals, cols, x)
