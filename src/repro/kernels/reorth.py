"""Reorthogonalization Pallas kernels (paper Alg 1 lines 6 / 13).

CGS against the basis ``Q (m, k)`` is two tall-skinny products:

    c = Qᵀ v          (k coefficients)
    w = v − Q c       (projection applied)

Each is one streaming pass over Q in ``(bm, k)`` row tiles (k ≤ a few
hundred, so a whole basis *row-block* fits VMEM; the k axis is never tiled).
The coefficient vector c lives in VMEM for the whole second pass.  Compared
to the naive jnp composition, nothing here materializes a (m, k)-shaped
temporary and Q is read exactly twice per CGS pass — the theoretical minimum
for classical Gram-Schmidt (the two products have a true dependency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BM = 512           # Q row-block; (512, k<=1024) f32 ≤ 2 MiB of VMEM


def _qtv_kernel(q_ref, v_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        q_ref[...].astype(jnp.float32), v_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),   # Qᵀ v
        preferred_element_type=jnp.float32)


def _sub_kernel(v_ref, q_ref, c_ref, o_ref):
    o_ref[...] = (v_ref[...].astype(jnp.float32)
                  - jnp.dot(q_ref[...].astype(jnp.float32),
                            c_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32))


def qtv(Q: Array, v: Array, *, bm: int = BM, interpret: bool = True) -> Array:
    """c = Qᵀ v.  Q: (m, k); v: (m, 1) → (k, 1) f32."""
    m, k = Q.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _qtv_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(Q, v)


def subtract_qc(v: Array, Q: Array, c: Array, *, bm: int = BM,
                interpret: bool = True) -> Array:
    """w = v − Q c.  v: (m, 1); Q: (m, k); c: (k, 1) → (m, 1) f32."""
    m, k = Q.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _sub_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(v, Q, c)
