"""Pallas TPU kernels for the paper's compute hot-spots.

gk_matvec      — fused Lanczos half-iterations  u = A p − α q,  v = Aᵀ q − β p
gk_step        — fully-fused GK step pipeline: matvec + CGS products +
                 norm epilogue with the candidate vector VMEM-resident
                 (Q read the theoretical minimum passes+1 times)
reorth         — CGS reorthogonalization passes  (Qᵀv then v − Qc)
lowrank_update — W = U diag(s) Vᵀ materialization
sparse_matvec  — row-blocked ELL sparse matvec  y = A x  (SparseOp backend)

``ops`` holds the jit'd public wrappers (padding + interpret-mode switch);
``ref`` holds the pure-jnp oracles every kernel is allclose-tested against.
"""
