"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def matvec_fused(A: Array, p: Array, y: Array, alpha) -> Array:
    """u = A @ p - alpha * y   (GK line 5 / 12, f32 accumulate)."""
    return (A.astype(jnp.float32) @ p.astype(jnp.float32)
            - jnp.asarray(alpha, jnp.float32) * y.astype(jnp.float32))


def rmatvec_fused(A: Array, q: Array, y: Array, beta) -> Array:
    """v = A^T @ q - beta * y."""
    return (A.astype(jnp.float32).T @ q.astype(jnp.float32)
            - jnp.asarray(beta, jnp.float32) * y.astype(jnp.float32))


def qtv(Q: Array, v: Array) -> Array:
    """c = Q^T v  (reorthogonalization coefficients)."""
    return Q.astype(jnp.float32).T @ v.astype(jnp.float32)


def subtract_qc(v: Array, Q: Array, c: Array) -> Array:
    """w = v - Q c  (apply the CGS projection)."""
    return v.astype(jnp.float32) - Q.astype(jnp.float32) @ c.astype(jnp.float32)


def reorth(v: Array, Q: Array, passes: int = 2) -> Array:
    for _ in range(passes):
        v = subtract_qc(v, Q, qtv(Q, v))
    return v


def gk_step(A: Array, p: Array, y: Array, alpha, Q: Array,
            passes: int = 2) -> tuple[Array, Array]:
    """Fused left GK half-step: u = A p − α y, CGS^passes vs Q, and ‖u‖."""
    u = reorth(matvec_fused(A, p, y, alpha), Q, passes)
    return u, jnp.linalg.norm(u)


def gk_rstep(A: Array, q: Array, y: Array, beta, P: Array,
             passes: int = 2) -> tuple[Array, Array]:
    """Fused right GK half-step: v = Aᵀ q − β y, CGS^passes vs P, and ‖v‖."""
    v = reorth(rmatvec_fused(A, q, y, beta), P, passes)
    return v, jnp.linalg.norm(v)


def lowrank_matmul(U: Array, s: Array, Vt: Array) -> Array:
    """W = U diag(s) V^T  (retraction materialization)."""
    return (U.astype(jnp.float32) * s.astype(jnp.float32)[None, :]) \
        @ Vt.astype(jnp.float32)


def sparse_matvec(vals: Array, cols: Array, x: Array) -> Array:
    """y = A @ x for A in padded-ELL rows (vals/cols (m, L), x (n,))."""
    return jnp.sum(vals.astype(jnp.float32)
                   * x.astype(jnp.float32)[cols], axis=1)


def sketch_matmat(signs: Array, idx: Array, X: Array) -> Array:
    """Y = Tᵀ @ X for T in the sparse-sign ELL pack (signs/idx (d, ζ),
    X (N, b)) — sketch row i sums its ζ signed source rows of X."""
    return jnp.einsum("ds,dsb->db", signs.astype(jnp.float32),
                      X.astype(jnp.float32)[idx])


def scatter_add(rows: Array, cols: Array, vals: Array,
                shape: tuple[int, int]) -> Array:
    """Dense (m, d) f32 accumulation of a COO stream — the einsum oracle
    for the count-sketch scatter kernel.  Destinations are expanded to
    one-hot matrices and contracted over the entry axis, so duplicate
    coordinates *sum* (the semantics the kernel must match)."""
    m, d = shape
    R = (rows[:, None] == jnp.arange(m, dtype=rows.dtype)[None, :]
         ).astype(jnp.float32)
    H = (cols[:, None] == jnp.arange(d, dtype=cols.dtype)[None, :]
         ).astype(jnp.float32) * vals.astype(jnp.float32)[:, None]
    return jnp.einsum("em,ed->md", R, H)
