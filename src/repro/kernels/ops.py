"""Public jit'd wrappers for the Pallas kernels.

Handles: (i) shape padding to tile multiples (zero rows/cols are exact for
every kernel here), (ii) vector ⇄ column reshaping, (iii) the
interpret-mode switch — ``interpret=True`` on CPU (this container), compiled
Mosaic on real TPU.

These wrappers expose the same signatures as ``repro.kernels.ref`` so the
GK/F-SVD core can swap implementations via the ``use_kernels`` flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import count_sketch as _cs
from repro.kernels import gk_matvec as _gk
from repro.kernels import gk_step as _gs
from repro.kernels import lowrank_update as _lr
from repro.kernels import reorth as _ro
from repro.kernels import sketch_matvec as _sk
from repro.kernels import sparse_matvec as _sp

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _col(v: Array) -> Array:
    return v.reshape(-1, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matvec_fused(A: Array, p: Array, y: Array, alpha, *, bm: int = _gk.BM,
                 bn: int = _gk.BN) -> Array:
    """u = A @ p − alpha * y  (vectors 1-D in, 1-D f32 out)."""
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    pp = _pad_to(_col(p), bn, 0)
    yp = _pad_to(_col(y), bm, 0)
    out = _gk.matvec_fused(Ap, pp, yp, alpha, bm=bm, bn=bn,
                           interpret=_interpret())
    return out[:m, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def rmatvec_fused(A: Array, q: Array, y: Array, beta, *, bm: int = _gk.BM,
                  bn: int = _gk.BN) -> Array:
    """v = Aᵀ @ q − beta * y."""
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    qp = _pad_to(_col(q), bm, 0)
    yp = _pad_to(_col(y), bn, 0)
    out = _gk.rmatvec_fused(Ap, qp, yp, beta, bm=bm, bn=bn,
                            interpret=_interpret())
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("passes", "bm", "bn"))
def gk_step_fused(A: Array, p: Array, y: Array, alpha, Q: Array,
                  passes: int = 2, *, bm: int = _gs.BM,
                  bn: int = _gs.BN) -> tuple[Array, Array]:
    """Fused left GK half-step: ``u = A p − α y`` reorthogonalized
    CGS^passes against Q, plus its norm — the candidate vector never
    round-trips to HBM between the matvec and the first CGS product, and
    Q is read ``passes + 1`` times (the theoretical minimum: each CGS
    pass's two products have a true dependency, but the second product of
    pass i fuses with the first of pass i+1).

    A: (m, n); p: (n,); y: (m,); Q: (m, k) → (u (m,) f32, ‖u‖ () f32).
    """
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    Qp = _pad_to(Q, bm, 0)
    pp = _pad_to(_col(p), bn, 0)
    yp = _pad_to(_col(y), bm, 0)
    interp = _interpret()
    u, c = _gs.mv_qtv(Ap, pp, yp, alpha, Qp, bm=bm, bn=bn, interpret=interp)
    if passes == 0:
        return u[:m, 0], jnp.linalg.norm(u[:m, 0])
    for _ in range(passes - 1):
        u, c = _gs.proj_qtv(u, Qp, c, bm=bm, interpret=interp)
    v, nrm2 = _gs.proj_norm(u, Qp, c, bm=bm, interpret=interp)
    return v[:m, 0], jnp.sqrt(nrm2[0, 0])


@functools.partial(jax.jit, static_argnames=("passes", "bm", "bn"))
def gk_rstep_fused(A: Array, q: Array, y: Array, beta, P: Array,
                   passes: int = 2, *, bm: int = _gs.BM,
                   bn: int = _gs.BN) -> tuple[Array, Array]:
    """Fused right GK half-step: ``v = Aᵀ q − β y`` vs the P basis.

    A: (m, n); q: (m,); y: (n,); P: (n, k) → (v (n,) f32, ‖v‖ () f32).
    """
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    Pp = _pad_to(P, bn, 0)
    qp = _pad_to(_col(q), bm, 0)
    yp = _pad_to(_col(y), bn, 0)
    interp = _interpret()
    v, c = _gs.rmv_qtv(Ap, qp, yp, beta, Pp, bm=bm, bn=bn, interpret=interp)
    if passes == 0:
        return v[:n, 0], jnp.linalg.norm(v[:n, 0])
    for _ in range(passes - 1):
        v, c = _gs.proj_qtv(v, Pp, c, bm=bn, interpret=interp)
    w, nrm2 = _gs.proj_norm(v, Pp, c, bm=bn, interpret=interp)
    return w[:n, 0], jnp.sqrt(nrm2[0, 0])


def local_mv_qtv(A: Array, p: Array, y: Array, alpha, Q: Array, *,
                 bm: int = _gs.BM, bn: int = _gs.BN
                 ) -> tuple[Array, Array]:
    """One fused pass of the ``gk_step`` stage-1 tile over a LOCAL shard:
    ``u = A p − α y`` plus the partial first CGS product ``c = Qᵀu``.

    Unlike :func:`gk_step_fused` this stops after stage 1 — the caller
    (the sharded Lanczos step body) psums ``c`` across shards before the
    remaining CGS algebra.  Column-vector shapes in/out: A (m, n);
    p (n, 1); y (m, 1); Q (m, k) → (u (m, 1), c (k, 1)) f32.  Not jitted:
    it is traced inside a ``shard_map`` body.
    """
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    Qp = _pad_to(Q, bm, 0)
    pp = _pad_to(p, bn, 0)
    yp = _pad_to(y, bm, 0)
    u, c = _gs.mv_qtv(Ap, pp, yp, alpha, Qp, bm=bm, bn=bn,
                      interpret=_interpret())
    return u[:m], c


def local_rmv_qtv(A: Array, q: Array, y: Array, beta, P: Array, *,
                  bm: int = _gs.BM, bn: int = _gs.BN
                  ) -> tuple[Array, Array]:
    """Reverse direction of :func:`local_mv_qtv` over a local shard:
    ``v = Aᵀ q − β y`` plus the partial ``c = Pᵀv``.  A (m, n); q (m, 1);
    y (n, 1); P (n, k) → (v (n, 1), c (k, 1)) f32."""
    m, n = A.shape
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Ap = _pad_to(_pad_to(A, bm, 0), bn, 1)
    Pp = _pad_to(P, bn, 0)
    qp = _pad_to(q, bm, 0)
    yp = _pad_to(y, bn, 0)
    v, c = _gs.rmv_qtv(Ap, qp, yp, beta, Pp, bm=bm, bn=bn,
                       interpret=_interpret())
    return v[:n], c


@functools.partial(jax.jit, static_argnames=("passes", "bm"))
def reorth(v: Array, Q: Array, passes: int = 2, *, bm: int = _ro.BM) -> Array:
    """CGS^passes: v − Q(Qᵀv), repeated.  v: (m,), Q: (m, k) → (m,) f32."""
    m, k = Q.shape
    bm = min(bm, m) or 1
    Qp = _pad_to(Q, bm, 0)
    vp = _pad_to(_col(v), bm, 0)
    interp = _interpret()
    for _ in range(passes):
        c = _ro.qtv(Qp, vp, bm=bm, interpret=interp)
        vp = _ro.subtract_qc(vp, Qp, c, bm=bm, interpret=interp)
    return vp[:m, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lowrank_matmul(U: Array, s: Array, Vt: Array, *, bm: int = _lr.BM,
                   bn: int = _lr.BN) -> Array:
    """W = U diag(s) Vᵀ → (m, n) f32."""
    m, r = U.shape
    n = Vt.shape[1]
    bm, bn = min(bm, m) or 1, min(bn, n) or 1
    Up = _pad_to(U, bm, 0)
    Vtp = _pad_to(Vt, bn, 1)
    out = _lr.lowrank_matmul(Up, s, Vtp, bm=bm, bn=bn, interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm",))
def sparse_matvec(vals: Array, cols: Array, x: Array, *,
                  bm: int = _sp.BM) -> Array:
    """y = A @ x, A in padded-ELL rows (``sparse_matvec.ell_pack``) → (m,) f32.

    Pads rows to a ``bm`` multiple and the slot dim to the f32 lane width;
    both paddings add (value 0, column 0) slots, which are exact.
    """
    m, _ = vals.shape
    bm = min(bm, m) or 1
    vp = _pad_to(_pad_to(vals, bm, 0), _sp.BL, 1)
    cp = _pad_to(_pad_to(cols, bm, 0), _sp.BL, 1)
    out = _sp.sparse_matvec(vp, cp, _col(x), bm=bm, interpret=_interpret())
    return out[:m, 0]


@functools.partial(jax.jit, static_argnames=("bd",))
def sketch_matmat(signs: Array, idx: Array, X: Array, *,
                  bd: int = _sk.BD) -> Array:
    """Y = Tᵀ @ X, T in the sparse-sign ELL pack (``core.sketch``) →
    (d, b) f32.

    Pads sketch rows to a ``bd`` multiple (zero-sign slots reading row 0
    of X are exact) and the RHS column count to the f32 lane width; both
    paddings slice off after the call.
    """
    d, _ = signs.shape
    b = X.shape[1]
    bd = min(bd, d) or 1
    sp = _pad_to(signs, bd, 0)
    ip = _pad_to(idx, bd, 0)
    Xp = _pad_to(X, _sk.BN, 1)
    out = _sk.sketch_matmat(sp, ip, Xp, bd=bd, interpret=_interpret())
    return out[:d, :b]


@functools.partial(jax.jit, static_argnames=("shape", "be"))
def scatter_add(rows: Array, cols: Array, vals: Array,
                shape: tuple[int, int], *, be: int = _cs.BE) -> Array:
    """Dense (m, d) f32 accumulation of a COO entry stream; duplicate
    coordinates sum (count-sketch collision semantics).

    Pads the entry count to a ``be`` multiple with (row 0, col 0, val 0)
    entries — exactly zero contribution — and the output panel to f32
    tile multiples, sliced off after the call.
    """
    m, d = shape
    E = rows.shape[0]
    if E == 0:
        return jnp.zeros((m, d), jnp.float32)
    be = min(be, E)
    rp = _pad_to(rows.reshape(-1, 1).astype(jnp.int32), be, 0)[:, 0]
    cp = _pad_to(cols.reshape(-1, 1).astype(jnp.int32), be, 0)[:, 0]
    vp = _pad_to(vals.reshape(-1, 1), be, 0)[:, 0]
    mp = m + ((-m) % 8)
    dp = d + ((-d) % 128)
    out = _cs.scatter_add(rp, cp, vp, (mp, dp), be=be,
                          interpret=_interpret())
    return out[:m, :d]
