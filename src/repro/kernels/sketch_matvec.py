"""Sketch-row-blocked sparse-sign sketch application Pallas kernel.

Sketch-based solvers (``rbk`` / ``gnystrom``) compress an operand through a
tall random test matrix ``T`` of shape (N, d) with ζ nonzeros per column,
each ±1/√ζ (Clarkson–Woodruff / Tropp sparse-sign ensemble).  Applying the
sketch to a block ``X`` (N, b) is ``Y = Tᵀ X`` — like the sparse matvec in
``sparse_matvec.py`` this is gather-bound, not FLOP-bound, so the kernel
generalizes the same gather-only ELL layout from vector to block RHS:

    Y[i, :] = Σ_s signs[i, s] * X[idx[i, s], :]          i = sketch row

with ``idx``/``signs`` of shape (d, ζ) — row i lists the ζ source rows of X
that sketch coordinate i reads, and their signed weights.  Each grid step
owns ``bd`` sketch rows while X stays resident in VMEM; the slot loop is
unrolled (ζ is a small static constant), so every step is a row gather plus
a rank-1-broadcast multiply-accumulate — scatter never appears, which keeps
the kernel TPU-shaped in both the forward (``AΩ`` needs ``Tᵀ`` applied to
rows of Aᵀ) and co-range (``ΨᵀA``) directions.

Unlike the SparseOp ELL pack (value-dependent row widths, built host-side),
the sketch pack has *static* shape (d, ζ) for a given spec — it is built
in-trace from a PRNG key by ``repro.core.sketch`` and therefore survives
``jit`` / ``vmap`` whole.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# default tile: 128 sketch rows per grid step; ops.py pads the RHS block's
# column count to a multiple of BN so (bd, b) tiles sit on f32 lane
# boundaries.  ZETA is the default nonzeros-per-column of the ensemble.
BD, BN = 128, 128
ZETA = 8


def _sketch_kernel(s_ref, i_ref, x_ref, o_ref):
    """One sketch-row block: o = Σ_slots signs ⊙ X[idx]  (f32 accumulate).

    The slot dimension is unrolled at trace time (ζ is static and small):
    each term is a (bd,)-row gather from the resident X and a broadcast
    multiply — 2-D ops only, no 3-D intermediates.
    """
    x = x_ref[...].astype(jnp.float32)                   # (N, b) resident
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for s in range(i_ref.shape[1]):
        gathered = jnp.take(x, i_ref[:, s], axis=0)      # (bd, b)
        acc = acc + s_ref[:, s].astype(jnp.float32)[:, None] * gathered
    o_ref[...] = acc


def sketch_matmat(signs: Array, idx: Array, X: Array, *,
                  bd: int = BD, interpret: bool = True) -> Array:
    """Y = Tᵀ @ X with T in the sparse-sign ELL pack.

    signs/idx: (d, ζ); X: (N, b).  d must be a multiple of bd (``ops.py``
    pads sketch rows with zero-sign slots, which contribute exactly 0).
    """
    d, L = signs.shape
    assert d % bd == 0, (signs.shape, bd)
    n = X.shape[1]
    return pl.pallas_call(
        _sketch_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd, L), lambda i: (i, 0)),
            pl.BlockSpec((bd, L), lambda i: (i, 0)),
            pl.BlockSpec(X.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=interpret,
    )(signs, idx, X)
