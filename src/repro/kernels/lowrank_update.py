"""Low-rank materialization Pallas kernel: ``W = U diag(s) Vᵀ``.

Used when the RSGD retraction output (or a compressed gradient) must be
densified — e.g. applying a rank-r update to an optimizer's dense parameter
block.  Output-stationary tiling: each (bm, bn) tile of W is produced by one
(bm, r) × (r, bn) MXU contraction; r ≤ a few hundred so both factor slabs sit
in VMEM, and W is *written once, never read* (the jnp composition would
materialize U·diag(s) first — an extra (m, r) HBM round-trip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BM, BN = 256, 256


def _lr_kernel(u_ref, s_ref, vt_ref, o_ref):
    us = u_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(us, vt_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)


def lowrank_matmul(U: Array, s: Array, Vt: Array, *, bm: int = BM,
                   bn: int = BN, interpret: bool = True) -> Array:
    """W = U diag(s) Vᵀ.  U: (m, r); s: (r,); Vt: (r, n) → (m, n) f32."""
    m, r = U.shape
    r2, n = Vt.shape
    assert r == r2 and m % bm == 0 and n % bn == 0, (U.shape, Vt.shape, bm, bn)
    s2 = jnp.asarray(s, jnp.float32).reshape(1, r)
    return pl.pallas_call(
        _lr_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(U, s2, Vt)


def _pick_block(dim: int) -> int | None:
    """Largest tile from the standard ladder that divides ``dim`` exactly
    (whole-dim tiles for small operands).  None → shape doesn't tile."""
    if dim <= BM:
        return dim
    for b in (256, 128, 64, 32):
        if dim % b == 0:
            return b
    return None


def materialize(U: Array, s: Array, Vt: Array, *,
                interpret: bool = True) -> Array:
    """Shape-adaptive ``W = U diag(s) Vᵀ``: route through the Pallas tile
    kernel when both dims tile on the standard ladder, otherwise fall back
    to the jnp composition.  Used by ``repro.core.update`` to fold low-rank
    drifts into dense operands without each caller re-deriving tile sizes.
    """
    m, _ = U.shape
    n = Vt.shape[1]
    bm, bn = _pick_block(m), _pick_block(n)
    if bm is None or bn is None:
        return (jnp.asarray(U, jnp.float32)
                * jnp.asarray(s, jnp.float32)[None, :]) @ jnp.asarray(
                    Vt, jnp.float32)
    return lowrank_matmul(U, s, Vt, bm=bm, bn=bn, interpret=interpret)
