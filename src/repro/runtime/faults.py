"""Fault injection: named failpoints for chaos-testing the serving stack.

A *failpoint* is a named hook compiled into production code paths
(``serve.dispatch``, ``plan.solve``, ``checkpoint.write``,
``session.restore``) that does nothing until a test, the chaos benchmark
or the ``--chaos`` CLI flag *arms* it with a :class:`FaultSpec`:

    with inject("plan.solve", mode="raise", p=0.2, transient=True):
        ...  # ~20% of plan solves raise TransientFault

Armed behaviours:

* ``mode="raise"``    raise :class:`FaultInjected` (or
                      :class:`TransientFault` when ``transient=True`` —
                      the retry layer's signal that backing off is worth
                      it, or any exception type passed via ``exc``).
* ``mode="delay"``    sleep ``delay_s`` seconds (simulates a hung worker
                      / slow device; the serve watchdog's prey).
* ``mode="corrupt"``  :func:`corrupt` mangles the value passed through
                      the failpoint (NaN for float arrays, flipped bytes
                      for raw buffers) — simulates bit-rot and poisoned
                      operands.

Design constraints, in order:

1. **No-op when disarmed.**  The registry holds a single module-level
   ``_ARMED`` flag checked before any dict lookup, so production traffic
   pays one attribute read per failpoint crossing.
2. **Seeded.**  Each armed failpoint owns a ``numpy`` Generator seeded
   from (``seed``, name), so a chaos run replays the same fault schedule
   for the same seed regardless of which other failpoints are armed.
3. **Thread-safe.**  Arming/disarming and probability draws take a lock;
   failpoints fire concurrently from client threads, the dispatch worker
   and the watchdog.
4. **Scoped.**  ``inject(...)`` / ``chaos(...)`` are context managers
   that disarm on exit even when the body raises — a failed test never
   leaves a failpoint armed for the rest of the suite.

``fire_count(name)`` / ``fault_stats()`` expose how often each armed
failpoint actually triggered — the chaos bench reports the injected-fault
mix next to the availability it measured.
"""
from __future__ import annotations

import contextlib
import threading
import time
import zlib
from typing import Any, Dict, Iterator, Optional

import numpy as np


class FaultInjected(RuntimeError):
    """An armed failpoint fired (mode="raise")."""


class TransientFault(FaultInjected):
    """A retryable injected failure — the bounded-retry layer's cue."""


class FaultSpec:
    """One armed failpoint's behaviour.

    mode        "raise" | "delay" | "corrupt".
    p           per-crossing trigger probability in [0, 1].
    delay_s     sleep length for mode="delay".
    transient   mode="raise" raises TransientFault instead of
                FaultInjected (ignored when ``exc`` is given).
    exc         exception *type* to raise for mode="raise".
    max_fires   stop triggering after this many fires (None = unbounded)
                — "crash exactly once" tests want determinism, not a
                probability.
    seed        RNG seed; the stream is additionally folded with the
                failpoint name so two armed points never share a draw
                sequence.
    """

    __slots__ = ("name", "mode", "p", "delay_s", "transient", "exc",
                 "max_fires", "fires", "_rng")

    def __init__(self, name: str, mode: str = "raise", *, p: float = 1.0,
                 delay_s: float = 0.05, transient: bool = False,
                 exc: Optional[type] = None,
                 max_fires: Optional[int] = None, seed: int = 0):
        if mode not in ("raise", "delay", "corrupt"):
            raise ValueError(
                f"mode must be 'raise', 'delay' or 'corrupt', got {mode!r}")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {p}")
        self.name = name
        self.mode = mode
        self.p = float(p)
        self.delay_s = float(delay_s)
        self.transient = bool(transient)
        self.exc = exc
        self.max_fires = max_fires
        self.fires = 0
        # fold the name into the seed so arming the same chaos seed on N
        # failpoints yields N independent, reproducible schedules
        self._rng = np.random.default_rng(
            (int(seed) << 32) ^ zlib.crc32(name.encode()))

    def _should_fire(self) -> bool:
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        if self.p >= 1.0 or self._rng.random() < self.p:
            self.fires += 1
            return True
        return False


_LOCK = threading.Lock()
_POINTS: Dict[str, FaultSpec] = {}
_ARMED = False          # fast-path gate: production pays one bool read
_TOTALS: Dict[str, int] = {}


def arm(name: str, mode: str = "raise", **kw) -> FaultSpec:
    """Arm ``name`` with a :class:`FaultSpec` (replacing any previous)."""
    global _ARMED
    spec = FaultSpec(name, mode, **kw)
    with _LOCK:
        _POINTS[name] = spec
        _ARMED = True
    return spec


def disarm(name: str) -> None:
    global _ARMED
    with _LOCK:
        _POINTS.pop(name, None)
        _ARMED = bool(_POINTS)


def disarm_all() -> None:
    global _ARMED
    with _LOCK:
        _POINTS.clear()
        _ARMED = False


def armed(name: str) -> bool:
    with _LOCK:
        return name in _POINTS


def fire_count(name: str) -> int:
    """How many times the failpoint actually triggered (lifetime, across
    re-arms)."""
    with _LOCK:
        live = _POINTS.get(name)
        return _TOTALS.get(name, 0) + (live.fires if live else 0)


def fault_stats() -> Dict[str, Any]:
    """{name: {mode, p, fires}} for every armed point plus lifetime fire
    totals of disarmed ones (the chaos bench's injected-fault report)."""
    with _LOCK:
        out: Dict[str, Any] = {
            name: {"mode": s.mode, "p": s.p, "fires": s.fires}
            for name, s in _POINTS.items()}
        for name, n in _TOTALS.items():
            if name not in out:
                out[name] = {"mode": None, "p": 0.0, "fires": n}
        return out


def reset_stats() -> None:
    with _LOCK:
        _TOTALS.clear()
        for s in _POINTS.values():
            s.fires = 0


def fire(name: str) -> None:
    """The failpoint crossing: no-op unless ``name`` is armed and its
    probability draw triggers; then raise or delay per the armed spec.

    Call this at the top of the protected operation — the fault lands
    *before* the real work, like a crash on entry."""
    if not _ARMED:
        return
    with _LOCK:
        spec = _POINTS.get(name)
        if spec is None or not spec._should_fire():
            return
        mode, delay_s = spec.mode, spec.delay_s
        exc = spec.exc
        transient = spec.transient
    if mode == "delay":
        time.sleep(delay_s)
        return
    if mode == "raise":
        if exc is not None:
            raise exc(f"failpoint {name!r} fired")
        if transient:
            raise TransientFault(f"failpoint {name!r} fired (transient)")
        raise FaultInjected(f"failpoint {name!r} fired")
    # mode == "corrupt" without a value crossing: nothing to mangle here;
    # sites that carry data route through corrupt() instead.


def corrupt(name: str, value):
    """Value-carrying failpoint: return ``value`` unchanged when disarmed,
    a mangled copy when an armed mode="corrupt" spec fires.

    Float arrays get a NaN planted at a seeded position (poisoned
    operand); byte buffers get one byte flipped (bit-rot).  Raise/delay
    specs behave as in :func:`fire` — one site serves all three modes.
    """
    if not _ARMED:
        return value
    with _LOCK:
        spec = _POINTS.get(name)
        if spec is None or spec.mode != "corrupt":
            pass
        elif spec._should_fire():
            rng = spec._rng
            if isinstance(value, (bytes, bytearray)):
                buf = bytearray(value)
                i = int(rng.integers(len(buf))) if buf else 0
                if buf:
                    buf[i] ^= 0xFF
                return bytes(buf)
            arr = np.array(value, copy=True)
            if arr.size:
                i = int(rng.integers(arr.size))
                flat = arr.reshape(-1)
                flat[i] = np.nan if np.issubdtype(arr.dtype, np.floating) \
                    else flat[i] ^ np.asarray(-1, arr.dtype)
            return arr
    fire(name)        # raise/delay specs still apply at value crossings
    return value


@contextlib.contextmanager
def inject(name: str, mode: str = "raise", **kw) -> Iterator[FaultSpec]:
    """Scoped arming: arm on enter, disarm (and roll the spec's fire
    count into the lifetime totals) on exit — exception-safe."""
    spec = arm(name, mode, **kw)
    try:
        yield spec
    finally:
        global _ARMED
        with _LOCK:
            if _POINTS.get(name) is spec:
                del _POINTS[name]
            _TOTALS[name] = _TOTALS.get(name, 0) + spec.fires
            _ARMED = bool(_POINTS)


# the serving stack's compiled-in failpoint names (importable constants so
# call sites and tests cannot drift apart on a typo)
SERVE_DISPATCH = "serve.dispatch"
PLAN_SOLVE = "plan.solve"
CHECKPOINT_WRITE = "checkpoint.write"
SESSION_RESTORE = "session.restore"


@contextlib.contextmanager
def chaos(seed: int = 0, *,
          dispatch_crash_p: float = 0.0,
          dispatch_hang_p: float = 0.0,
          hang_s: float = 0.2,
          solve_transient_p: float = 0.0) -> Iterator[None]:
    """Arm the serving fault mix in one scope (the ``--chaos`` flag and
    the chaos bench).  Crash and hang cannot share the one
    ``serve.dispatch`` slot — crash wins when both are requested; the
    bench arms them in separate phases instead.
    """
    stack = contextlib.ExitStack()
    with stack:
        if dispatch_crash_p > 0:
            stack.enter_context(inject(
                SERVE_DISPATCH, "raise", p=dispatch_crash_p, seed=seed))
        elif dispatch_hang_p > 0:
            stack.enter_context(inject(
                SERVE_DISPATCH, "delay", p=dispatch_hang_p,
                delay_s=hang_s, seed=seed))
        if solve_transient_p > 0:
            stack.enter_context(inject(
                PLAN_SOLVE, "raise", p=solve_transient_p, transient=True,
                seed=seed))
        yield


__all__ = [
    "CHECKPOINT_WRITE", "FaultInjected", "FaultSpec", "PLAN_SOLVE",
    "SERVE_DISPATCH", "SESSION_RESTORE", "TransientFault", "arm", "armed",
    "chaos", "corrupt", "disarm", "disarm_all", "fault_stats", "fire",
    "fire_count", "inject", "reset_stats",
]
