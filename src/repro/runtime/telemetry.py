"""Training- and serving-health telemetry.

Two signals live here: the paper's Algorithm 3 applied to gradients
(spectral training health, below), and :class:`LatencyStats` — the
thread-safe latency reservoir behind the solve server's stats endpoint
(``repro.serve.server``).

The numerical rank (and top-Ritz spectrum) of per-layer gradients is a
cheap-to-compute training-health signal: a collapsing gradient rank flags
dead layers / LR pathologies, an exploding tail flags noise domination —
and it directly prescribes the ``compression_rank`` the Krylov gradient
compression can use losslessly.  Cost: k matvecs with the (m, n) gradient,
k ~ 16 — negligible next to the step itself; run every
``FsvdConfig.rank_telemetry_every`` steps.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FsvdConfig
from repro.core.gk import gk_bidiag
from repro.core.operators import DenseOp
from repro.core.tridiag import btb_eigh

Array = jax.Array
PyTree = Any


class LatencyStats:
    """Thread-safe latency accumulator with bounded memory.

    Percentiles come from a sliding window of the most recent ``window``
    samples (a long-running server must not grow without bound); count,
    mean and max are exact over the full lifetime.  All methods take one
    short lock — safe to call from submit threads and the dispatch worker
    concurrently.
    """

    def __init__(self, window: int = 8192):
        self._buf: "collections.deque[float]" = collections.deque(
            maxlen=int(window))
        self._lock = threading.Lock()
        self._count = 0
        self._total = 0.0
        self._max = 0.0

    def record(self, ms: float) -> None:
        ms = float(ms)
        with self._lock:
            self._buf.append(ms)
            self._count += 1
            self._total += ms
            self._max = max(self._max, ms)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    # Readers snapshot under the lock and crunch OUTSIDE it: record() on
    # the dispatch hot path takes the same lock, and an np.percentile over
    # the full 8192-sample window (tens of µs, unboundedly worse under a
    # descheduled reader) must never stall it.  The copy is O(window) but
    # lock-held time is a bounded memcpy, not a sort.

    def percentile(self, p: float) -> float:
        with self._lock:
            data = np.asarray(self._buf)
        if data.size == 0:
            return 0.0
        return float(np.percentile(data, p))

    def summary(self) -> dict:
        """{count, mean_ms, p50_ms, p99_ms, max_ms} snapshot."""
        with self._lock:
            count, total, mx = self._count, self._total, self._max
            data = np.asarray(self._buf)
        if count == 0:
            return {"count": 0, "mean_ms": 0.0, "p50_ms": 0.0,
                    "p99_ms": 0.0, "max_ms": 0.0}
        return {"count": count,
                "mean_ms": total / count,
                "p50_ms": float(np.percentile(data, 50)),
                "p99_ms": float(np.percentile(data, 99)),
                "max_ms": mx}


def grad_spectrum(g: Array, k: int = 16, eps: float = 1e-6) -> dict:
    """Top-k Ritz spectrum + effective numerical rank of one 2-D gradient.

    Returns {"sigma": (k,) descending, "rank": (), "energy_r": ()} where
    ``energy_r`` is the spectral energy fraction captured by the top
    ``rank`` values (how losslessly a rank-r compression would transmit
    this gradient).
    """
    if g.ndim > 2:
        g = g.reshape(g.shape[0], -1)
    m, n = g.shape
    k = min(k, m, n)
    # run the recurrence past k (bounded slack) so near-degenerate spectra
    # still resolve k clean Ritz values; the REPORTED rank is clamped to
    # the k-vector actually returned — rank must never exceed len(sigma).
    kk = min(4 * k, m, n)
    res = gk_bidiag(DenseOp(g.astype(jnp.float32)), kk, reorth_passes=2,
                    key=jax.random.PRNGKey(0))  # deterministic diagnostic
    theta, _ = btb_eigh(res.alphas, res.betas, res.kprime)
    finite = jnp.where(jnp.isfinite(theta), jnp.clip(theta, 0.0, None), 0.0)
    sigma = jnp.sqrt(finite[:k])
    tol = jnp.max(finite) * eps
    rank = jnp.minimum(jnp.sum(finite > tol), k).astype(jnp.int32)
    # energy fraction against the FULL Frobenius energy, not just the
    # computed Ritz values (a white spectrum must not read as 100%)
    total = jnp.sum(jnp.square(g.astype(jnp.float32))) + 1e-30
    csum = jnp.cumsum(finite[:k])
    idx = jnp.clip(rank - 1, 0, k - 1)
    # a zero / below-tolerance spectrum captures no energy at rank 0 — the
    # unguarded csum[0]/total would report the top-1 fraction instead
    energy_r = jnp.where(rank > 0, csum[idx] / total, 0.0)
    return {"sigma": sigma, "rank": rank, "energy_r": energy_r}


def gradient_rank_summary(grads: PyTree, cfg: Optional[FsvdConfig] = None,
                          k: int = 16, max_leaves: int = 8) -> dict:
    """Alg-3 telemetry over the largest 2-D gradient leaves.

    Returns {leaf-path: spectrum dict}; jit-able (fixed leaf selection at
    trace time — the ``max_leaves`` biggest compressible matrices).
    """
    min_dim = cfg.compression_min_dim if cfg is not None else 256
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    cands = []
    for path, leaf in flat:
        if leaf.ndim < 2:
            continue
        m = leaf.shape[0] if leaf.ndim == 2 else leaf.shape[1]
        n = leaf.size // leaf.shape[0] if leaf.ndim == 2 else \
            leaf.size // (leaf.shape[0] * leaf.shape[1])
        if min(m, n) < min_dim:
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "name", "?")))
                        for p in path)
        cands.append((leaf.size, name, leaf))
    cands.sort(key=lambda t: -t[0])
    out = {}
    for _, name, leaf in cands[:max_leaves]:
        if leaf.ndim >= 3:
            # stacked layers: spectrum of the middle layer as representative
            leaf = leaf[leaf.shape[0] // 2]
        out[name] = grad_spectrum(leaf, k=k)
    return out
