"""Pure step functions shared by the trainer, the serving loop and the
multi-pod dry-run (the dry-run lowers exactly what the trainer executes).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.base import FsvdConfig, ModelConfig, OptimConfig, RunConfig
from repro.models import model as model_mod
from repro.optim import make_optimizer

Array = jax.Array
PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: Any               # optim.OptState


def init_state(cfg: ModelConfig, optim_cfg: OptimConfig, key) -> TrainState:
    params, _ = model_mod.init_model(cfg, key)
    opt_init, _ = make_optimizer(optim_cfg)
    return TrainState(params, opt_init(params))


def build_train_step(model_cfg: ModelConfig, optim_cfg: OptimConfig,
                     mesh: Optional[Mesh] = None, nan_guard: bool = True):
    """(state, batch) -> (new_state, metrics dict).

    The NaN guard is *in-graph*: a non-finite loss turns the whole update
    into a no-op select (no host round-trip, SPMD-consistent across pods) and
    is reported in ``metrics["skipped"]`` for the host-side counter.
    """
    _, opt_update = make_optimizer(optim_cfg)

    def train_step(state: TrainState, batch: dict):
        def lf(params):
            loss, met = model_mod.loss_fn(params, batch, model_cfg, mesh)
            return loss, met

        (loss, met), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
        new_params, new_opt, stats = opt_update(state.params, state.opt, grads)
        metrics = {"loss": loss, "ce": met.ce, "aux": met.aux,
                   "n_tokens": met.n_tokens, **stats}
        if nan_guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(stats["grad_norm"])
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, state.params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state.opt)
            metrics["skipped"] = (~ok).astype(jnp.int32)
        return TrainState(new_params, new_opt), metrics

    return train_step


def build_compressed_train_step(model_cfg: ModelConfig,
                                optim_cfg: OptimConfig, mesh: Mesh,
                                fsvd_cfg: FsvdConfig,
                                nan_guard: bool = True):
    """Multi-pod train step with Krylov gradient compression on the "pod"
    axis (the DCN hop — the slow, expensive link at 1000-node scale).

    Structure: ``shard_map`` is MANUAL over "pod" only (``auto`` covers
    data/model, so FSDP/TP inside each pod is unchanged GSPMD); each pod
    computes gradients on its batch shard, then the cross-pod mean of every
    large 2-D (or stacked per-layer) gradient is exchanged as GK factors —
    ``k (m+n)`` floats over DCN instead of ``m n`` (repro.distributed.
    compression).  Small leaves ride a plain psum.

    Note: per-step error feedback is disabled here (it would add an f32
    params-sized residual per pod); the examples/tests exercise EF on the
    pure-DP path.  MoE archs keep their inner EP shard_map and are not
    supported on this path — compression applies to their dense submatrices
    via the default path instead.
    """
    assert "pod" in mesh.axis_names, "compressed step needs a pod axis"
    from repro.distributed import compression as C
    _, opt_update = make_optimizer(optim_cfg)
    fcfg = FsvdConfig(**{**fsvd_cfg.__dict__, "error_feedback": False})
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def train_step(state: TrainState, batch: dict):
        def pod_body(params, batch):
            def lf(p):
                loss, met = model_mod.loss_fn(p, batch, model_cfg, mesh)
                return loss, met

            (loss, met), grads = jax.value_and_grad(lf, has_aux=True)(params)
            ef = jax.tree.map(lambda g: jnp.zeros((), jnp.float32), grads)
            mean, _, stats = C.compressed_mean_grads(grads, ef, "pod", fcfg)
            loss = jax.lax.pmean(loss, "pod")
            return mean, loss, met.ce, met.aux, met.n_tokens, \
                stats.dense_bytes, stats.compressed_bytes

        grads, loss, ce, aux, n_tok, dense_b, comp_b = compat.shard_map(
            pod_body, mesh=mesh,
            in_specs=(P(), P("pod")),
            out_specs=(P(), P(), P(), P(), P(), P(), P()),
            axis_names={"pod"}, check_vma=False,
        )(state.params, batch)

        new_params, new_opt, stats = opt_update(state.params, state.opt,
                                                grads)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "n_tokens": n_tok,
                   "comm_dense_bytes": dense_b,
                   "comm_compressed_bytes": comp_b, **stats}
        if nan_guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(stats["grad_norm"])
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, state.params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state.opt)
            metrics["skipped"] = (~ok).astype(jnp.int32)
        return TrainState(new_params, new_opt), metrics

    return train_step


def build_eval_step(model_cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def eval_step(params, batch):
        loss, met = model_mod.loss_fn(params, batch, model_cfg, mesh)
        return {"loss": loss, "ce": met.ce, "n_tokens": met.n_tokens}
    return eval_step


def build_prefill_step(model_cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def prefill(params, batch):
        return model_mod.prefill_step(params, batch, model_cfg, mesh)
    return prefill


def build_decode_step(model_cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def decode(params, cache, batch):
        return model_mod.decode_step(params, cache, batch, model_cfg, mesh)
    return decode
