"""Runtime: train-state/step builders and the fault-tolerant training loop."""
from repro.runtime.steps import TrainState, build_eval_step, build_train_step
from repro.runtime.trainer import Trainer

__all__ = ["TrainState", "build_train_step", "build_eval_step", "Trainer"]
