"""Runtime: train-state/step builders, the fault-tolerant training loop,
serving telemetry and the fault-injection (failpoint) registry.

Train-loop members resolve lazily (PEP 562): ``repro.runtime.faults`` is
compiled into hot serving/checkpoint paths, and importing it must not
drag the trainer/model stack into a solve server's process.
"""
from repro.runtime import faults  # dependency-free; safe eagerly

__all__ = ["TrainState", "build_train_step", "build_eval_step", "Trainer",
           "faults"]


def __getattr__(name):
    if name in ("TrainState", "build_train_step", "build_eval_step"):
        from repro.runtime import steps
        return getattr(steps, name)
    if name == "Trainer":
        from repro.runtime.trainer import Trainer
        return Trainer
    raise AttributeError(f"module 'repro.runtime' has no attribute {name!r}")
