"""Fault-tolerant training loop.

Failure modes handled (and unit-tested):
  * process death        -> atomic checkpoints + auto-resume from latest valid
  * loss/grad NaN or Inf -> in-graph no-op select + host counter; abort after
                            ``max_nan_skips`` consecutive skips
  * stragglers           -> per-step EWMA timing; z-score alarms with a
                            slow-step report (on multi-host, each host logs
                            its own timings; the controller aggregates)
  * SIGTERM / preemption -> drain: finish the in-flight step, write a final
                            checkpoint, exit cleanly
  * elastic restarts     -> reshard-on-restore (checkpoint stores host
                            arrays; restore re-places them under the current
                            mesh, which may differ from the writer's)

A training loop that owns a solver ``repro.api.Session`` (e.g. the RSL
loop tracking its drifting gradient operator) can hand it to the trainer:
its tracking state (previous factorization + plan spec) checkpoints
alongside the model state under ``<ckpt_dir>/session`` and resumes with
``maybe_resume`` — a restarted job keeps its warm-start seam instead of
paying a cold solve.
"""
from __future__ import annotations

import collections
import math
import os
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig

PyTree = Any


class StragglerWatchdog:
    """EWMA step-time monitor: flags steps whose duration z-score exceeds
    the configured threshold (the single-host stand-in for per-host
    heartbeat monitoring on a real cluster)."""

    def __init__(self, zscore: float = 3.0, window: int = 50):
        self.z = zscore
        self.times: collections.deque = collections.deque(maxlen=window)
        self.alarms: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        flagged = False
        if len(self.times) >= 10:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if (dt - mu) / sd > self.z:
                self.alarms.append((step, dt, mu))
                flagged = True
        self.times.append(dt)
        return flagged


class Trainer:
    """Drives ``train_step`` with checkpointing, NaN accounting, straggler
    telemetry and SIGTERM draining."""

    def __init__(self, run_cfg: RunConfig, train_step: Callable,
                 batch_fn: Callable[[int], dict],
                 state: PyTree,
                 state_sharding_fn: Optional[Callable] = None,
                 log_fn: Callable[[str], None] = print,
                 install_sigterm: bool = True,
                 session=None):
        self.cfg = run_cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.state = state
        self.log = log_fn
        self.ckpt = CheckpointManager(run_cfg.checkpoint.directory,
                                      keep=run_cfg.checkpoint.keep,
                                      async_write=run_cfg.checkpoint.async_write)
        self.watchdog = StragglerWatchdog(run_cfg.runtime.straggler_zscore,
                                          run_cfg.runtime.straggler_window)
        self.state_sharding_fn = state_sharding_fn
        self.session = session       # optional repro.api.Session (tracking)
        self.step = 0
        self.consecutive_nans = 0
        self.history: list[dict] = []
        self._drain = False
        if install_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass           # not on the main thread (tests)

    def _on_sigterm(self, signum, frame):
        self.log("[trainer] SIGTERM received - draining")
        self._drain = True

    @property
    def _session_dir(self) -> str:
        return os.path.join(self.cfg.checkpoint.directory, "session")

    def _save_session(self) -> None:
        if self.session is not None:
            # same keep-N retention as the model checkpoints, so a
            # rolled-back restore still finds a matching session state
            self.session.save(self._session_dir, self.step,
                              keep=self.ckpt.keep)

    def maybe_resume(self) -> bool:
        restored = self.ckpt.restore_latest(self.state,
                                            self.state_sharding_fn)
        if restored is None:
            return False
        step, state, extra = restored
        self.state = state
        self.step = step
        if self.session is not None and self.session.load_latest(
                self._session_dir):
            self.log(f"[trainer] solver session resumed "
                     f"({self.session.solves} tracked solves)")
        self.log(f"[trainer] resumed from step {step}")
        return True

    def run(self, num_steps: int) -> list[dict]:
        cfg = self.cfg
        end = self.step + num_steps
        while self.step < end and not self._drain:
            t0 = time.perf_counter()
            batch = self.batch_fn(self.step)
            self.state, metrics = self.train_step(self.state, batch)
            metrics = jax.tree.map(lambda x: np.asarray(x), metrics)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0

            skipped = int(metrics.get("skipped", 0))
            if skipped or not math.isfinite(loss):
                self.consecutive_nans += 1
                self.log(f"[trainer] step {self.step}: non-finite loss - "
                         f"update skipped ({self.consecutive_nans} in a row)")
                if self.consecutive_nans > cfg.runtime.max_nan_skips:
                    raise RuntimeError(
                        f"aborting: {self.consecutive_nans} consecutive "
                        f"non-finite steps")
            else:
                self.consecutive_nans = 0

            if self.watchdog.observe(self.step, dt):
                self.log(f"[trainer] step {self.step}: straggler alarm "
                         f"({dt:.3f}s vs EWMA {np.mean(self.watchdog.times):.3f}s)")

            rec = {"step": self.step, "loss": loss, "time": dt, **{
                k: float(v) for k, v in metrics.items()
                if np.ndim(v) == 0 and k != "loss"}}
            self.history.append(rec)
            if cfg.runtime.log_every and self.step % cfg.runtime.log_every == 0:
                self.log(f"[trainer] step {self.step}: loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms)")

            self.step += 1
            if (cfg.checkpoint.every_steps
                    and self.step % cfg.checkpoint.every_steps == 0):
                self.ckpt.save(self.step, self.state,
                               extra={"run": cfg.to_dict()})
                self._save_session()

        if self._drain:
            self.log(f"[trainer] drained at step {self.step}; final checkpoint")
        self.ckpt.save(self.step, self.state, extra={"run": cfg.to_dict()})
        self._save_session()
        self.ckpt.wait()
        return self.history
