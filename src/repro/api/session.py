"""Session — track a drifting operator across many solves.

The paper's §V workload (Riemannian similarity learning) and the ROADMAP
serving target are not one SVD but a *stream* of partial SVDs of an
operator that drifts slowly between solves (a gradient operator along a
training trajectory, a similarity matrix under live updates).  A
:class:`Session` owns that stream:

    sess = session(A, SVDSpec(method="fsvd", rank=8), key=key)
    f0 = sess.solve()                 # cold: full Krylov budget
    f1 = sess.update(A_next)          # warm: refine from f0, reduced budget
    f2 = sess.delta(LowRankOp(...))   # structured drift: rank-k update,
                                      # ZERO Krylov iterations when it
                                      # passes the parity gate
    f3 = sess.entries(rows, cols, v)  # unstructured drift: fold the COO
                                      # stream into a resident sketch,
                                      # reconstruct — zero iterations when
                                      # it passes the residual probe

The decision is **four-way** per step:

  ============  =============================================  ==========
  branch        taken when                                     GK iters
  ============  =============================================  ==========
  ``update``    drift is an explicit ``LowRankOp`` delta AND   0
                the measured residual-after-update passes the
                parity gate (``update_tol``, learned when not
                pinned)
  ``sketch``    drift arrived as a COO entry stream via        0
                :meth:`entries`, the resident sketch's
                staleness odometer is under budget, AND the
                reconstructed factorization passes the
                residual probe (``sketch_tol``, learned when
                not pinned)
  ``refine``    measured subspace drift ≤ ``restart_angle``    reduced
  ``restart``   drift above ``restart_angle`` (or no previous  full
                factorization); also the staleness fallback —
                a tripped sketch re-sketches from the operand
                and answers with a REAL solve, never an
                unverified reconstruction
  ============  =============================================  ==========

For refine/restart the session measures the **subspace angle** between the
previous Ritz basis and its image under the new operator — ``sin θ =
||(I − U Uᵀ) A' V||_F / ||A' V||_F``, r matvecs, negligible next to a
solve.  For a structured delta it instead runs the rank-k Brand update
(:mod:`repro.core.update`) and measures the resulting residual directly:
the update is *exact* when the previous factorization captured the operand
exactly, and the gate catches the noisy-tail case where it would silently
degrade — rejected updates fall through to the refine/restart policy with
the rejection recorded in ``history``.

Solves run through one shared :class:`~repro.api.plan.SolverPlan`, so a
session pays exactly one XLA trace per (operand signature, budget) for its
entire lifetime — the update path included.  Every step appends a record
(kind, iterations, drift, residual) to ``history``; device scalars are
recorded lazily and only materialized when ``history``/``meta()`` is read,
so ``track_residuals=False`` streams never block on a per-solve host sync.

Sessions checkpoint: ``sess.save(dir, step)`` persists the previous
factorization + plan spec through ``repro.checkpoint`` (atomic, crash
safe); ``Session.restore(dir, A)`` / ``sess.load_latest(dir)`` resume
tracking where the stream left off — including ``track_residuals``,
``restart_angle``, ``update_tol`` and the update counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import method_needs_key
from repro.api.plan import plan as _make_plan
from repro.api.results import Factorization
from repro.api.spec import SVDSpec
from repro.core._keys import resolve_key
from repro.core.operators import DenseOp, LowRankOp, as_operator

Array = jax.Array


def spec_to_dict(spec: SVDSpec) -> dict:
    """JSON-able spec (dtype names, not dtype objects) for manifests."""
    d = dataclasses.asdict(spec)
    d["dtype"] = None if spec.dtype is None else jnp.dtype(spec.dtype).name
    return d


def spec_from_dict(d: dict) -> SVDSpec:
    d = dict(d)
    if d.get("dtype") is not None:
        d["dtype"] = jnp.dtype(d["dtype"])
    return SVDSpec(**d)


def _load_newest_verified(directory: str):
    """(step, fact, meta) from the newest session checkpoint that both
    passes the CRC directory scan *and* actually loads; None when no step
    survives.  The two-layer check matters: the scan certifies bytes at
    scan time, the load re-verifies at read time — either failure falls
    back to the next older verified step instead of surfacing garbage."""
    from repro.checkpoint.store import load_session_state, valid_steps
    for step in valid_steps(directory):
        try:
            fact, meta = load_session_state(directory, step)
            return step, fact, meta
        except Exception:        # noqa: BLE001 — corrupt step: try older
            continue
    return None


def _cold_iters(spec: SVDSpec, shape) -> int:
    """The Krylov budget a cold solve actually runs (facade defaults —
    the ``k=None`` rule lives in ``repro.core.fsvd.default_k``)."""
    from repro.core.fsvd import default_k
    cold = spec.max_iters if spec.max_iters is not None \
        else default_k(spec.rank, shape)
    return max(min(cold, min(shape)), spec.rank)


def _default_refine_iters(spec: SVDSpec, shape) -> int:
    """Initial Krylov budget for a warm-started refine solve.

    A cold solve explores from a random vector and needs ``~4 r``
    iterations for the top-r Ritz values to converge; a warm start already
    lies in the (nearly invariant) previous subspace, so ``r`` iterations
    re-extract it and a modest slack absorbs the drift.  Never exceeds the
    cold budget — refine must be a strict saving.  This is only the
    *seed*: the session re-learns the budget from each solve's observed
    convergence trace (see ``Session._learn_refine_iters``).
    """
    return max(1, min(max(spec.rank + 8, (3 * spec.rank) // 2),
                      _cold_iters(spec, shape), min(shape)))


# budget learning: the per-iteration GK residual proxy (beta) collapses
# once the Krylov space has captured the reachable spectrum — the collapse
# index measures how hard THIS spectrum is (r for a gapped matrix, never
# for a flat one), which is exactly what the refine budget should track.
_DECAY_TOL = 3e-2      # "collapsed" = beta below this fraction of max beta
_DECAY_SLACK = 8       # iterations granted beyond the collapse index
_REFINE_CAP = 0.75     # hard-spectrum cap as a fraction of the cold budget
_BUDGET_QUANTUM = 4    # round budgets up to multiples (bounds recompiles)

# update gate learning: an update is accepted when its measured residual
# stays within a margin of the residual the *solver* itself achieves on
# this stream (the gate's reference), floored so exact-rank operands with
# ~eps residuals don't demand the impossible.  The reference comes only
# from solver-produced factorizations — update-produced residuals never
# ratchet the gate, so accumulated tail drift eventually fails the gate
# and falls back to a real solve, which re-anchors the reference.
_UPDATE_MARGIN = 4.0   # accepted when r_update <= margin * r_solver
_UPDATE_FLOOR = 1e-5   # parity gate floor (matches the acceptance gate)


class Session:
    """Stateful compile-once / solve-many tracker for one operand stream.

    Parameters
    ----------
    A             initial operand (anything ``factorize`` accepts).
    spec          solve configuration; ``method="auto"`` resolves
                  operator-aware, once.
    key           PRNG key stream seed; per-solve keys are folded in, so
                  one session key covers the whole stream.  Omitted: the
                  facade's implicit-key policy applies (warn + PRNGKey(0)).
    refine_iters  Krylov budget for warm refine solves (default: see
                  ``_default_refine_iters``).
    restart_angle refine/restart threshold on the drift sine in [0, 1]
                  (default 0.5 ≈ 30°).
    track_residuals
                  append the relative residual ``||AᵀU − VΣ||/||Σ||`` to
                  each history record (r extra matvecs + one host sync per
                  solve); disable for latency-critical streams.
    update_tol    parity gate for the zero-iteration update path taken by
                  :meth:`delta`/:meth:`downdate`.  ``None`` (default)
                  learns the gate from the stream (margin over the
                  solver's own residual, floored at 1e-5); a positive
                  float pins an absolute residual gate; ``0.0`` disables
                  the update path entirely (every delta folds + re-solves,
                  the pre-PR-7 behavior).
    sketch_tol    residual-probe gate for the sketch-reconstruct path
                  taken by :meth:`entries`.  Same convention as
                  ``update_tol``: ``None`` learns it (margin over the
                  probe of the solver's own factorization), a positive
                  float pins it, ``0.0`` disables the sketch path (every
                  entry batch folds + re-solves).
    """

    def __init__(self, A, spec: Optional[SVDSpec] = None, *,
                 key: Optional[Array] = None,
                 refine_iters: Optional[int] = None,
                 restart_angle: float = 0.5,
                 track_residuals: bool = True,
                 update_tol: Optional[float] = None,
                 sketch_tol: Optional[float] = None,
                 **overrides):
        spec = (spec or SVDSpec())
        if overrides:
            spec = spec.replace(**overrides)
        self.op = as_operator(A, backend=spec.backend)
        self.plan = _make_plan(spec, like=self.op)
        self.spec = self.plan.spec
        # an explicit refine_iters pins the budget; otherwise the session
        # seeds it optimistically and re-learns it from every solve's
        # convergence trace.
        self._auto_refine = refine_iters is None
        if refine_iters is None:
            refine_iters = _default_refine_iters(self.spec, self.op.shape)
        self.refine_iters = int(refine_iters)
        # the refine plan shares the resolved method but not the budget —
        # both executables live in the process-wide cache.
        self.refine_plan = _make_plan(
            self.spec.replace(max_iters=self.refine_iters), like=self.op)
        self.restart_angle = float(restart_angle)
        self.track_residuals = track_residuals
        self.update_tol = None if update_tol is None else float(update_tol)
        self.sketch_tol = None if sketch_tol is None else float(sketch_tol)
        self._key = key
        self._step = 0
        self.fact: Optional[Factorization] = None
        self._history: list[dict] = []
        # deferred state: the previous solve's ConvergenceInfo (budget
        # learning reads it at the START of the next solve, keeping the
        # solve itself sync-free) and the solver-residual gate reference.
        self._pending_info = None
        self._ref_residual: Optional[float] = None
        # sketch residency (the entries path): built lazily from the
        # pre-drift operand on the first entries() call, folded in place
        # after that, invalidated whenever the operand changes by a route
        # the sketch cannot fold (update(), beta != 1 deltas).
        self.sketch = None
        self._ref_probe: Optional[float] = None

    # --- key stream ---------------------------------------------------
    def _next_key(self, key: Optional[Array]) -> Array:
        if key is not None:
            return key
        if self._key is None:
            self._key = resolve_key(None, caller="session")
        return jax.random.fold_in(self._key, self._step)

    # --- drift measurement --------------------------------------------
    def drift(self, op=None) -> Optional[float]:
        """sin of the aggregate angle between span(U_prev) and the image
        of the previous right Ritz basis under the (new) operator; None
        before the first solve.  ~0 for an unchanged operator."""
        if self.fact is None:
            return None
        op = self.op if op is None else as_operator(
            op, backend=self.spec.backend)
        f = self.fact
        if (f.U.shape[0], f.V.shape[0]) != tuple(op.shape):
            # geometry changed under the session: the previous basis spans
            # nothing of the new operand — maximal drift, forcing the
            # restart branch instead of a shape-mismatched matmat.
            return float("inf")
        compute = jnp.promote_types(f.U.dtype, jnp.float32)
        U = f.U.astype(compute)
        B = op.matmat(f.V.astype(compute))          # (m, r): A' V_prev
        R = B - U @ (U.T @ B)                        # component off span(U)
        num = jnp.linalg.norm(R)
        den = jnp.maximum(jnp.linalg.norm(B), jnp.finfo(compute).tiny)
        return float(num / den)

    # --- solves -------------------------------------------------------
    def solve(self, *, key: Optional[Array] = None) -> Factorization:
        """Solve the current operand: cold on first use, tracked after."""
        return self._tracked_solve(key)

    def update(self, A, *, key: Optional[Array] = None) -> Factorization:
        """Replace the operand with ``A`` (a drifted version) and solve.

        Same-kind/shape operands reuse the session's staged executables;
        a structural change (different operator class / shape / mesh)
        simply compiles a fresh cache entry.
        """
        self.op = as_operator(A, backend=self.spec.backend)
        # wholesale replacement: the resident sketch describes the old
        # operand and nothing relates the two — drop it (rebuilt lazily).
        self.sketch = None
        return self._tracked_solve(key)

    def delta(self, delta_op, *, beta: float = 1.0,
              key: Optional[Array] = None) -> Factorization:
        """Apply an additive drift ``A ← beta * A + delta_op`` and solve.

        A ``LowRankOp`` delta first attempts the zero-iteration rank-k
        update (:meth:`SolverPlan.update`); acceptance is gated on the
        measured residual-after-update (see ``update_tol``).  Rejected or
        ineligible deltas fall back to the refine/restart policy.  Dense
        operands fold the delta in place (no ``SumOp`` structure growth,
        so long delta streams keep reusing the same staged executables).
        """
        dop = as_operator(delta_op, backend=self.spec.backend)
        return self._apply_delta(dop, beta, key, kind="update")

    def downdate(self, *, rows=None, cols=None,
                 key: Optional[Array] = None) -> Factorization:
        """Remove (zero) ``rows`` or ``cols`` of the tracked operand.

        The removal is itself a rank-|idx| delta derived from the current
        factorization (:func:`repro.core.update.row_removal_delta`), so it
        rides the same gated update path; dense operands are zeroed
        exactly, other operator kinds compose the removal delta.
        """
        if (rows is None) == (cols is None):
            raise ValueError("pass exactly one of rows= / cols=")
        if self.fact is None:
            raise RuntimeError("downdate requires a previous solve; call "
                               "solve() first")
        from repro.core.update import col_removal_delta, row_removal_delta
        dop = (row_removal_delta(self.fact, rows) if rows is not None
               else col_removal_delta(self.fact, cols))
        fold: Optional[Callable[[], Any]] = None
        if isinstance(self.op, DenseOp):
            base, A = self.op, self.op.A
            idx = jnp.asarray(rows if rows is not None else cols, jnp.int32)
            A2 = (A.at[idx, :].set(0) if rows is not None
                  else A.at[:, idx].set(0))
            fold = lambda: DenseOp(A2, backend=base.backend)  # noqa: E731
        return self._apply_delta(dop, 1.0, key, kind="downdate", fold=fold)

    def entries(self, rows, cols, vals, *,
                key: Optional[Array] = None) -> Factorization:
        """Apply an *unstructured* entrywise drift ``A[rows, cols] +=
        vals`` (COO triplets) and solve — the fourth policy branch.

        The session keeps a :class:`~repro.sketchres.state.SketchState`
        resident next to the operand (built lazily from the pre-drift
        operand on first use).  Each entry batch folds into BOTH the
        operand and the sketch (``SolverPlan.sketch_fold`` — the
        count-sketch scatter-add kernel, staged once per padded batch
        length); the answer is then reconstructed from the sketch panels
        alone with ZERO Krylov iterations and accepted only when

        * the sketch's staleness odometer (cumulative folded Frobenius
          mass vs. the coverage budget) has not tripped, and
        * the HMT residual probe of the reconstruction against the
          *post-drift* operand passes the gate (``sketch_tol``).

        A staleness trip re-sketches from the updated operand (odometer
        reset) and answers with a real tracked solve; a probe rejection
        falls back to refine/restart with the rejection annotated on the
        fallback record.  Either way the caller never receives an
        unverified reconstruction.  Dense operands only: an entrywise
        fold needs addressable storage.
        """
        if not isinstance(self.op, DenseOp):
            raise TypeError(
                "entries() folds COO triplets in place and needs a dense "
                f"operand; got {type(self.op).__name__}. Materialize the "
                "operand or express the drift as a LowRankOp via delta().")
        rows = jnp.asarray(rows, jnp.int32).reshape(-1)
        cols = jnp.asarray(cols, jnp.int32).reshape(-1)
        vals = jnp.asarray(vals).reshape(-1)
        if not (rows.shape == cols.shape == vals.shape):
            raise ValueError("rows/cols/vals must have equal lengths; got "
                             f"{rows.shape[0]}/{cols.shape[0]}/"
                             f"{vals.shape[0]}")
        enabled = self.sketch_tol is None or self.sketch_tol > 0.0
        if enabled and self.sketch is None:
            # sketch the PRE-drift operand: the fold below then brings the
            # sketch exactly up to date with the post-drift operand, and
            # the very first entries() call already answers from panels.
            self.sketch = self.plan.sketch(
                self.op, key=jax.random.fold_in(self._next_key(key), 1))
        A2 = self.op.A.at[rows, cols].add(vals.astype(self.op.A.dtype))
        new_op = DenseOp(A2, backend=self.op.backend)
        if not enabled:
            self.op = new_op
            return self._tracked_solve(key)
        from repro.sketchres import is_stale, staleness_ratio
        # the learned gate's reference probes self.fact against the
        # operand it described — the PRE-drift one — so form the gate
        # before the operand swap.
        gate = self._sketch_gate()
        self.sketch = self.plan.sketch_fold(self.sketch, rows, cols, vals)
        ratio = float(staleness_ratio(self.sketch))
        self.op = new_op
        if bool(is_stale(self.sketch)):
            # folds are exact, but cumulative drift past the coverage
            # budget means the panels may no longer capture the dominant
            # subspace — re-sketch from the operand (odometer reset) and
            # answer with a verified solve.
            self.sketch = self.plan.sketch(
                new_op, key=jax.random.fold_in(self._next_key(key), 2))
            fact = self._tracked_solve(key)
            self._history[-1]["sketch_stale"] = True
            self._history[-1]["staleness"] = ratio
            return fact
        if gate is not None:
            from repro.serve.resilience import residual_probe
            fact = self.plan.sketch_reconstruct(self.sketch)
            probe = residual_probe(np.asarray(new_op.A), fact,
                                   probes=4, seed=self._step)
            if probe <= gate:
                rec = {"step": self._step, "kind": "sketch", "drift": None,
                       "iterations": 0, "breakdown": False,
                       "probe": probe, "gate": gate, "staleness": ratio}
                if self.track_residuals:
                    rec["residual"] = self._residual(fact)
                self._history.append(rec)
                self.fact = fact
                self._step += 1
                return fact
            rejected = (probe, gate)
        else:
            # no reference factorization to learn the gate from yet (cold
            # stream): solve for real — the solve both answers and anchors
            # the probe reference for the next entries() call.
            rejected = None
        fact = self._tracked_solve(key)
        if rejected is not None:
            self._history[-1]["sketch_rejected"] = True
            self._history[-1]["probe"] = rejected[0]
            self._history[-1]["gate"] = rejected[1]
        return fact

    def _sketch_gate(self) -> Optional[float]:
        """Residual-probe acceptance gate for the sketch branch; None when
        it cannot be formed yet (learned gate with no prior solve)."""
        if self.sketch_tol is not None:
            return self.sketch_tol
        if self.fact is None:
            return None
        if self._ref_probe is None:
            # probe the solver-produced factorization once, lazily, against
            # the operand it described — sketch-produced probes never
            # ratchet the reference (same one-way rule as the update gate).
            if not isinstance(self.op, DenseOp):
                return None
            from repro.serve.resilience import residual_probe
            self._ref_probe = residual_probe(
                np.asarray(self.op.A), self.fact, probes=4,
                seed=self._step)
        return max(_UPDATE_FLOOR, _UPDATE_MARGIN * self._ref_probe)

    # --- the four-way policy ------------------------------------------
    def _fold(self, dop, beta):
        """The post-delta operand.  Dense operands absorb the delta (and
        any decay) in place — pytree structure, and therefore every staged
        executable, stays stable across arbitrarily long delta streams.
        Other operator kinds compose ``beta * op + dop``."""
        if isinstance(self.op, DenseOp) and isinstance(dop, LowRankOp):
            from repro.core.update import materialize_lowrank
            W = materialize_lowrank(dop, backend=self.op.backend,
                                    dtype=self.op.A.dtype)
            A = self.op.A if beta == 1.0 else beta * self.op.A
            return DenseOp(A + W, backend=self.op.backend)
        base = self.op if beta == 1.0 else beta * self.op
        return base + dop

    def _update_eligible(self, dop) -> bool:
        if self.fact is None or not isinstance(dop, LowRankOp):
            return False
        if self.update_tol is not None and self.update_tol <= 0.0:
            return False        # update_tol=0.0: update path disabled
        if tuple(dop.shape) != tuple(self.op.shape):
            return False
        from repro.core.update import delta_rank
        return self.fact.rank + delta_rank(dop) <= min(self.op.shape)

    def _update_gate(self) -> float:
        if self.update_tol is not None:
            return self.update_tol
        if self._ref_residual is None:
            # no solver residual on file (track_residuals off, or it was
            # invalidated by a newer solve): measure the current
            # factorization against the PRE-delta operand once, lazily.
            self._ref_residual = self._residual(self.fact)
        return max(_UPDATE_FLOOR, _UPDATE_MARGIN * self._ref_residual)

    def _apply_delta(self, dop, beta, key, kind: str,
                     fold: Optional[Callable[[], Any]] = None
                     ) -> Factorization:
        eligible = self._update_eligible(dop)
        gate = self._update_gate() if eligible else None
        new_op = self._fold(dop, beta) if fold is None else fold()
        if self.sketch is not None:
            if fold is None and beta == 1.0:
                # sketches are linear in A: the same delta that folds into
                # the operand folds into the panels (two panel GEMMs), so
                # a later entries() call resumes from live panels.
                self.sketch = self.plan.sketch_fold_delta(self.sketch, dop)
            else:
                # decayed (beta != 1) or custom-folded operands (downdate's
                # exact zeroing, where ``dop`` is only the factorization's
                # approximation of the change) diverge from what the panels
                # would track — drop the sketch rather than let it lie.
                self.sketch = None
        rejected = None
        if eligible:
            fact = self.plan.update(self.fact, dop, beta=beta)
            r_upd = self._residual(fact, op=new_op)
            if r_upd <= gate:
                self.op = new_op
                rec = {"step": self._step, "kind": kind, "drift": None,
                       "iterations": 0, "breakdown": False,
                       "residual_update": r_upd, "gate": gate}
                if self.track_residuals:
                    rec["residual"] = r_upd
                self._history.append(rec)
                self.fact = fact
                self._step += 1
                return fact
            rejected = (r_upd, gate)
        self.op = new_op
        fact = self._tracked_solve(key)
        if rejected is not None:
            # the fallback solve appended its own record; annotate it with
            # why the cheap path was not taken.
            self._history[-1]["update_rejected"] = True
            self._history[-1]["residual_update"] = rejected[0]
            self._history[-1]["gate"] = rejected[1]
        return fact

    def _learn_refine_iters(self, info) -> None:
        """Re-fit the refine budget to the observed GK residual trace.

        The collapse index of the beta trace is the number of iterations
        this spectrum actually needed; gapped spectra collapse at ~r (the
        optimistic seed holds), hard flat spectra never collapse (budget
        rises to the cap — still a strict saving over cold).  Budgets are
        quantized so the stream stages at most a handful of executables.
        """
        if not self._auto_refine or info is None or info.method != "gk":
            return
        res = np.asarray(info.residuals, np.float64)
        if res.size == 0 or res.max() <= 0.0:
            return
        cold = _cold_iters(self.spec, self.op.shape)
        floor = _default_refine_iters(self.spec, self.op.shape)
        cap = max(floor, int(np.ceil(_REFINE_CAP * cold)))
        idx = np.nonzero(res < _DECAY_TOL * res.max())[0]
        learned = int(idx[0]) + _DECAY_SLACK if idx.size else cap
        learned = -(-learned // _BUDGET_QUANTUM) * _BUDGET_QUANTUM
        learned = int(np.clip(learned, floor, cap))
        if learned != self.refine_iters:
            self.refine_iters = learned
            self.refine_plan = _make_plan(
                self.spec.replace(max_iters=learned), like=self.op)

    def _tracked_solve(self, key: Optional[Array]) -> Factorization:
        # budget learning reads the PREVIOUS solve's residual trace here —
        # before this solve picks its plan — so the learning timeline
        # matches eager processing while the solve that produced the trace
        # returned without blocking on it.
        if self._pending_info is not None:
            info, self._pending_info = self._pending_info, None
            self._learn_refine_iters(info)
        drift = self.drift() if self.fact is not None else None
        refine = drift is not None and drift <= self.restart_angle
        if refine:
            q1 = self.fact.warm_start()
            # key-consuming methods (the sketch) draw from the session's
            # key stream even on refines — q1 has no warm-start seam there
            rkey = self._next_key(key) if method_needs_key(
                self.plan.method) else key
            fact, info = self.refine_plan.solve(self.op, key=rkey, q1=q1,
                                                with_info=True)
            kind = "refine"
        else:
            fact, info = self.plan.solve(self.op, key=self._next_key(key),
                                         with_info=True)
            kind = "cold" if drift is None else "restart"
        budget = self.refine_iters if refine else None
        self._pending_info = info
        # iterations/breakdown stay device scalars here — `history` /
        # `meta()` materialize them on read, so latency-critical streams
        # (track_residuals=False) never block on this record.
        rec = {"step": self._step, "kind": kind, "drift": drift,
               "iterations": fact.iterations,
               "breakdown": fact.breakdown}
        if budget is not None:
            rec["budget"] = budget
        if self.track_residuals:
            rec["residual"] = self._residual(fact)
            self._ref_residual = rec["residual"]
        else:
            # the old reference described a superseded factorization; the
            # update gate re-measures lazily when next needed.
            self._ref_residual = None
        # a fresh solver factorization re-anchors the sketch gate too
        self._ref_probe = None
        self._history.append(rec)
        self.fact = fact
        self._step += 1
        return fact

    def _residual(self, fact: Factorization, op=None) -> float:
        op = self.op if op is None else op
        compute = jnp.promote_types(fact.U.dtype, jnp.float32)
        ATU = op.rmatmat(fact.U.astype(compute))
        num = jnp.linalg.norm(ATU - fact.V.astype(compute)
                              * fact.s[None, :].astype(compute))
        return float(num / jnp.maximum(jnp.linalg.norm(fact.s), 1e-30))

    # --- bookkeeping ---------------------------------------------------
    @property
    def solves(self) -> int:
        return self._step

    @property
    def history(self) -> list[dict]:
        """Per-step records.  Device scalars recorded by solves are
        materialized (in place, once) on first read — reading history is
        the sync point, not the solve that appended the record."""
        for rec in self._history:
            for k, v in rec.items():
                if isinstance(v, (jax.Array, np.generic)):
                    rec[k] = v.item()
        return self._history

    @history.setter
    def history(self, value) -> None:
        self._history = list(value)

    def counts(self) -> dict:
        """Per-kind step counts over the history.  Always includes the
        solver kinds (``cold``/``refine``/``restart``); ``update`` /
        ``downdate`` keys appear once those paths have been taken."""
        out = {"cold": 0, "refine": 0, "restart": 0}
        for rec in self._history:
            out[rec["kind"]] = out.get(rec["kind"], 0) + 1
        return out

    def meta(self) -> dict:
        """JSON-able session metadata (manifest ``extra`` payload)."""
        c = self.counts()
        return {"spec": spec_to_dict(self.spec), "method": self.plan.method,
                "refine_iters": self.refine_iters,
                "auto_refine": self._auto_refine,
                "restart_angle": self.restart_angle,
                "track_residuals": self.track_residuals,
                "update_tol": self.update_tol,
                "sketch_tol": self.sketch_tol,
                "updates": c.get("update", 0) + c.get("downdate", 0),
                "sketches": c.get("sketch", 0),
                "step": self._step, "history": self.history}

    # --- persistence ----------------------------------------------------
    def save(self, directory: str, step: Optional[int] = None, *,
             keep: int = 0) -> str:
        """Atomic checkpoint of the tracking state (previous factorization
        + plan spec + history) via ``repro.checkpoint``.  ``keep > 0``
        prunes old session states to the newest ``keep``."""
        from repro.checkpoint.store import save_session_state
        return save_session_state(directory,
                                  self._step if step is None else step,
                                  self, keep=keep)

    def load_latest(self, directory: str) -> bool:
        """Restore tracking state in place from the newest *verified*
        session checkpoint under ``directory``; False when none exists.

        Walks the verified steps newest-first: a checkpoint that passes
        the directory scan but fails at read time (bit-rot between scan
        and load, a truncated leaf) is skipped and the next older
        verified step restores instead — recovery degrades to an earlier
        state, never to a corrupt one.
        """
        from repro.runtime import faults
        faults.fire(faults.SESSION_RESTORE)
        loaded = _load_newest_verified(directory)
        if loaded is None:
            return False
        step, fact, meta = loaded
        if meta["spec"] != spec_to_dict(self.spec):
            import warnings
            warnings.warn(
                "session checkpoint was written under a different spec "
                f"({meta['spec']} != {spec_to_dict(self.spec)}); restoring "
                "its factorization anyway — the next solve re-tracks under "
                "the current spec.", stacklevel=2)
        self.fact = fact
        self._step = int(meta["step"])
        self.history = list(meta["history"])
        self._auto_refine = bool(meta.get("auto_refine",
                                          self._auto_refine))
        self.restart_angle = float(meta.get("restart_angle",
                                            self.restart_angle))
        self.track_residuals = bool(meta.get("track_residuals",
                                             self.track_residuals))
        if "update_tol" in meta:
            tol = meta["update_tol"]
            self.update_tol = None if tol is None else float(tol)
        if "sketch_tol" in meta:
            tol = meta["sketch_tol"]
            self.sketch_tol = None if tol is None else float(tol)
        self._ref_residual = None
        self._pending_info = None
        # sketches are cheap to rebuild and expensive to checkpoint-verify;
        # a restored session re-sketches lazily on its next entries() call.
        self.sketch = None
        self._ref_probe = None
        learned = int(meta.get("refine_iters", self.refine_iters))
        if learned != self.refine_iters:
            self.refine_iters = learned
            self.refine_plan = _make_plan(
                self.spec.replace(max_iters=learned), like=self.op)
        return True

    @classmethod
    def restore(cls, directory: str, A, *, key: Optional[Array] = None,
                step: Optional[int] = None) -> "Session":
        """Rebuild a session around operand ``A`` from a checkpoint —
        spec, factorization, policy knobs and history all come from the
        manifest.  With ``step=None`` the newest checkpoint that passes
        its CRC verification restores (corrupted newer steps are skipped,
        same fallback as :meth:`load_latest`)."""
        from repro.checkpoint.store import load_session_state
        from repro.runtime import faults
        faults.fire(faults.SESSION_RESTORE)
        if step is None:
            loaded = _load_newest_verified(directory)
            if loaded is None:
                raise FileNotFoundError(
                    f"no valid session checkpoint under {directory!r}")
            step, fact, meta = loaded
        else:
            fact, meta = load_session_state(directory, step)
        sess = cls(A, spec_from_dict(meta["spec"]), key=key,
                   refine_iters=meta.get("refine_iters"),
                   restart_angle=meta.get("restart_angle", 0.5),
                   track_residuals=meta.get("track_residuals", True),
                   update_tol=meta.get("update_tol"),
                   sketch_tol=meta.get("sketch_tol"))
        # carry the learned budget but keep learning if the original did
        sess._auto_refine = bool(meta.get("auto_refine", True))
        sess.fact = fact
        sess._step = int(meta["step"])
        sess.history = list(meta["history"])
        return sess


def session(A, spec: Optional[SVDSpec] = None, *,
            key: Optional[Array] = None, **kwargs) -> Session:
    """Build a :class:`Session` (keyword conveniences as in ``plan``)."""
    return Session(A, spec, key=key, **kwargs)
