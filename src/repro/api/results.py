"""Unified result types for the solver facade.

``Factorization`` subsumes the per-solver result tuples (FSVDResult,
RSVDResult): same fields whichever solver produced it, registered as a
pytree (``method`` rides in aux data) so results flow through jit / vmap /
checkpointing like any array bundle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class Factorization:
    """Partial SVD  A ≈ U diag(s) Vᵀ.

    iterations — GK iterations actually used (F-SVD; doubles as the Alg-1
                 rank estimate) or power iterations performed (R-SVD).
    breakdown  — did the GK breakdown criterion fire (always False for
                 sketch-based solvers).
    method     — solver that produced this (static; survives pytree ops).
    """

    U: Array
    s: Array
    V: Array
    iterations: Array
    breakdown: Array
    method: str = "fsvd"

    @property
    def rank(self) -> int:
        return self.s.shape[0]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.U.shape[0], self.V.shape[0])

    def reconstruct(self) -> Array:
        """Materialize U diag(s) Vᵀ (tests / retraction only)."""
        return (self.U * self.s[None, :]) @ self.V.T

    def errors(self, A) -> dict:
        """The paper's Table-2 metrics: relative ||AᵀU − VΣ||_F/||Σ||_F and
        (for dense operands) residual ||A − UΣVᵀ||_F."""
        from repro.core.fsvd import truncated_svd_errors
        return truncated_svd_errors(A, self)

    def as_operator(self):
        """The factorization itself as a LowRankOp (e.g. to feed back into
        the solvers or the manifold machinery)."""
        from repro.core.operators import LowRankOp
        return LowRankOp(self.U, self.s, self.V.T)

    def warm_start(self) -> Array:
        """Left start vector q1 for warm-starting the next GK solve on the
        same or a nearby operator: the sigma-weighted blend ``U @ s`` of the
        computed left subspace.  (A single exact singular vector would be an
        invariant direction — GK would break down after one step — so the
        blend spreads the start across all computed directions, letting the
        solver re-extract the whole subspace in ~rank iterations.)

        Always returned in the *compute* dtype: under ``precision="bf16"``
        the stored U is half-width, and a q1 inheriting that storage dtype
        would seed the next solve's CGS2 with bf16 rounding noise — the
        warm start would start the recurrence at the narrow storage's
        noise floor instead of the compute dtype's.
        """
        compute = jnp.promote_types(self.U.dtype, jnp.float32)
        return self.U.astype(compute) @ self.s.astype(compute)


def _fact_flatten(f: Factorization):
    return ((f.U, f.s, f.V, f.iterations, f.breakdown), (f.method,))


def _fact_unflatten(aux, children):
    return Factorization(*children, method=aux[0])


jax.tree_util.register_pytree_node(Factorization, _fact_flatten,
                                   _fact_unflatten)


@dataclasses.dataclass(frozen=True, eq=False)
class RankEstimate:
    """Numerical-rank determination result (paper Alg 3).

    rank        — accurate numerical rank (eigenvalue count above tol).
    iterations  — Alg-1 GK iteration count at termination (the first,
                  slightly loose estimate).
    eigenvalues — Ritz values of BᵀB, descending (−inf padded).
    """

    rank: Array
    iterations: Array
    eigenvalues: Array
    method: str = "gk"

    def __int__(self) -> int:
        return int(self.rank)


def _rank_flatten(r: RankEstimate):
    return ((r.rank, r.iterations, r.eigenvalues), (r.method,))


def _rank_unflatten(aux, children):
    return RankEstimate(*children, method=aux[0])


jax.tree_util.register_pytree_node(RankEstimate, _rank_flatten,
                                   _rank_unflatten)
