"""repro.api — the spec-driven solver facade (the public entry point).

Three layers since PR 5:

    from repro.api import SVDSpec, factorize, plan, session

    fact = factorize(A, SVDSpec(method="fsvd", rank=20), key=key)  # one-shot
    p = plan(SVDSpec(rank=20), like=A); p.solve(A, key=key)        # compile
                                                                   # once,
                                                                   # solve many
    sess = session(A, rank=20, key=key)                            # track a
    sess.solve(); sess.update(A_drifted); sess.history             # drifting
                                                                   # operator

``plan`` resolves method/backend/placement once and memoizes compiled
solvers process-wide (the cache key includes the operand kind, shape,
dtype and mesh); ``session`` adds warm-started tracking with a
restart-vs-refine decision from the subspace angle, residual history via
the ``ConvergenceInfo`` diagnostics, and checkpointable state.

Everything — dense arrays, implicit low-rank operators (``LowRankOp``),
operator algebra (``A.T``, ``A + B``, ``alpha * A``), pod-sharded operators
(``repro.distributed.ShardedOp``) — goes through the same calls; the
solver registry (``register_solver``) lets extensions plug in new methods.

The legacy per-solver entry points (``repro.core.fsvd/rsvd/numerical_rank``)
remain as deprecated shims.
"""
from repro.api.facade import (estimate_rank, factorize, factorize_jit,
                              resolve_method)
from repro.api.callbacks import (CaptureCallback, ConvergenceCallback,
                                 ConvergenceInfo, RecordingCallback)
from repro.api.plan import (SolverPlan, clear_plan_cache, plan,
                            plan_cache_stats, register_ingraph_method,
                            trace_count)
from repro.api.registry import (available_solvers, get_solver,
                                register_solver)
from repro.api.results import Factorization, RankEstimate
from repro.api.session import Session, session
from repro.api.spec import METHODS, SVDSpec
from repro.core._keys import ImplicitKeyWarning, resolve_key
from repro.core.operators import (DenseOp, GramOp, KroneckerOp, LowRankOp,
                                  Operator, ScaledOp, SinglePassOp,
                                  SparseOp, SumOp, TransposedOp,
                                  as_operator)
from repro.core.update import (downdate_cols, downdate_rows,
                               update_factorization)

# importing the module registers the built-in solvers
from repro.api import solvers as _solvers  # noqa: E402,F401  (side effect)

_resolve_key = resolve_key   # the facade's canonical key helper

__all__ = [
    "SVDSpec", "METHODS", "factorize", "factorize_jit", "estimate_rank",
    "resolve_method",
    "plan", "SolverPlan", "clear_plan_cache", "plan_cache_stats",
    "trace_count", "register_ingraph_method",
    "session", "Session",
    "update_factorization", "downdate_rows", "downdate_cols",
    "ConvergenceInfo", "ConvergenceCallback", "RecordingCallback",
    "CaptureCallback",
    "Factorization", "RankEstimate",
    "register_solver", "get_solver", "available_solvers",
    "Operator", "DenseOp", "LowRankOp", "SumOp", "ScaledOp",
    "TransposedOp", "SparseOp", "KroneckerOp", "GramOp", "SinglePassOp",
    "as_operator",
    "resolve_key", "ImplicitKeyWarning",
]
