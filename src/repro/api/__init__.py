"""repro.api — the spec-driven solver facade (the public entry point).

    from repro.api import SVDSpec, factorize, estimate_rank

    fact = factorize(A, SVDSpec(method="fsvd", rank=20), key=key)
    fact.reconstruct();  fact.errors(A);  fact.warm_start()

    est = estimate_rank(A, key=key)      # paper Alg 3
    int(est.rank), int(est.iterations)

Everything — dense arrays, implicit low-rank operators (``LowRankOp``),
operator algebra (``A.T``, ``A + B``, ``alpha * A``), pod-sharded operators
(``repro.distributed.ShardedOp``) — goes through the same two calls; the
solver registry (``register_solver``) lets extensions plug in new methods.

The legacy per-solver entry points (``repro.core.fsvd/rsvd/numerical_rank``)
remain as deprecated shims.
"""
from repro.api.facade import (estimate_rank, factorize, factorize_jit,
                              resolve_method)
from repro.api.registry import (available_solvers, get_solver,
                                register_solver)
from repro.api.results import Factorization, RankEstimate
from repro.api.spec import METHODS, SVDSpec
from repro.core._keys import ImplicitKeyWarning, resolve_key
from repro.core.operators import (DenseOp, GramOp, KroneckerOp, LowRankOp,
                                  Operator, ScaledOp, SparseOp, SumOp,
                                  TransposedOp, as_operator)

# importing the module registers the built-in solvers
from repro.api import solvers as _solvers  # noqa: E402,F401  (side effect)

_resolve_key = resolve_key   # the facade's canonical key helper

__all__ = [
    "SVDSpec", "METHODS", "factorize", "factorize_jit", "estimate_rank",
    "resolve_method",
    "Factorization", "RankEstimate",
    "register_solver", "get_solver", "available_solvers",
    "Operator", "DenseOp", "LowRankOp", "SumOp", "ScaledOp",
    "TransposedOp", "SparseOp", "KroneckerOp", "GramOp", "as_operator",
    "resolve_key", "ImplicitKeyWarning",
]
