"""Built-in solver registrations for the facade.

Both solvers take the same (operator, spec, key, q1) inputs and return the
same :class:`~repro.api.results.Factorization` — HMT randomized SVD and GK
block-Krylov F-SVD are interchangeable points on one accuracy/cost curve.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.registry import register_solver
from repro.api.results import Factorization
from repro.api.spec import SVDSpec
from repro.core._keys import resolve_key
from repro.core.fsvd import fsvd as _fsvd
from repro.core.rsvd import rsvd as _rsvd

Array = jax.Array


@register_solver("fsvd")
def solve_fsvd(A, spec: SVDSpec, *, key: Optional[Array] = None,
               q1: Optional[Array] = None) -> Factorization:
    """Paper Alg 2: k-step GK bidiagonalization + Ritz extraction."""
    if q1 is None:
        key = resolve_key(key, caller="factorize(method='fsvd')")
    res = _fsvd(A, spec.rank, spec.max_iters, key=key, q1=q1,
                eps=spec.tol, relative_eps=spec.relative_tol,
                reorth_passes=spec.reorth_passes,
                host_loop=bool(spec.host_loop), dtype=spec.dtype)
    return Factorization(res.U, res.s, res.V, res.kprime, res.breakdown,
                         method="fsvd")


@register_solver("rsvd")
def solve_rsvd(A, spec: SVDSpec, *, key: Optional[Array] = None,
               q1: Optional[Array] = None) -> Factorization:
    """HMT 2011 randomized range sketch (+ optional power iterations).

    ``q1`` is accepted for signature parity but unused — sketching has no
    warm-start seam.
    """
    key = resolve_key(key, caller="factorize(method='rsvd')")
    res = _rsvd(A, spec.rank, p=spec.oversample,
                power_iters=spec.power_iters, key=key, dtype=spec.dtype)
    return Factorization(
        res.U, res.s, res.V,
        iterations=jnp.asarray(spec.power_iters, jnp.int32),
        breakdown=jnp.asarray(False), method="rsvd")
