"""Built-in solver registrations for the facade.

All solvers take the same (operator, spec, key, q1) inputs and return the
same :class:`~repro.api.results.Factorization` — HMT randomized SVD, GK
block-Krylov F-SVD and the streaming blocked variant are interchangeable
points on one accuracy/cost/memory trade-off surface.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.registry import register_solver
from repro.api.results import Factorization
from repro.api.spec import SVDSpec
from repro.core._keys import resolve_key
from repro.core.fsvd import fsvd as _fsvd
from repro.core.gk_block import fsvd_blocked as _fsvd_blocked
from repro.core.rsvd import rsvd as _rsvd
from repro.core.sketch import gnystrom as _gnystrom
from repro.core.sketch import rbk as _rbk

Array = jax.Array


@register_solver("fsvd")
def solve_fsvd(A, spec: SVDSpec, *, key: Optional[Array] = None,
               q1: Optional[Array] = None, callback=None) -> Factorization:
    """Paper Alg 2: k-step GK bidiagonalization + Ritz extraction."""
    if q1 is None:
        key = resolve_key(key, caller="factorize(method='fsvd')")
    res = _fsvd(A, spec.rank, spec.max_iters, key=key, q1=q1,
                eps=spec.tol, relative_eps=spec.relative_tol,
                reorth_passes=spec.reorth_passes,
                host_loop=bool(spec.host_loop), dtype=spec.dtype,
                precision=spec.precision, callback=callback)
    return Factorization(res.U, res.s, res.V, res.kprime, res.breakdown,
                         method="fsvd")


@register_solver("rsvd")
def solve_rsvd(A, spec: SVDSpec, *, key: Optional[Array] = None,
               q1: Optional[Array] = None, callback=None) -> Factorization:
    """HMT 2011 randomized range sketch (+ optional power iterations).

    ``q1`` is accepted for signature parity but unused — sketching has no
    warm-start seam.
    """
    key = resolve_key(key, caller="factorize(method='rsvd')")
    res = _rsvd(A, spec.rank, p=spec.oversample,
                power_iters=spec.power_iters, key=key, dtype=spec.dtype,
                precision=spec.precision, callback=callback)
    return Factorization(
        res.U, res.s, res.V,
        iterations=jnp.asarray(spec.power_iters, jnp.int32),
        breakdown=jnp.asarray(False), method="rsvd")


@register_solver("rbk")
def solve_rbk(A, spec: SVDSpec, *, key: Optional[Array] = None,
              q1: Optional[Array] = None, callback=None) -> Factorization:
    """Musco–Musco randomized block Krylov: sketch start, ``spec.passes``
    expansions of ``Aᵀ(A·)``, Rayleigh–Ritz extraction — gap-independent
    accuracy per pass where power-iterated R-SVD degrades.

    ``q1`` is accepted for signature parity but unused — the Krylov space
    starts from a fresh sketch block.
    """
    key = resolve_key(key, caller="factorize(method='rbk')")
    res = _rbk(A, spec.rank, passes=spec.passes,
               sketch_dim=spec.sketch_dim, kind=spec.sketch_kind,
               oversample=spec.oversample, key=key, dtype=spec.dtype,
               precision=spec.precision, backend=spec.backend,
               callback=callback)
    return Factorization(res.U, res.s, res.V, iterations=res.passes,
                         breakdown=jnp.asarray(False), method="rbk")


@register_solver("gnystrom")
def solve_gnystrom(A, spec: SVDSpec, *, key: Optional[Array] = None,
                   q1: Optional[Array] = None,
                   callback=None) -> Factorization:
    """Generalized Nyström: both sketches (``AΩ``, ``ΨᵀA``) captured in
    ONE sweep over the operator, core solve via stabilized pseudo-inverse
    — the solver for operands affordable to touch exactly once
    (``Operator.single_pass_only``) and the serve breaker's shed plan.

    ``q1`` is accepted for signature parity but unused.
    """
    key = resolve_key(key, caller="factorize(method='gnystrom')")
    res = _gnystrom(A, spec.rank, sketch_dim=spec.sketch_dim,
                    kind=spec.sketch_kind, oversample=spec.oversample,
                    key=key, dtype=spec.dtype, precision=spec.precision,
                    backend=spec.backend, callback=callback)
    return Factorization(res.U, res.s, res.V, iterations=res.passes,
                         breakdown=jnp.asarray(False), method="gnystrom")


@register_solver("fsvd_blocked")
def solve_fsvd_blocked(A, spec: SVDSpec, *, key: Optional[Array] = None,
                       q1: Optional[Array] = None,
                       callback=None) -> Factorization:
    """Streaming block-GK with Ritz locking + thick restart — for operators
    whose dense form would not fit memory (sparse / Kronecker / sharded).

    ``spec.block_size`` is the expansion block width, ``spec.max_basis`` the
    memory budget (max retained right-basis vectors), ``spec.max_iters`` the
    restart-cycle cap.  ``q1`` warm-starts the first block via ``Aᵀq1``.
    """
    if q1 is None:
        key = resolve_key(key, caller="factorize(method='fsvd_blocked')")
    res = _fsvd_blocked(A, spec.rank, block=spec.block_size,
                        max_basis=spec.max_basis, tol=spec.tol,
                        relative_tol=spec.relative_tol,
                        max_restarts=spec.max_iters or 40, key=key, q1=q1,
                        reorth_passes=spec.reorth_passes, dtype=spec.dtype,
                        precision=spec.precision, callback=callback)
    return Factorization(res.U, res.s, res.V,
                         iterations=jnp.asarray(res.block_passes, jnp.int32),
                         breakdown=jnp.asarray(not res.converged),
                         method="fsvd_blocked")
