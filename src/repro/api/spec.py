"""SVDSpec — one declarative knob set for every low-rank solver.

Halko-Martinsson-Tropp randomized SVD and GK block-Krylov F-SVD are points
on one accuracy/cost trade-off curve; the spec names the point and
:func:`repro.api.factorize` picks/runs the solver.  The spec is a frozen,
hashable dataclass so it can be closed over by ``jit`` (it is static
configuration, never traced).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

METHODS = ("auto", "fsvd", "rsvd", "fsvd_blocked", "fsvd_sharded", "rbk",
           "gnystrom")

SKETCH_KINDS = ("sparse_sign", "gaussian")


@dataclasses.dataclass(frozen=True)
class SVDSpec:
    """Declarative description of a partial-SVD / rank-estimation solve.

    method        "fsvd" (paper Alg 2), "rsvd" (HMT baseline), "auto"
                  (operator-aware: sharded operands -> "fsvd_sharded",
                  matrix-free sparse/Kronecker/Gram operands -> the
                  streaming "fsvd_blocked"; dense operands pick F-SVD
                  unless the tolerance is loose enough that a sketch is
                  sufficient), or any name registered via
                  ``repro.api.register_solver``.
    rank          number of dominant triplets wanted (r).
    max_iters     GK iteration budget k (fsvd) or the iteration cap for
                  rank estimation; None = per-method default
                  (``min(4 rank, min(m, n))`` for F-SVD, ``min(m, n)``
                  for rank estimation).
    tol           breakdown / termination epsilon (paper eps, default 1e-8).
    relative_tol  scale tol by ||A|| (float32-safe reading of the paper's
                  absolute threshold; see core/gk.py).
    reorth_passes CGS passes per Lanczos step ("twice is enough").
    oversample    R-SVD oversampling p (paper default 10); also the
                  default sketch-width pad for "rbk" / "gnystrom" when
                  ``sketch_dim`` is unset.
    power_iters   R-SVD subspace iterations q.
    sketch_dim    rbk/gnystrom: sketch block width (rbk's Krylov block,
                  gnystrom's right-panel width k; its co-range panel is
                  2k).  None = ``rank + oversample`` clamped to
                  ``min(m, n)``.
    passes        rbk: number of ``Aᵀ(A·)`` Krylov expansions q — the
                  operator sweep budget is ``2·passes + 1``.  (gnystrom
                  ignores it: single-pass by construction.)
    sketch_kind   "sparse_sign" (ζ nonzeros/col ±1/√ζ; streamable via the
                  sketch kernel) or "gaussian" (dense HMT ensemble).
    backend       "xla" | "pallas" — how dense inputs are wrapped
                  (subsumes the old ``from_dense(use_kernels=...)``).
    block_size    fsvd_blocked: Krylov expansion block width b (None =
                  ``min(max(8, min(rank, 32)), min(m, n))``).
    max_basis     fsvd_blocked: memory budget — max right-basis vectors
                  held before a thick restart (None = ``max(3 rank,
                  rank + 2 b)``, clamped to ``min(m, n)``).
    precision     basis *storage* width: None (= compute dtype), "f32",
                  or "bf16" (bases live half-width in HBM; every
                  reduction/accumulation stays in the compute dtype).
                  The GK breakdown threshold widens to the storage's CGS2
                  noise floor (~eps_bf16² relative), so "bf16" is a
                  throughput mode for fixed-k factorization — rank
                  *detection* resolution degrades to that floor and wants
                  full precision.
    dtype         compute dtype override (None = promote input to f32).
    host_loop     True = host-side Python loop with real early exit
                  (paper wall-time behaviour); False = in-graph fori_loop
                  (jit/vmap-able); None = per-entry-point default
                  (False for factorize, True for estimate_rank).
                  ``method="fsvd_sharded"`` rejects an explicit True: a
                  host loop on a sharded operand stalls the whole mesh on
                  a host round-trip every iteration.

    ``METHODS`` lists the built-in names; "fsvd_sharded" registers on
    import of ``repro.distributed.gk_dist`` and requires a sharded
    operand (any other method accepts sharded operands too — the facade
    is operator-agnostic).
    """

    method: str = "auto"
    rank: int = 10
    max_iters: Optional[int] = None
    tol: float = 1e-8
    relative_tol: bool = True
    reorth_passes: int = 2
    oversample: int = 10
    power_iters: int = 0
    sketch_dim: Optional[int] = None
    passes: int = 2
    sketch_kind: str = "sparse_sign"
    backend: str = "xla"
    block_size: Optional[int] = None
    max_basis: Optional[int] = None
    precision: Optional[str] = None
    dtype: Any = None
    host_loop: Optional[bool] = None

    def __post_init__(self):
        if self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")
        if self.block_size is not None and self.block_size < 1:
            raise ValueError(
                f"block_size must be >= 1, got {self.block_size}")
        if self.max_basis is not None and self.max_basis < 1:
            raise ValueError(f"max_basis must be >= 1, got {self.max_basis}")
        if self.sketch_dim is not None and self.sketch_dim < 1:
            raise ValueError(
                f"sketch_dim must be >= 1, got {self.sketch_dim}")
        if self.passes < 0:
            raise ValueError(f"passes must be >= 0, got {self.passes}")
        if self.method == "rbk" and self.passes == 0:
            raise ValueError(
                "method='rbk' is the iterative randomized block-Krylov "
                "solver and needs at least one pass over the operand; "
                "passes=0 (sketch-only) is the gnystrom regime — use "
                "method='gnystrom' instead")
        if self.method in ("rbk", "gnystrom") and \
                self.sketch_dim is not None and self.sketch_dim < self.rank:
            raise ValueError(
                f"sketch_dim={self.sketch_dim} cannot resolve rank="
                f"{self.rank}: the sketch panel must span at least the "
                "requested rank (sketch_dim >= rank; leave sketch_dim=None "
                "for the oversampled default)")
        if self.sketch_kind not in SKETCH_KINDS:
            raise ValueError(
                f"sketch_kind must be one of {SKETCH_KINDS}, got "
                f"{self.sketch_kind!r}")
        if self.backend not in ("xla", "pallas"):
            raise ValueError(
                f"backend must be 'xla' or 'pallas', got {self.backend!r}")
        if self.precision not in (None, "f32", "bf16"):
            raise ValueError(
                "precision must be None, 'f32' or 'bf16', got "
                f"{self.precision!r}")

    def replace(self, **changes) -> "SVDSpec":
        return dataclasses.replace(self, **changes)
