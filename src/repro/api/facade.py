"""`factorize` / `estimate_rank` — the one seam every workload goes through.

Dense arrays, implicit low-rank operators, pod-sharded operators and legacy
``LinOp`` closures all enter here; the spec picks the solver; a unified
``Factorization`` / ``RankEstimate`` comes back.  Because operators and
results are pytrees, the facade composes with jax transforms:

    batched = jax.vmap(lambda op: factorize(op, spec, key=key))(stacked_op)

runs a batched partial SVD over a stacked ``DenseOp`` with no extra code.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.api.registry import get_solver
from repro.api.results import Factorization, RankEstimate
from repro.api.spec import SVDSpec
from repro.core._keys import resolve_key
from repro.core.operators import as_operator, sharding_mesh
from repro.core.rank import numerical_rank as _numerical_rank

Array = jax.Array

# "auto" heuristic: the GK solver tracks the paper's accuracy (relative
# errors at roundoff level); the sketch is cheaper per pass but its tail
# triplets degrade (paper Fig 1).  A loose tolerance or an explicit
# power-iteration request signals the caller is on the sketch side of the
# trade-off curve.
_AUTO_SKETCH_TOL = 1e-4


def resolve_method(spec: SVDSpec) -> str:
    """Resolve ``method="auto"`` to a registered solver name."""
    if spec.method != "auto":
        return spec.method
    if spec.power_iters > 0 or spec.tol >= _AUTO_SKETCH_TOL:
        return "rsvd"
    return "fsvd"


def factorize(A, spec: Optional[SVDSpec] = None, *,
              key: Optional[Array] = None, q1: Optional[Array] = None,
              **overrides) -> Factorization:
    """Rank-``spec.rank`` partial SVD of ``A`` under ``spec``.

    ``A`` — dense array, any ``repro.core.operators`` operator, a sharded
    operator, or a legacy ``LinOp``.
    ``key`` — PRNG key for the start vector / sketch (warns and falls back
    to ``PRNGKey(0)`` when omitted).
    ``q1`` — optional GK warm-start vector (e.g. ``prev.warm_start()``).
    Keyword overrides are merged into the spec:
    ``factorize(A, rank=20)`` == ``factorize(A, SVDSpec(rank=20))``.
    """
    spec = (spec or SVDSpec())
    if overrides:
        spec = spec.replace(**overrides)
    op = as_operator(A, backend=spec.backend)
    solver = get_solver(resolve_method(spec))
    return solver(op, spec, key=key, q1=q1)


# solvers that run a host-side Python loop (real early exit / restarts)
# cannot be staged into a single XLA program.
_HOST_SIDE_METHODS = frozenset({"fsvd_blocked"})


def factorize_jit(spec: SVDSpec, *, donate_q1: bool = True):
    """A jit-compiled ``fn(A, key, q1) -> Factorization`` specialized to
    ``spec``, with the warm-start buffer donated on accelerator backends.

    The GK start vector ``q1`` is consumed on entry (normalized into the
    first basis column), so its HBM allocation is dead for the rest of the
    solve — donation lets XLA reuse it for an output instead of holding
    both live.  Donation is only requested on TPU/GPU (CPU ignores it with
    a per-call warning).  Pass ``q1=None`` to use the keyed start vector.

    Host-loop specs (``host_loop=True`` or a host-side method such as
    ``fsvd_blocked``) cannot be staged into one XLA program and are
    rejected.
    """
    method = resolve_method(spec)
    if spec.host_loop or method in _HOST_SIDE_METHODS:
        raise ValueError(
            f"factorize_jit requires an in-graph solver; method={method!r} "
            f"host_loop={spec.host_loop!r} runs a host-side loop")
    solver = get_solver(method)

    def run(A, key, q1):
        return solver(as_operator(A, backend=spec.backend), spec,
                      key=key, q1=q1)

    donate = (2,) if donate_q1 and jax.default_backend() in ("tpu", "gpu") \
        else ()
    return jax.jit(run, donate_argnums=donate)


def estimate_rank(A, spec: Optional[SVDSpec] = None, *,
                  key: Optional[Array] = None,
                  sigma_tol: Optional[float] = None,
                  **overrides) -> RankEstimate:
    """Numerical rank of ``A`` (paper Alg 3) under ``spec``.

    ``spec.max_iters`` caps the GK sweep (default: ``min(m, n)``);
    ``spec.tol`` is the Alg-1 breakdown epsilon; ``sigma_tol`` optionally
    overrides the Alg-3 counting threshold on the Ritz values of BᵀB.
    ``spec.host_loop=None`` defaults to the early-exit host loop (the
    paper's wall-time behaviour — iteration count == rank estimate) —
    except on *sharded* operands, where the default flips to the in-graph
    loop: a host loop gathers device scalars every iteration, stalling
    the whole mesh on one host round-trip per step.  An explicit
    ``host_loop=True`` remains honored either way.
    """
    spec = (spec or SVDSpec())
    if overrides:
        spec = spec.replace(**overrides)
    if spec.precision is not None:
        # breakdown-based rank detection resolves directions down to the
        # basis storage's CGS2 noise floor — narrowing the storage silently
        # changes what "numerical rank" means, so refuse rather than ignore.
        raise ValueError(
            "estimate_rank requires full-precision bases; got "
            f"spec.precision={spec.precision!r} (rank detection counts "
            "directions the stored basis can certify — use precision=None)")
    op = as_operator(A, backend=spec.backend)
    key = resolve_key(key, caller="estimate_rank")
    if spec.host_loop is None:
        host_loop = sharding_mesh(op) is None
    else:
        host_loop = spec.host_loop
    res = _numerical_rank(op, max_iters=spec.max_iters, eps=spec.tol,
                          relative_eps=spec.relative_tol,
                          sigma_tol=sigma_tol, key=key,
                          host_loop=host_loop,
                          reorth_passes=spec.reorth_passes,
                          dtype=spec.dtype)
    return RankEstimate(res.rank, res.gk_iterations, res.eigenvalues,
                        method="gk")
