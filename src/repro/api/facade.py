"""`factorize` / `estimate_rank` — the one seam every workload goes through.

Dense arrays, implicit low-rank operators, pod-sharded operators and legacy
``LinOp`` closures all enter here; the spec picks the solver; a unified
``Factorization`` / ``RankEstimate`` comes back.  Since PR 5 these are thin
wrappers over the plan layer (``repro.api.plan``): each call builds a
:class:`~repro.api.plan.SolverPlan` — method resolution is operator-aware —
and executes through the process-wide compile cache, so repeated one-shot
calls with the same (spec, operand kind, shape, dtype, mesh) share one
staged executable.  For stateful solve-many workloads use
``repro.api.session`` directly.

Because operators and results are pytrees, the facade composes with jax
transforms:

    batched = jax.vmap(lambda op: factorize(op, spec, key=key))(stacked_op)

runs a batched partial SVD over a stacked ``DenseOp`` with no extra code.
"""
from __future__ import annotations

from typing import Optional

import jax

# NOTE: the package re-exports the *function* ``plan`` under the same name
# as the module, so bind the names straight off the submodule.
from repro.api.plan import HOST_SIDE_METHODS
from repro.api.plan import plan as _make_plan
from repro.api.plan import resolve_method  # re-export (public since PR 1)
from repro.api.results import Factorization, RankEstimate
from repro.api.spec import SVDSpec

Array = jax.Array

__all__ = ["factorize", "factorize_jit", "estimate_rank", "resolve_method"]


def _spec_of(spec: Optional[SVDSpec], overrides: dict) -> SVDSpec:
    spec = (spec or SVDSpec())
    if overrides:
        spec = spec.replace(**overrides)
    return spec


def factorize(A, spec: Optional[SVDSpec] = None, *,
              key: Optional[Array] = None, q1: Optional[Array] = None,
              callback=None, **overrides) -> Factorization:
    """Rank-``spec.rank`` partial SVD of ``A`` under ``spec``.

    ``A`` — dense array, any ``repro.core.operators`` operator, a sharded
    operator, or a legacy ``LinOp``.
    ``key`` — PRNG key for the start vector / sketch (warns and falls back
    to ``PRNGKey(0)`` when omitted).
    ``q1`` — optional GK warm-start vector (e.g. ``prev.warm_start()``).
    ``callback`` — optional ``repro.api.callbacks.ConvergenceCallback``.
    Keyword overrides are merged into the spec:
    ``factorize(A, rank=20)`` == ``factorize(A, SVDSpec(rank=20))``.

    Equivalent to ``plan(spec, like=A).solve(key=key, q1=q1)`` — solver
    resolution is operator-aware and compiled programs are shared through
    the plan cache.
    """
    spec = _spec_of(spec, overrides)
    # one-shot semantics: the caller keeps ownership of q1 (donation is
    # opt-in via factorize_jit / plan(donate_q1=True), where the handle
    # makes the consume-on-entry contract explicit).
    return _make_plan(spec, like=A, donate_q1=False).solve(
        key=key, q1=q1, callback=callback)


def factorize_jit(spec: SVDSpec, *, donate_q1: bool = True):
    """A compiled-once ``fn(A, key, q1) -> Factorization`` specialized to
    ``spec``, with the warm-start buffer donated on accelerator backends.

    The GK start vector ``q1`` is consumed on entry (normalized into the
    first basis column), so its HBM allocation is dead for the rest of the
    solve — donation lets XLA reuse it for an output instead of holding
    both live.  Donation is only requested on TPU/GPU.  Pass ``q1=None``
    to use the keyed start vector.

    Host-loop specs (``host_loop=True`` or a host-side method such as
    ``fsvd_blocked``) cannot be staged into one XLA program and are
    rejected.  The returned function executes through the shared plan
    cache — two ``factorize_jit`` handles for the same spec reuse one
    executable per operand signature.
    """
    method = resolve_method(spec)
    if spec.host_loop or method in HOST_SIDE_METHODS:
        raise ValueError(
            f"factorize_jit requires an in-graph solver; method={method!r} "
            f"host_loop={spec.host_loop!r} runs a host-side loop")
    p = _make_plan(spec, donate_q1=donate_q1)

    def run(A, key, q1):
        return p.solve(A, key=key, q1=q1)

    return run


def estimate_rank(A, spec: Optional[SVDSpec] = None, *,
                  key: Optional[Array] = None,
                  sigma_tol: Optional[float] = None,
                  **overrides) -> RankEstimate:
    """Numerical rank of ``A`` (paper Alg 3) under ``spec``.

    ``spec.max_iters`` caps the GK sweep (default: ``min(m, n)``);
    ``spec.tol`` is the Alg-1 breakdown epsilon; ``sigma_tol`` optionally
    overrides the Alg-3 counting threshold on the Ritz values of BᵀB.
    ``spec.host_loop=None`` defaults to the early-exit host loop (the
    paper's wall-time behaviour — iteration count == rank estimate) —
    except on *sharded* operands, where the default flips to the in-graph
    loop: a host loop gathers device scalars every iteration, stalling
    the whole mesh on one host round-trip per step.  An explicit
    ``host_loop=True`` remains honored either way.

    Equivalent to ``plan(spec, like=A).estimate(key=key, ...)``; in-graph
    estimates share the plan compile cache.
    """
    spec = _spec_of(spec, overrides)
    return _make_plan(spec, like=A).estimate(key=key, sigma_tol=sigma_tol)
