"""SolverPlan — compile once, solve many.

``factorize`` is the right call for *one* SVD; the paper's real workloads
(the §V Riemannian similarity loop, rank tracking of a drifting gradient
operator, heavy-traffic serving) issue *thousands* of structurally
identical solves.  Re-resolving the solver, re-wrapping the operand and
re-staging XLA per call is pure overhead, so the plan layer splits the two
phases:

    p = plan(SVDSpec(method="fsvd", rank=8), like=A)   # resolve ONCE
    f1 = p.solve(A,  key=k1)                            # compile ONCE
    f2 = p.solve(A2, key=k2)                            # reuse executable

``plan()`` resolves ``method="auto"`` *operator-aware* (sharded operands →
``fsvd_sharded``, matrix-free sparse/Kronecker/Gram operands → the
streaming blocked solver), pins the solver, and — for in-graph specs —
stages a jitted ``run(op, key, q1) -> (Factorization, ConvergenceInfo)``
with the warm-start buffer donated on accelerator backends.  Compiled
executables are memoized in a process-wide LRU keyed by

    (task, spec, method, operator treedef, leaf shapes/dtypes, arg structure)

where the operator *treedef* carries the static aux data of every pytree
operator — including the ``Mesh`` of a ``ShardedOp`` — so two plans on
different meshes (or mesh factorizations) never share an executable, while
every plan on the same (spec, kind, shape, dtype, mesh) shares one.

Host-loop specs and non-pytree operands (legacy ``LinOp`` closures) fall
back to the eager path transparently: a plan always solves, it just cannot
always stage.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.callbacks import CaptureCallback, empty_info
from repro.api.registry import get_solver
from repro.api.results import Factorization, RankEstimate
from repro.api.spec import SVDSpec
from repro.core._keys import resolve_key
from repro.core.operators import (GramOp, KroneckerOp, LowRankOp, Operator,
                                  ScaledOp, SparseOp, SumOp, TransposedOp,
                                  as_operator, sharding_mesh)
from repro.runtime import faults as _faults

Array = jax.Array

# methods that run a host-side Python loop (real early exit / restarts)
# and therefore cannot be staged into a single XLA program.
HOST_SIDE_METHODS = frozenset({"fsvd_blocked"})

# built-in in-graph methods the plan may stage + memoize.  Extensions that
# register a jit-safe solver accepting the ``callback`` kwarg opt in here.
_INGRAPH_METHODS = {"fsvd", "rsvd", "fsvd_sharded", "rbk", "gnystrom"}

# sketch-based methods always consume a PRNG key (no warm-start seam).
_NEEDS_KEY = frozenset({"rsvd", "rbk", "gnystrom"})

# "auto" heuristic for *dense* operands: the GK solver tracks the paper's
# accuracy; the sketch is cheaper per pass but its tail triplets degrade
# (paper Fig 1).  A loose tolerance or an explicit power-iteration request
# signals the caller is on the sketch side of the trade-off curve.
_AUTO_SKETCH_TOL = 1e-4


def register_ingraph_method(name: str) -> None:
    """Declare a registered solver stageable by plans (jit-safe, accepts
    ``callback=``)."""
    _INGRAPH_METHODS.add(name)


def method_needs_key(method: str) -> bool:
    """Does ``method`` consume a PRNG key even when warm-started?"""
    return method in _NEEDS_KEY


# ---------------------------------------------------------------------------
# operator-aware method resolution
# ---------------------------------------------------------------------------

def _is_matrix_free(op) -> bool:
    """True when materializing ``op`` densely would defeat its structure —
    these operands want the streaming blocked solver, never the dense
    heuristics (sketch included: an R-SVD range pass is fine, but "auto"
    should not pick it just because ``tol`` is loose)."""
    if isinstance(op, (SparseOp, KroneckerOp, GramOp)):
        return True
    if isinstance(op, TransposedOp):
        return _is_matrix_free(op.inner)
    if isinstance(op, ScaledOp):
        return _is_matrix_free(op.op)
    if isinstance(op, SumOp):
        return any(_is_matrix_free(t) for t in op.terms)
    return False


def resolve_method(spec: SVDSpec, like: Any = None) -> str:
    """Resolve ``method="auto"`` to a registered solver name.

    Operator-aware: an operand flagged ``single_pass_only`` resolves to
    the one solver honouring that contract (``gnystrom``), a *sharded*
    operand resolves to ``fsvd_sharded`` (the shim that enforces the
    in-graph loop), and sparse / Kronecker / Gram operands resolve to the
    streaming ``fsvd_blocked`` — only plain dense (or low-rank /
    legacy-closure) operands consult the tol/power-iters heuristic.
    ``like`` is optional for backward compatibility; without it the dense
    heuristic applies.

    Non-``Operator`` operands are normalized through ``as_operator``
    (which still duck-passes legacy ``LinOp`` closures carrying *both*
    ``mv`` and ``rmv``) — an incidental ``mv`` attribute alone must not
    bypass backend/spec normalization and sharded/matrix-free detection.
    """
    if spec.method != "auto":
        return spec.method
    if like is not None:
        op = like if isinstance(like, Operator) \
            else as_operator(like, backend=spec.backend)
        if getattr(op, "single_pass_only", False):
            return "gnystrom"
        if sharding_mesh(op) is not None:
            return "fsvd_sharded"
        if _is_matrix_free(op):
            return "fsvd_blocked"
    if spec.power_iters > 0 or spec.tol >= _AUTO_SKETCH_TOL:
        return "rsvd"
    return "fsvd"


# ---------------------------------------------------------------------------
# the process-wide compile cache
# ---------------------------------------------------------------------------

_LOCK = threading.RLock()
_CACHE: "collections.OrderedDict[tuple, Any]" = collections.OrderedDict()
_CACHE_SIZE = 128
_STATS = {"traces": 0, "hits": 0, "misses": 0, "evictions": 0}
# single-flight: cache key -> Event, present while one thread builds that
# entry; concurrent requesters wait instead of duplicating the build.
_BUILDING: dict = {}


def clear_plan_cache(reset_stats: bool = False) -> None:
    """Drop every memoized executable (tests / memory pressure).

    ``reset_stats=True`` also zeroes the hit/miss/eviction/trace counters —
    the serve layer snapshots deltas, but tests (and a server restart)
    want a clean origin."""
    with _LOCK:
        _CACHE.clear()
        if reset_stats:
            for k in _STATS:
                _STATS[k] = 0


def plan_cache_stats() -> dict:
    """Snapshot of {traces, hits, misses, evictions, entries, hit_rate}.

    ``hits``/``misses`` count :func:`_memoized` lookups (one per staged
    ``solve``/``estimate``/``solve_batched`` call), ``evictions`` counts
    LRU drops, ``traces`` counts real solver tracings — the serve layer's
    bucket-hit-rate metric is ground-truthed against these counters."""
    with _LOCK:
        total = _STATS["hits"] + _STATS["misses"]
        return {**_STATS, "entries": len(_CACHE),
                "hit_rate": _STATS["hits"] / total if total else 0.0}


def trace_count() -> int:
    """Total solver traces staged through plans this process (a retrace
    means a cache key failed to cover something — the compile-once tests
    assert on deltas of this counter)."""
    with _LOCK:
        return _STATS["traces"]


def _bump_traces() -> None:
    with _LOCK:
        _STATS["traces"] += 1


def _operand_signature(op) -> Optional[tuple]:
    """(treedef, ((shape, dtype), ...)) of a pytree operand, or None when
    the operand cannot be staged (non-hashable aux, non-array leaves that
    are not plain scalars)."""
    leaves, treedef = jax.tree_util.tree_flatten(op)
    try:
        hash(treedef)
    except TypeError:
        return None
    sig = []
    for leaf in leaves:
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if (shape is not None and dtype is not None
                and isinstance(leaf, (jax.Array, np.ndarray))):
            sig.append((tuple(shape), str(dtype)))
        elif isinstance(leaf, (bool, int, float, complex)):
            sig.append(((), str(np.result_type(type(leaf)))))
        else:
            return None
    return (treedef, tuple(sig))


def _accepts_callback(fn) -> bool:
    import inspect
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):          # builtins / C callables
        return False
    return "callback" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def _once_then_parallel(fn):
    """Serialize calls to ``fn`` until the first one completes.

    ``jax.jit`` compiles lazily on the first *call*, and concurrent first
    calls with the same signature can race into duplicate traces.  The
    compile-once contract (exactly one trace per cache key) therefore
    needs the first call fenced; once it returns, the executable exists
    and subsequent calls run lock-free.
    """
    lock = threading.Lock()
    primed = threading.Event()

    def wrapper(*args, **kwargs):
        if primed.is_set():
            return fn(*args, **kwargs)
        with lock:
            out = fn(*args, **kwargs)
            primed.set()
        return out

    return wrapper


def _memoized(cache_key: tuple, build):
    """Single-flight LRU lookup; ``build()`` constructs the jitted callable
    on a miss.

    Concurrent misses on the same key coalesce: the first thread builds
    (off-lock — building may itself take locks, e.g. jax internals) while
    the rest wait on a per-key event, so N threads hammering the same
    (spec, aval) key stage exactly one executable and trace exactly once.
    Waiters count as hits — they end up sharing the built executable.
    """
    while True:
        with _LOCK:
            hit = _CACHE.get(cache_key)
            if hit is not None:
                _CACHE.move_to_end(cache_key)
                _STATS["hits"] += 1
                return hit
            event = _BUILDING.get(cache_key)
            if event is None:
                event = threading.Event()
                _BUILDING[cache_key] = event
                _STATS["misses"] += 1
                builder = True
            else:
                builder = False
        if not builder:
            event.wait()
            continue        # built (or failed — then we take over the build)
        try:
            fn = _once_then_parallel(build())
        except BaseException:
            with _LOCK:
                _BUILDING.pop(cache_key, None)
            event.set()     # wake waiters; one of them retries the build
            raise
        with _LOCK:
            _CACHE[cache_key] = fn
            _CACHE.move_to_end(cache_key)
            while len(_CACHE) > _CACHE_SIZE:
                _CACHE.popitem(last=False)
                _STATS["evictions"] += 1
            _BUILDING.pop(cache_key, None)
        event.set()
        return fn


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """A resolved (spec, method) pair with a staged-executable cache.

    Build with :func:`plan`.  ``solve`` runs the factorization; in-graph
    specs execute a memoized jitted program (the warm-start buffer ``q1``
    is donated on TPU/GPU), host-loop specs and legacy non-pytree operands
    run eagerly.  The plan itself is stateless — it may be shared freely
    across threads / sessions; all memoization lives in the process-wide
    cache.
    """

    spec: SVDSpec
    method: str
    like: Any = None                 # wrapped template operand (optional)
    donate_q1: bool = True

    # --- introspection ------------------------------------------------
    @property
    def staged(self) -> bool:
        """Can this plan compile (method + loop style allow staging)?"""
        return (self.method in _INGRAPH_METHODS
                and not self.spec.host_loop
                and self.method not in HOST_SIDE_METHODS)

    def operand_key(self, A: Any = None) -> Optional[tuple]:
        """The (treedef, avals) component of the compile-cache key for
        ``A`` — includes every static operator field, e.g. a ShardedOp's
        ``Mesh``; None when the operand cannot be staged."""
        op = self._wrap(A)
        if not isinstance(op, Operator):
            return None
        return _operand_signature(op)

    def _wrap(self, A: Any):
        if A is None:
            if self.like is None:
                raise ValueError(
                    "plan was built without a template operand; pass A to "
                    "solve()/estimate()")
            return self.like
        return as_operator(A, backend=self.spec.backend)

    # --- execution ----------------------------------------------------
    def solve(self, A: Any = None, *, key: Optional[Array] = None,
              q1: Optional[Array] = None, with_info: bool = False,
              callback=None):
        """Run the planned factorization on ``A`` (default: the template
        operand).  Returns a ``Factorization``, or ``(Factorization,
        ConvergenceInfo)`` when ``with_info=True``.  ``callback`` receives
        ``on_info`` either way (and ``on_step`` from host-loop solvers).
        """
        _faults.fire(_faults.PLAN_SOLVE)
        op = self._wrap(A)
        okey = self.operand_key(op) if self.staged else None
        if okey is None:
            return self._solve_eager(op, key, q1, with_info, callback)

        # key resolution happens HERE, per call, so the implicit-key
        # warning keeps firing once per solve (not once per compile) and
        # the staged program only ever sees concrete keys.
        if q1 is None or self.method in _NEEDS_KEY:
            key = resolve_key(key, caller=f"plan(method={self.method!r})")
        donate = (self.donate_q1 and q1 is not None
                  and jax.default_backend() in ("tpu", "gpu"))
        cache_key = ("solve", self.spec, self.method, okey,
                     key is None, q1 is None, donate)
        fn = _memoized(cache_key, lambda: self._build_solve(donate))
        fact, info = fn(op, key, q1)
        if callback is not None:
            callback.on_info(info)
        return (fact, info) if with_info else fact

    def _build_solve(self, donate: bool):
        solver = get_solver(self.method)
        spec = self.spec
        method = self.method
        takes_cb = _accepts_callback(solver)

        # `run` must close over scalars only — never `self`: the jitted
        # callable lives in the process-wide cache, and a closure over the
        # plan would pin its `like` template operand (a full input array)
        # for the cache entry's lifetime.
        def run(op, key, q1):
            _bump_traces()      # trace-time only: counts real compilations
            cb = CaptureCallback()
            if takes_cb:
                fact = solver(op, spec, key=key, q1=q1, callback=cb)
            else:
                fact = solver(op, spec, key=key, q1=q1)
            info = cb.info if cb.info is not None else empty_info(method)
            return fact, info

        return jax.jit(run, donate_argnums=(2,) if donate else ())

    def _solve_eager(self, op, key, q1, with_info, callback):
        solver = get_solver(self.method)
        rec = CaptureCallback()
        cb: Any = rec
        if callback is not None:
            class _Tee:
                def on_step(self, i, **m):
                    callback.on_step(i, **m)

                def on_info(self, info):
                    rec.on_info(info)
                    callback.on_info(info)
            cb = _Tee()
        if _accepts_callback(solver):
            fact = solver(op, self.spec, key=key, q1=q1, callback=cb)
        else:
            # extension solvers predating the callback protocol
            fact = solver(op, self.spec, key=key, q1=q1)
        info = rec.info if rec.info is not None else empty_info(self.method)
        return (fact, info) if with_info else fact

    def update(self, fact: Factorization, delta: Any, *, beta=1.0):
        """Rank-k update of an existing ``Factorization`` — zero GK
        iterations (see :mod:`repro.core.update`).

        Staged through the same process-wide cache as solves, keyed by the
        (spec, factorization signature, delta signature) triple, so a
        tracking stream pays ONE trace for every update of a given shape.
        ``beta`` enters the staged program as a traced scalar: one
        executable covers all decay factors.
        """
        from repro.core.update import update_factorization
        dop = as_operator(delta, backend=self.spec.backend)
        if not isinstance(dop, LowRankOp):
            raise TypeError(
                f"plan.update requires a low-rank delta (LowRankOp), got "
                f"{type(dop).__name__}; use solve() for unstructured drift")
        backend = self.spec.backend
        fsig = _operand_signature(fact)
        dsig = _operand_signature(dop)
        if fsig is None or dsig is None:
            return update_factorization(fact, dop, beta=beta,
                                        backend=backend)
        cache_key = ("update", self.spec, fsig, dsig)

        def build():
            def run(fact, dop, beta):
                _bump_traces()
                return update_factorization(fact, dop, beta=beta,
                                            backend=backend)
            return jax.jit(run)

        fn = _memoized(cache_key, build)
        return fn(fact, dop, jnp.asarray(beta, jnp.float32))

    # --- sketch-resident seam (repro.sketchres) -----------------------
    def sketch(self, A: Any = None, *, key: Optional[Array] = None,
               budget: Optional[float] = None):
        """ONE staged sweep over the operand → a resident ``SketchState``
        sized by this plan's spec (``sketchres.sketch_operand``).  Keyed
        by the operand signature, so every (re-)sketch of a given operand
        shape shares one executable."""
        from repro.sketchres import BUDGET, sketch_operand
        op = self._wrap(A)
        key = resolve_key(key, caller="plan.sketch")
        budget = BUDGET if budget is None else budget
        okey = _operand_signature(op)
        spec = self.spec
        if okey is None:
            return sketch_operand(op, spec, key=key, budget=budget)
        cache_key = ("sketch", spec, okey, budget)

        def build():
            def run(op, key):
                _bump_traces()
                return sketch_operand(op, spec, key=key, budget=budget)
            return jax.jit(run)

        return _memoized(cache_key, build)(op, key)

    def sketch_fold(self, state, rows, cols, vals):
        """Fold a COO entry batch into a ``SketchState`` through the
        count-sketch scatter-add kernel — staged + memoized per (state
        signature, padded entry count).  Batches are padded to power-of-
        two lengths (``sketchres.pad_entries``; zero-value pads are exact
        no-ops) so an arbitrary delta stream pays O(log E) traces total,
        shared across every tenant with the same panel shapes."""
        from repro.sketchres import apply_entries, pad_entries
        rows, cols, vals = pad_entries(rows, cols, vals)
        ssig = _operand_signature(state)
        if ssig is None:
            return apply_entries(state, rows, cols, vals)
        cache_key = ("sketch_fold", ssig, rows.shape[0])

        def build():
            def run(state, rows, cols, vals):
                _bump_traces()
                return apply_entries(state, rows, cols, vals)
            return jax.jit(run)

        return _memoized(cache_key, build)(state, rows, cols, vals)

    def sketch_fold_delta(self, state, delta):
        """Fold a factored (or dense) drift block into a ``SketchState``
        via two panel products — staged per (state, delta) signature."""
        from repro.sketchres import apply_lowrank_delta
        dop = as_operator(delta, backend=self.spec.backend)
        ssig = _operand_signature(state)
        dsig = _operand_signature(dop)
        if ssig is None or dsig is None:
            return apply_lowrank_delta(state, dop)
        cache_key = ("sketch_fold_delta", ssig, dsig)

        def build():
            def run(state, dop):
                _bump_traces()
                return apply_lowrank_delta(state, dop)
            return jax.jit(run)

        return _memoized(cache_key, build)(state, dop)

    def sketch_reconstruct(self, state):
        """Zero-sweep ``Factorization`` from maintained panels
        (``sketchres.reconstruct`` — stabilized-pinv Nyström core),
        staged per (spec, state signature).  The answer is unverified by
        construction; callers gate it (residual probe + staleness)."""
        from repro.sketchres import reconstruct
        spec = self.spec
        ssig = _operand_signature(state)
        if ssig is None:
            return reconstruct(state, spec)
        cache_key = ("sketch_reconstruct", spec, ssig)

        def build():
            def run(state):
                _bump_traces()
                return reconstruct(state, spec)
            return jax.jit(run)

        return _memoized(cache_key, build)(state)

    def solve_batched(self, ops: Any, *, keys: Optional[Array] = None,
                      q1s: Optional[Array] = None, with_info: bool = False):
        """Run the planned factorization over a *stacked* operand — one
        operator pytree whose array leaves carry a leading batch axis
        (e.g. ``DenseOp(A)`` with ``A`` of shape ``(B, m, n)``).

        This is the serve layer's dispatch seam: the solver is staged
        ONCE per (spec, stacked signature) as ``jit(vmap(run))`` and
        memoized in the same process-wide cache as single solves, so a
        continuous-batching queue pays one trace per (bucket, batch-size)
        and the batched matvecs execute as batched GEMMs.  ``keys`` is a
        stacked key array (one per example; required unless every example
        is warm-started), ``q1s`` an optional stacked warm-start buffer.
        Returns a batched ``Factorization`` (leaves gain the batch axis),
        plus a batched ``ConvergenceInfo`` when ``with_info=True``.

        Unlike ``solve`` there is no eager fallback: batching exists to
        amortize staging, so a plan that cannot stage (host-loop method,
        non-pytree operand) is a caller error.
        """
        _faults.fire(_faults.PLAN_SOLVE)
        if not self.staged:
            raise ValueError(
                f"solve_batched requires a stageable plan; method="
                f"{self.method!r} host_loop={self.spec.host_loop!r} runs "
                "a host-side loop")
        op = as_operator(ops, backend=self.spec.backend)
        okey = _operand_signature(op)
        if okey is None:
            raise ValueError(
                "solve_batched requires a pytree operand with array "
                f"leaves; got {type(ops).__name__}")
        if keys is None and (q1s is None or self.method in _NEEDS_KEY):
            raise ValueError(
                "solve_batched needs stacked `keys` (one per example) "
                "unless every example is warm-started via `q1s`")
        cache_key = ("solve_batched", self.spec, self.method, okey,
                     keys is None, q1s is None)
        fn = _memoized(cache_key,
                       lambda: self._build_batched(keys is None,
                                                   q1s is None))
        fact, info = fn(op, keys, q1s)
        return (fact, info) if with_info else fact

    def _build_batched(self, no_keys: bool, no_q1: bool):
        solver = get_solver(self.method)
        spec = self.spec
        method = self.method
        takes_cb = _accepts_callback(solver)

        # same scalars-only closure rule as _build_solve: the staged
        # callable outlives the plan in the process-wide cache.
        def run(op, key, q1):
            _bump_traces()
            cb = CaptureCallback()
            if takes_cb:
                fact = solver(op, spec, key=key, q1=q1, callback=cb)
            else:
                fact = solver(op, spec, key=key, q1=q1)
            info = cb.info if cb.info is not None else empty_info(method)
            return fact, info

        in_axes = (0, None if no_keys else 0, None if no_q1 else 0)
        return jax.jit(jax.vmap(run, in_axes=in_axes))

    def estimate(self, A: Any = None, *, key: Optional[Array] = None,
                 sigma_tol: Optional[float] = None) -> RankEstimate:
        """Numerical rank (paper Alg 3) under this plan's spec.

        ``spec.host_loop=None`` keeps the per-entry-point default: the
        early-exit host loop (iteration count == rank estimate) — except
        on sharded operands, where the in-graph loop avoids stalling the
        mesh on a host round-trip per step.  In-graph estimates are staged
        through the same compile cache as solves.
        """
        from repro.core.rank import numerical_rank as _numerical_rank
        spec = self.spec
        if spec.precision is not None:
            # breakdown-based rank detection resolves directions down to
            # the basis storage's CGS2 noise floor — narrowing the storage
            # silently changes what "numerical rank" means, so refuse.
            raise ValueError(
                "estimate_rank requires full-precision bases; got "
                f"spec.precision={spec.precision!r} (rank detection counts "
                "directions the stored basis can certify — use "
                "precision=None)")
        op = self._wrap(A)
        key = resolve_key(key, caller="estimate_rank")
        if spec.host_loop is None:
            host_loop = sharding_mesh(op) is None
        else:
            host_loop = spec.host_loop

        kwargs = dict(max_iters=spec.max_iters, eps=spec.tol,
                      relative_eps=spec.relative_tol, sigma_tol=sigma_tol,
                      reorth_passes=spec.reorth_passes, dtype=spec.dtype)
        okey = None if host_loop else self.operand_key(op)
        if okey is None:
            res = _numerical_rank(op, key=key, host_loop=host_loop,
                                  **kwargs)
        else:
            cache_key = ("estimate", spec, okey, sigma_tol)

            def build():
                def run(op, key):
                    _bump_traces()
                    return _numerical_rank(op, key=key, host_loop=False,
                                           **kwargs)
                return jax.jit(run)

            res = _memoized(cache_key, build)(op, key)
        return RankEstimate(res.rank, res.gk_iterations, res.eigenvalues,
                            method="gk")


def plan(spec: Optional[SVDSpec] = None, *, like: Any = None,
         donate_q1: bool = True, **overrides) -> SolverPlan:
    """Resolve ``spec`` (method, backend, placement) against an optional
    template operand ``like`` and return a reusable :class:`SolverPlan`.

    Keyword overrides merge into the spec exactly as in ``factorize``:
    ``plan(rank=20, like=A)`` == ``plan(SVDSpec(rank=20), like=A)``.
    """
    spec = (spec or SVDSpec())
    if overrides:
        spec = spec.replace(**overrides)
    wrapped = None
    if like is not None:
        wrapped = as_operator(like, backend=spec.backend)
    return SolverPlan(spec=spec, method=resolve_method(spec, wrapped),
                      like=wrapped, donate_q1=donate_q1)
