"""Solver registry: methods plug into the facade by name.

A solver is ``fn(A: Operator, spec: SVDSpec, *, key, q1) -> Factorization``.
Core solvers (fsvd, rsvd) register at import; extensions (e.g. the
pod-sharded solver in ``repro.distributed.gk_dist``) register themselves on
import of their module — the facade never hard-codes the set.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

_SOLVERS: Dict[str, Callable] = {}


def register_solver(name: str, fn: Optional[Callable] = None):
    """Register ``fn`` under ``name`` (usable as a decorator).

    Re-registration overwrites — last writer wins, so downstream code can
    shadow a solver with an instrumented variant.
    """
    def _register(f):
        _SOLVERS[name] = f
        return f

    if fn is not None:
        return _register(fn)
    return _register


def get_solver(name: str) -> Callable:
    try:
        return _SOLVERS[name]
    except KeyError:
        raise KeyError(
            f"no solver registered under {name!r}; available: "
            f"{sorted(_SOLVERS)}") from None


def available_solvers() -> tuple[str, ...]:
    return tuple(sorted(_SOLVERS))
