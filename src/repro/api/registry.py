"""Solver registry: methods plug into the facade by name.

A solver is ``fn(A: Operator, spec: SVDSpec, *, key, q1) -> Factorization``
(optionally also accepting ``callback=`` — a
``repro.api.callbacks.ConvergenceCallback``; the plan layer detects the
parameter and only passes it to solvers that take it).  Core solvers
(fsvd, rsvd) register at import; extensions (e.g. the pod-sharded solver
in ``repro.distributed.gk_dist``) register themselves on import of their
module — the facade never hard-codes the set.  A registered solver that
is jit-safe can additionally opt into plan staging via
``repro.api.plan.register_ingraph_method``.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

_SOLVERS: Dict[str, Callable] = {}


def register_solver(name: str, fn: Optional[Callable] = None):
    """Register ``fn`` under ``name`` (usable as a decorator).

    Re-registration overwrites — last writer wins, so downstream code can
    shadow a solver with an instrumented variant.
    """
    def _register(f):
        _SOLVERS[name] = f
        return f

    if fn is not None:
        return _register(fn)
    return _register


def get_solver(name: str) -> Callable:
    try:
        return _SOLVERS[name]
    except KeyError:
        raise KeyError(
            f"no solver registered under {name!r}; available: "
            f"{sorted(_SOLVERS)}") from None


def available_solvers() -> tuple[str, ...]:
    return tuple(sorted(_SOLVERS))
