"""Convergence diagnostics: the ``ConvergenceInfo`` pytree and the callback
protocol threaded through the GK / blocked-GK / R-SVD solvers.

Two delivery modes, matched to the two execution styles:

  * **host-loop solvers** (``gk_bidiag_host``, ``fsvd_blocked``) already sync
    a scalar pair per iteration — they call ``callback.on_step(i, **metrics)``
    with the *same* host floats, so observing convergence costs zero extra
    device round-trips.
  * **in-graph solvers** (``gk_bidiag`` under ``jit`` / ``SolverPlan``)
    cannot call back to the host per iteration.  Instead the per-iteration
    residual proxies are *already arrays in the graph* (the GK recurrence
    scalars live in fixed-size buffers), so the solver assembles a
    :class:`ConvergenceInfo` pytree of device arrays and hands it to
    ``callback.on_info(info)`` — under a trace this happens at trace time
    and the info rides out of the compiled program as ordinary outputs
    (``SolverPlan.solve(with_info=True)``); no host round-trips occur until
    the caller reads a value.

For GK the per-iteration residual proxy is ``beta_{i+1}``: the coupling
scalar of the three-term recurrence, whose collapse under the breakdown
threshold *is* the convergence/rank-revelation event of paper Alg 1.  The
blocked solver reports the per-restart-cycle minimum Ritz residual
``min_i ||A^T u_i - sigma_i v_i||`` instead (its native locking criterion).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class ConvergenceInfo:
    """Per-solve convergence record (a pytree; jit/vmap/checkpoint-safe).

    residuals  — (k,) per-iteration residual proxies in solve order,
                 zero-padded beyond ``iterations``: GK recurrence betas for
                 "fsvd"/"fsvd_sharded", per-cycle min Ritz residuals for
                 "fsvd_blocked", empty (shape (0,)) for sketch solvers.
    iterations — () int32: iterations / restart cycles actually used.
    breakdown  — () bool: did the solver's breakdown / non-convergence flag
                 fire.
    method     — producing solver (static aux; survives pytree ops).
    """

    residuals: Array
    iterations: Array
    breakdown: Array
    method: str = "fsvd"

    @property
    def last_residual(self) -> Array:
        """The final (possibly masked) residual proxy, 0.0 when empty."""
        if self.residuals.shape[0] == 0:
            return jnp.asarray(0.0)
        idx = jnp.clip(self.iterations - 1, 0, self.residuals.shape[0] - 1)
        return self.residuals[idx]


def _info_flatten(c: ConvergenceInfo):
    return ((c.residuals, c.iterations, c.breakdown), (c.method,))


def _info_unflatten(aux, children):
    return ConvergenceInfo(*children, method=aux[0])


jax.tree_util.register_pytree_node(ConvergenceInfo, _info_flatten,
                                   _info_unflatten)


class ConvergenceCallback:
    """Base/no-op callback: subclass and override what you observe.

    ``on_step(i, **metrics)`` fires once per iteration from *host-loop*
    solvers only, with host scalars the loop already synced (typical keys:
    ``alpha``, ``beta`` for GK; ``residual``, ``locked`` for the blocked
    solver).  ``on_info(info)`` fires once per solve from every built-in
    solver; under a trace ``info`` holds tracers — store, don't ``float()``.
    """

    def on_step(self, i: int, **metrics) -> None:   # pragma: no cover
        pass

    def on_info(self, info: ConvergenceInfo) -> None:  # pragma: no cover
        pass


class RecordingCallback(ConvergenceCallback):
    """Collects everything: ``steps`` is a list of (i, metrics) tuples,
    ``info`` the final :class:`ConvergenceInfo` (None until the solve
    ends)."""

    def __init__(self) -> None:
        self.steps: list[tuple[int, dict]] = []
        self.info: Optional[ConvergenceInfo] = None

    def on_step(self, i: int, **metrics) -> None:
        self.steps.append((i, metrics))

    def on_info(self, info: ConvergenceInfo) -> None:
        self.info = info


class CaptureCallback(ConvergenceCallback):
    """Trace-time capture used by ``SolverPlan``: holds the (possibly
    traced) info pytree so the compiled program can return it as an
    output."""

    def __init__(self) -> None:
        self.info: Optional[ConvergenceInfo] = None

    def on_info(self, info: ConvergenceInfo) -> None:
        self.info = info


def empty_info(method: str) -> ConvergenceInfo:
    """A structurally-valid info for solvers with no per-iteration signal."""
    return ConvergenceInfo(jnp.zeros((0,), jnp.float32),
                           jnp.asarray(0, jnp.int32),
                           jnp.asarray(False), method=method)
