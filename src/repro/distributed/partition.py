"""Logical-axis -> mesh-axis partitioning.

Model code annotates every parameter dimension with a *logical* name
(``repro.models.layers.ParamBag``); this module maps those names onto the
physical mesh:

    vocab / heads / mlp / experts / ssm_inner  -> "model"   (TP / EP)
    embed                                      -> "data"    (FSDP)
    everything small / sequential              -> replicated

Two guards make the same rules work on any mesh shape (elasticity):
  * divisibility — a dim whose size does not divide the mesh axis falls back
    to replicated (e.g. starcoder2's kv_heads=4 on a 16-way model axis);
  * conflict — if an earlier dim already claimed a mesh axis, later dims
    fall back (expert weights claim "model" for the expert dim; their mlp
    dim then stays unsharded, matching the EP shard_map layout).

The "pod" axis is deliberately *never* assigned to parameters: parameters
are replicated across pods (pure DP over DCN) and sharded only within a pod
(FSDP/TP over ICI) — the standard multi-slice layout.  Batch axes shard over
("pod", "data").
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical axis -> preferred mesh axis (None = replicate)
RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "embed": "data",
    # replicated (small or sequential):
    "head_dim": None, "kv_lora": None, "q_lora": None, "experts_dim": None,
    "ssm_state": None, "ssm_heads": None, "conv_k": None, "img_in": None,
    "layers": None,
}


def logical_to_spec(axes: tuple[str, ...], shape: tuple[int, ...],
                    mesh: Mesh) -> P:
    """PartitionSpec for one parameter from its logical axes + shape."""
    taken: set[str] = set()
    spec = []
    sizes = dict(mesh.shape)
    for name, dim in zip(axes, shape):
        mesh_axis = RULES.get(name)
        if (mesh_axis is None or mesh_axis not in sizes
                or mesh_axis in taken or dim % sizes[mesh_axis] != 0):
            spec.append(None)
        else:
            spec.append(mesh_axis)
            taken.add(mesh_axis)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def param_shardings(logical: PyTree, params_shape: PyTree, mesh: Mesh
                    ) -> PyTree:
    """NamedSharding pytree matching the params pytree.

    ``logical`` mirrors params with tuples of axis names; ``params_shape``
    is the params pytree (arrays or ShapeDtypeStructs).
    """
    def f(axes, leaf):
        return NamedSharding(mesh, logical_to_spec(tuple(axes), leaf.shape,
                                                   mesh))
    return jax.tree.map(f, logical, params_shape,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def spec_for_batch(mesh: Mesh, batch: int, ndim: int,
                   seq_axis_shard: bool = False) -> P:
    """Spec for a (B, S, ...) batch tensor.

    Shards B over ("pod","data") when divisible; for B=1 long-context cells,
    ``seq_axis_shard=True`` shards the sequence axis over "data" instead.
    """
    baxes = batch_axes(mesh)
    sizes = dict(mesh.shape)
    total = 1
    for a in baxes:
        total *= sizes[a]
    if batch % total == 0 and batch >= total:
        return P(baxes, *([None] * (ndim - 1)))
    if seq_axis_shard and ndim >= 2:
        return P(None, "data", *([None] * (ndim - 2)))
    return P(*([None] * ndim))
