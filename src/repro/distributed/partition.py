"""Logical-axis -> mesh-axis partitioning.

Model code annotates every parameter dimension with a *logical* name
(``repro.models.layers.ParamBag``); this module maps those names onto the
physical mesh:

    vocab / heads / mlp / experts / ssm_inner  -> "model"   (TP / EP)
    embed                                      -> "data"    (FSDP)
    everything small / sequential              -> replicated

Two guards make the same rules work on any mesh shape (elasticity):
  * divisibility — a dim whose size does not divide the mesh axis falls back
    to replicated (e.g. starcoder2's kv_heads=4 on a 16-way model axis);
  * conflict — if an earlier dim already claimed a mesh axis, later dims
    fall back (expert weights claim "model" for the expert dim; their mlp
    dim then stays unsharded, matching the EP shard_map layout).

The "pod" axis is deliberately *never* assigned to parameters: parameters
are replicated across pods (pure DP over DCN) and sharded only within a pod
(FSDP/TP over ICI) — the standard multi-slice layout.  Batch axes shard over
("pod", "data").

Operator placement (the solver side of the same mapping) also lives here:
a dense (m, n) operand shards rows over ("pod", "data") and columns over
"model", the layout every ``repro.distributed.ShardedOp`` matvec assumes.
:func:`place_operator` lays a matrix out, :func:`shard_shape` /
:func:`padded_operand_shape` answer the tiling questions the property tests
(and the padding fallback for non-divisible operands) need.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.padding import padded_shape as _padded_shape

PyTree = Any

# logical axis -> preferred mesh axis (None = replicate)
RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "embed": "data",
    # replicated (small or sequential):
    "head_dim": None, "kv_lora": None, "q_lora": None, "experts_dim": None,
    "ssm_state": None, "ssm_heads": None, "conv_k": None, "img_in": None,
    "layers": None,
}


def logical_to_spec(axes: tuple[str, ...], shape: tuple[int, ...],
                    mesh: Mesh) -> P:
    """PartitionSpec for one parameter from its logical axes + shape."""
    taken: set[str] = set()
    spec = []
    sizes = dict(mesh.shape)
    for name, dim in zip(axes, shape):
        mesh_axis = RULES.get(name)
        if (mesh_axis is None or mesh_axis not in sizes
                or mesh_axis in taken or dim % sizes[mesh_axis] != 0):
            spec.append(None)
        else:
            spec.append(mesh_axis)
            taken.add(mesh_axis)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def param_shardings(logical: PyTree, params_shape: PyTree, mesh: Mesh
                    ) -> PyTree:
    """NamedSharding pytree matching the params pytree.

    ``logical`` mirrors params with tuples of axis names; ``params_shape``
    is the params pytree (arrays or ShapeDtypeStructs).
    """
    def f(axes, leaf):
        return NamedSharding(mesh, logical_to_spec(tuple(axes), leaf.shape,
                                                   mesh))
    return jax.tree.map(f, logical, params_shape,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# --------------------------------------------------------------------------
# operator placement: the (rows over ("pod","data"), cols over "model")
# layout shared by every ShardedOp matvec
# --------------------------------------------------------------------------

def operator_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], Optional[str]]:
    """``(row_axes, col_axis)`` of the operand layout on ``mesh``.

    Rows shard over the ("pod", "data") axes present; columns over "model"
    when present.  Either side may be absent (then that dim is replicated).
    """
    rows = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    col = "model" if "model" in mesh.axis_names else None
    return rows, col


def operator_counts(mesh: Mesh) -> Tuple[int, int]:
    """(row shard count R, column shard count C) of the operand layout."""
    rows, col = operator_axes(mesh)
    sizes = dict(mesh.shape)
    r = math.prod(sizes[a] for a in rows) if rows else 1
    c = sizes[col] if col else 1
    return r, c


def operator_spec(mesh: Mesh) -> P:
    """PartitionSpec of a dense (m, n) operand on ``mesh``."""
    rows, col = operator_axes(mesh)
    return P(rows or None, col)


def shard_shape(shape: Tuple[int, int], mesh: Mesh) -> Tuple[int, int]:
    """Per-device block shape of an operand laid out by
    :func:`place_operator` (requires a divisible ``shape``)."""
    m, n = shape
    r, c = operator_counts(mesh)
    if m % r or n % c:
        raise ValueError(
            f"operand shape {shape} does not tile a ({r} x {c})-way mesh "
            f"layout; pad first (see padded_operand_shape)")
    return (m // r, n // c)


def padded_operand_shape(shape: Tuple[int, int], mesh: Mesh
                         ) -> Tuple[int, int]:
    """Smallest shape >= ``shape`` whose rows/cols tile the mesh layout.

    Zero-padding to this shape is exact for every matvec/CGS reduction the
    solvers issue (zero rows and columns contribute nothing to any dot).
    The arithmetic is the shared :mod:`repro.core.padding` helper — the
    serve layer's shape buckets use the same one."""
    r, c = operator_counts(mesh)
    return _padded_shape(shape, (r, c))


def place_operator(A: jax.Array, mesh: Mesh) -> jax.Array:
    """device_put A under the pod-sharded operand layout."""
    return jax.device_put(A, NamedSharding(mesh, operator_spec(mesh)))


def spec_for_batch(mesh: Mesh, batch: int, ndim: int,
                   seq_axis_shard: bool = False) -> P:
    """Spec for a (B, S, ...) batch tensor.

    Shards B over ("pod","data") when divisible; for B=1 long-context cells,
    ``seq_axis_shard=True`` shards the sequence axis over "data" instead.
    """
    baxes = batch_axes(mesh)
    sizes = dict(mesh.shape)
    total = 1
    for a in baxes:
        total *= sizes[a]
    if batch % total == 0 and batch >= total:
        return P(baxes, *([None] * (ndim - 1)))
    if seq_axis_shard and ndim >= 2:
        return P(None, "data", *([None] * (ndim - 2)))
    return P(*([None] * ndim))
