"""Pod-sharded GK matvecs: the paper's "huge matrix" regime on a real mesh.

The operator A (m, n) is sharded ``P(("pod","data"), "model")`` — rows over
the pod+data axes, columns over model (``repro.distributed.partition``
owns the layout).  The Lanczos vectors live sharded on the matching axis:

    q (m,)  P(("pod","data"))          p (n,)  P("model")

and the communication model is **one collective per GK half-step** in the
row-sharded layout (a "model" axis adds one matvec-reduce psum):

  * left half-step ``u = A p − α q`` — the local GEMV needs no reduction
    (rows are local); the CGS products are *stacked*: each shard computes
    the partial first coefficient ``c₁ = Qᵀu``, the partial basis Gram
    matrix ``G = QᵀQ`` and the partial ``‖u‖²``, and ONE psum carries all
    three.  Every further CGS pass is then local algebra —
    ``c_{i+1} = c_i − G c_i`` (exact: ``Qᵀ(w − Q c) = Qᵀw − G c``) — and
    the norm comes from the scalar identity
    ``‖u − Q d‖² = ‖u‖² − 2 dᵀc₁ + dᵀG d``.
  * right half-step ``v = Aᵀ q − β p`` — the transpose GEMV is partial
    over the row shards; ONE psum replicates it, after which CGS against
    the replicated P basis is entirely local.

So a 1e5 x 8e4 matrix (the paper's largest, NA for dense SVD) occupies
~60 MB per device on a 512-chip mesh and each half-iteration is one local
GEMV-plus-partial-``Qᵀu`` and a single rendezvous, instead of one
collective per dot (2·passes + 2 of them for CGS²).  With
``backend="pallas"`` the local shard work runs on the fused
``repro.kernels.gk_step`` tiles (matvec + first CGS product in one pass
over the shard, candidate VMEM-resident).

``ShardedOp`` is a pytree operator (``repro.core.operators``): the sharded
payload is the only leaf, the mesh rides as static aux data, so a whole
F-SVD solve over it jits as one program and plugs into ``repro.api``
unchanged.  The payload may be a dense matrix *or* the row-partitioned
ELL packs of a :class:`~repro.core.operators.SparseOp`
(:func:`sharded_operator` builds either; it also pushes sharding through
``GramOp`` / ``TransposedOp`` wrappers).  Operands whose shape does not
tile the mesh are zero-padded (exact for every reduction the solvers
issue) and report their logical shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.operators import (GramOp, Operator, SparseOp, TransposedOp,
                                  cgs, register_operator)
from repro.distributed.partition import (operator_axes, operator_counts,
                                         operator_spec, padded_operand_shape,
                                         place_operator, shard_shape)

__all__ = ["ShardedOp", "SparseShards", "place_operator", "sharded_operator",
           "operator_axes", "operator_spec", "shard_shape"]

Array = jax.Array


class SparseShards(NamedTuple):
    """Row-partitioned ELL packs of a sparse operand (one pack per shard).

    ``mv_vals``/``mv_cols`` are the forward ELL pack over the (padded)
    global rows — column ids are global, the right vector is replicated.
    ``rmv_vals``/``rmv_rows`` stack R per-shard transpose packs along dim 0
    (global shape (R·n, L')): each shard's block indexes **its own** local
    rows, so the transpose matvec is a pure gather over the local q block
    and one psum finishes ``Aᵀq`` — scatter never appears.
    """

    mv_vals: Array     # (m_pad, L)
    mv_cols: Array     # (m_pad, L) int32, global column ids
    rmv_vals: Array    # (R * n, L')
    rmv_rows: Array    # (R * n, L') int32, shard-local row ids


def _f32(x: Array) -> Array:
    return x.astype(jnp.float32)


def _acc_tdot(B: Array, x: Array) -> Array:
    """``Bᵀ x`` contracting rows with f32 accumulation; a narrower-storage
    basis (bf16) is never upcast in memory (same policy as ``cgs``)."""
    if B.dtype != x.dtype and B.dtype != jnp.float32:
        x = x.astype(B.dtype)
    return jax.lax.dot_general(
        B, x, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _acc_apply(B: Array, d: Array) -> Array:
    """``B d`` with f32 accumulation under the same storage policy."""
    if B.dtype != d.dtype and B.dtype != jnp.float32:
        d = d.astype(B.dtype)
    return jnp.dot(B, d, preferred_element_type=jnp.float32)


def _gram_cgs_psum(w: Array, basis: Array, axes, passes: int,
                   c1_part: Optional[Array] = None) -> tuple[Array, Array]:
    """CGS^passes of the sharded column ``w`` against the equally-sharded
    ``basis`` with ONE stacked psum over ``axes``.

    Stacks the partial first coefficient ``c₁ = Qᵀw``, partial Gram matrix
    ``G = QᵀQ`` and partial ``‖w‖²`` into a single reduction; later passes
    use ``c_{i+1} = c_i − G c_i`` (exact, not an approximation) and the
    norm comes from ``‖w − Q d‖² = ‖w‖² − 2 dᵀc₁ + dᵀG d``.  Returns the
    sharded projected column and the replicated norm.
    """
    k = basis.shape[1]
    c1 = _acc_tdot(basis, w) if c1_part is None else c1_part   # (k, 1)
    G = _acc_tdot(basis, basis)                    # (k, k) partial
    ww = jnp.sum(_f32(w) * _f32(w)).reshape(1)     # (1,)  partial
    flat = jnp.concatenate([c1.ravel(), G.ravel(), ww])
    flat = jax.lax.psum(flat, axes)
    c1 = flat[:k][:, None]
    G = flat[k:k + k * k].reshape(k, k)
    ww = flat[k + k * k]
    d = c1
    ci = c1
    for _ in range(passes - 1):
        ci = ci - G @ ci
        d = d + ci
    v = _f32(w) - _acc_apply(basis, d)
    nrm2 = ww - 2.0 * jnp.vdot(d, c1) + jnp.vdot(d, G @ d)
    return v, jnp.sqrt(jnp.maximum(nrm2, 0.0))


def _local_cgs(w: Array, basis: Array, passes: int) -> tuple[Array, Array]:
    """Plain CGS^passes + direct norm on a fully replicated column."""
    v = cgs(_f32(w), basis, passes)
    return v, jnp.linalg.norm(v)


def _ell_mv(vals: Array, cols: Array, x: Array) -> Array:
    """``y = A x`` over a padded-ELL block: gather + lane reduction."""
    gathered = jnp.take(_f32(x)[:, 0], cols, axis=0)       # (rows, L)
    return jnp.sum(_f32(vals) * gathered, axis=1, keepdims=True)


def _ell_mm(vals: Array, cols: Array, X: Array) -> Array:
    """Block version: X (d, b) -> (rows, b)."""
    gathered = jnp.take(_f32(X), cols, axis=0)             # (rows, L, b)
    return jnp.einsum("rl,rlb->rb", _f32(vals), gathered)


def _local_mv(a, p_col: Array) -> Array:
    """Local shard of ``A p`` (partial over column shards, if any)."""
    if isinstance(a, SparseShards):
        return _ell_mv(a.mv_vals, a.mv_cols, p_col)
    return jnp.dot(_f32(a), _f32(p_col))


def _local_rmv(a, q_col: Array) -> Array:
    """Local shard of ``Aᵀ q`` (partial over row shards)."""
    if isinstance(a, SparseShards):
        return _ell_mv(a.rmv_vals, a.rmv_rows, q_col)
    return jax.lax.dot_general(
        _f32(a), _f32(q_col), dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _local_mm(a, X: Array) -> Array:
    if isinstance(a, SparseShards):
        return _ell_mm(a.mv_vals, a.mv_cols, X)
    return jnp.dot(_f32(a), _f32(X))


def _local_rmm(a, X: Array) -> Array:
    if isinstance(a, SparseShards):
        return _ell_mm(a.rmv_vals, a.rmv_rows, X)
    return jax.lax.dot_general(
        _f32(a), _f32(X), dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _a_specs(a_template, rows, col):
    """in_specs pytree for the operator payload."""
    if isinstance(a_template, SparseShards):
        blk = P(rows or None, None)
        return SparseShards(blk, blk, blk, blk)
    return P(rows or None, col)


@functools.lru_cache(maxsize=None)
def _matvec_fns(mesh: Mesh, sparse: bool):
    """shard_map'd fused three-term matvecs + block matmats (cached)."""
    rows, col = operator_axes(mesh)
    a_tmpl = SparseShards(None, None, None, None) if sparse else None
    a_spec = _a_specs(a_tmpl, rows, col)
    q_spec, p_spec = P(rows or None, None), P(col, None)

    def _mv(a, p_col, y_col, alpha):
        u = _local_mv(a, p_col)
        if col is not None:
            u = jax.lax.psum(u, col)
        return u - alpha * _f32(y_col)

    def _rmv(a, q_col, y_col, beta):
        v = _local_rmv(a, q_col)
        if rows:
            v = jax.lax.psum(v, rows)
        return v - beta * _f32(y_col)

    def _mm(a, X):
        Y = _local_mm(a, X)
        if col is not None:
            Y = jax.lax.psum(Y, col)
        return Y

    def _rmm(a, X):
        Z = _local_rmm(a, X)
        if rows:
            Z = jax.lax.psum(Z, rows)
        return Z

    def _sketch(a, om, ps):
        # one sweep over the local shard captures both directions: the
        # row-sharded range panel Y = A Ω needs no reduction (rows are
        # local), the co-range panel Z = Aᵀ Ψ is partial over the row
        # shards — each shard applies its own row block of Ψ and ONE psum
        # finishes it (a "model" axis adds the usual matvec-reduce psum).
        Y = _local_mm(a, om)
        if col is not None:
            Y = jax.lax.psum(Y, col)
        Z = _local_rmm(a, ps)
        if rows:
            Z = jax.lax.psum(Z, rows)
        return Y, Z

    sm = functools.partial(compat.shard_map, mesh=mesh, check_vma=False)
    return {
        "mv": sm(_mv, in_specs=(a_spec, p_spec, q_spec, P()),
                 out_specs=q_spec),
        "rmv": sm(_rmv, in_specs=(a_spec, q_spec, p_spec, P()),
                  out_specs=p_spec),
        "mm": sm(_mm, in_specs=(a_spec, P(col, None)),
                 out_specs=P(rows or None, None)),
        "rmm": sm(_rmm, in_specs=(a_spec, P(rows or None, None)),
                  out_specs=P(col, None)),
        "sketch": sm(_sketch,
                     in_specs=(a_spec, P(col, None), P(rows or None, None)),
                     out_specs=(P(rows or None, None), P(col, None))),
    }


@functools.lru_cache(maxsize=None)
def _step_fns(mesh: Mesh, passes: int, sparse: bool, pallas: bool):
    """shard_map'd fused Lanczos half-steps (cached per config).

    Row-sharded layout: exactly one psum per half-step.  A "model" axis
    adds the matvec-reduce psum (two total) and disables the Pallas local
    tiles (their fused ``Qᵀu`` would see a partial u).
    """
    rows, col = operator_axes(mesh)
    nrow, _ = operator_counts(mesh)
    a_tmpl = SparseShards(None, None, None, None) if sparse else None
    a_spec = _a_specs(a_tmpl, rows, col)
    q_spec, p_spec = P(rows or None, None), P(col, None)
    use_pallas = pallas and not sparse and col is None

    def _left(a, p_col, y_col, alpha, basis):
        # u = A p − α y, CGS^passes against the row-sharded basis, norm.
        if use_pallas and rows:
            from repro.kernels import ops as kops
            u, c1 = kops.local_mv_qtv(a, p_col, y_col, alpha, basis)
            return _gram_cgs_psum(u, basis, rows, passes, c1_part=c1)
        u = _local_mv(a, p_col)
        if col is not None:
            u = jax.lax.psum(u, col)
        u = u - alpha * _f32(y_col)
        if rows:
            return _gram_cgs_psum(u, basis, rows, passes)
        return _local_cgs(u, basis, passes)

    def _right(a, q_col, y_col, beta, basis):
        # v = Aᵀ q − β y, CGS^passes against the (col-sharded) basis, norm.
        if use_pallas and rows:
            from repro.kernels import ops as kops
            v, c1 = kops.local_rmv_qtv(a, q_col, _f32(y_col) / nrow, beta,
                                       basis)
            nloc = v.shape[0]
            flat = jax.lax.psum(
                jnp.concatenate([v.ravel(), c1.ravel()]), rows)
            v = flat[:nloc][:, None]
            c1 = flat[nloc:][:, None]
            v = v - _acc_apply(basis, c1)
            for _ in range(passes - 1):
                v = v - _acc_apply(basis, _acc_tdot(basis, v))
            return v, jnp.linalg.norm(v)
        v = _local_rmv(a, q_col)
        if rows:
            v = jax.lax.psum(v, rows)
        v = v - beta * _f32(y_col)
        if col is not None:
            return _gram_cgs_psum(v, basis, col, passes)
        return _local_cgs(v, basis, passes)

    sm = functools.partial(compat.shard_map, mesh=mesh, check_vma=False)
    left = sm(_left, in_specs=(a_spec, p_spec, q_spec, P(),
                               P(rows or None, None)),
              out_specs=(q_spec, P()))
    right = sm(_right, in_specs=(a_spec, q_spec, p_spec, P(),
                                 P(col, None)),
               out_specs=(p_spec, P()))
    return left, right


def _pad_rows(x: Array, rows: int) -> Array:
    if x.shape[0] == rows:
        return x
    widths = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class ShardedOp(Operator):
    """Pod-sharded operator: matvecs are local shard work + one psum.

    ``A`` is the sharded payload — a dense matrix laid out by
    :func:`place_operator`, or :class:`SparseShards` ELL packs — and is
    the only pytree leaf; the mesh (plus the logical shape, when the
    payload is padded) is static aux data, so the operator crosses
    ``jit`` boundaries whole and the GK / F-SVD cores (and
    ``repro.api.factorize``) run on it unmodified.  Build with
    :func:`sharded_operator`, which handles padding, sparse packing and
    ``GramOp`` / ``TransposedOp`` wrappers.

    ``backend="pallas"`` runs the local shard of each fused Lanczos
    half-step on the ``repro.kernels.gk_step`` tiles (row-sharded dense
    payloads only).
    """

    A: Any
    mesh: Mesh
    lshape: Optional[Tuple[int, int]] = None
    backend: str = "xla"

    _data_fields = ("A",)
    _meta_fields = ("mesh", "lshape", "backend")

    # --- shape bookkeeping -------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        if self.lshape is not None:
            return tuple(self.lshape)
        if isinstance(self.A, SparseShards):
            raise ValueError("sparse ShardedOp requires an explicit lshape "
                             "(build via sharded_operator)")
        return tuple(self.A.shape)

    @property
    def dtype(self):
        if isinstance(self.A, SparseShards):
            return self.A.mv_vals.dtype
        return self.A.dtype

    @property
    def _padded_shape(self) -> tuple[int, int]:
        return padded_operand_shape(self.shape, self.mesh)

    @property
    def _is_sparse(self) -> bool:
        return isinstance(self.A, SparseShards)

    def _payload(self):
        """Payload padded to the mesh tiling (no-op for factory-built ops;
        direct constructions of non-divisible dense operands pad here)."""
        if self._is_sparse:
            return self.A
        mp, np_ = self._padded_shape
        if tuple(self.A.shape) == (mp, np_):
            return self.A
        return jnp.pad(self.A, ((0, mp - self.A.shape[0]),
                                (0, np_ - self.A.shape[1])))

    # --- matvec protocol ---------------------------------------------
    def _fns(self):
        return _matvec_fns(self.mesh, self._is_sparse)

    def mv(self, p):
        m, _ = self.shape
        mp, np_ = self._padded_shape
        out = self._fns()["mv"](
            self._payload(), _pad_rows(_f32(p)[:, None], np_),
            jnp.zeros((mp, 1), jnp.float32), jnp.zeros((), jnp.float32))
        return out[:m, 0]

    def rmv(self, q):
        _, n = self.shape
        mp, np_ = self._padded_shape
        out = self._fns()["rmv"](
            self._payload(), _pad_rows(_f32(q)[:, None], mp),
            jnp.zeros((np_, 1), jnp.float32), jnp.zeros((), jnp.float32))
        return out[:n, 0]

    def mv_fused(self, p, y, alpha):
        m, _ = self.shape
        mp, np_ = self._padded_shape
        out = self._fns()["mv"](
            self._payload(), _pad_rows(_f32(p)[:, None], np_),
            _pad_rows(_f32(y)[:, None], mp),
            jnp.asarray(alpha, jnp.float32))
        return out[:m, 0]

    def rmv_fused(self, q, y, beta):
        _, n = self.shape
        mp, np_ = self._padded_shape
        out = self._fns()["rmv"](
            self._payload(), _pad_rows(_f32(q)[:, None], mp),
            _pad_rows(_f32(y)[:, None], np_),
            jnp.asarray(beta, jnp.float32))
        return out[:n, 0]

    def matmat(self, V):
        m, _ = self.shape
        _, np_ = self._padded_shape
        return self._fns()["mm"](self._payload(),
                                 _pad_rows(jnp.asarray(V), np_))[:m]

    def rmatmat(self, Q):
        _, n = self.shape
        mp, _ = self._padded_shape
        return self._fns()["rmm"](self._payload(),
                                  _pad_rows(jnp.asarray(Q), mp))[:n]

    def sketch_pass(self, omega, psi):
        """Both sketch directions in one shard_map body: per-shard panel
        GEMMs + a single psum on a row-sharded mesh (zero-padding the
        panels to the mesh tiling is exact — padded operand rows/cols are
        zero)."""
        m, n = self.shape
        mp, np_ = self._padded_shape
        Y, Z = self._fns()["sketch"](
            self._payload(),
            _pad_rows(jnp.asarray(omega.dense()), np_),
            _pad_rows(jnp.asarray(psi.dense()), mp))
        return Y[:m], Z[:n]

    # --- fused Lanczos half-steps (the scale-out seam) ---------------
    def lanczos_step(self, p, y, alpha, basis, *, passes: int = 2):
        m, _ = self.shape
        mp, np_ = self._padded_shape
        left, _ = _step_fns(self.mesh, passes, self._is_sparse,
                            self.backend == "pallas")
        u, nrm = left(self._payload(), _pad_rows(_f32(p)[:, None], np_),
                      _pad_rows(_f32(y)[:, None], mp),
                      jnp.asarray(alpha, jnp.float32),
                      _pad_rows(basis, mp))
        return u[:m, 0], nrm

    def lanczos_rstep(self, q, y, beta, basis, *, passes: int = 2):
        _, n = self.shape
        mp, np_ = self._padded_shape
        _, right = _step_fns(self.mesh, passes, self._is_sparse,
                             self.backend == "pallas")
        v, nrm = right(self._payload(), _pad_rows(_f32(q)[:, None], mp),
                       _pad_rows(_f32(y)[:, None], np_),
                       jnp.asarray(beta, jnp.float32),
                       _pad_rows(basis, np_))
        return v[:n, 0], nrm

    # --- placement helpers -------------------------------------------
    @property
    def sharding_mesh(self) -> Mesh:
        return self.mesh

    def place_basis(self, X: Array, side: str) -> Array:
        """Lay a basis buffer out on the operand's vector sharding, so
        host-loop solvers do not re-shard it on every eager step.

        Buffers whose leading dim does not tile the mesh stay as-is (the
        fused steps zero-pad them per call instead; ``device_put`` cannot
        shard unevenly)."""
        rows, col = operator_axes(self.mesh)
        nrow, ncol = operator_counts(self.mesh)
        parts = nrow if side == "left" else ncol
        if X.shape[0] % parts:
            return X
        spec = P(rows or None, None) if side == "left" else P(col, None)
        return jax.device_put(X, NamedSharding(self.mesh, spec))

    def to_dense(self):
        if self._is_sparse:
            return Operator.to_dense(self)
        m, n = self.shape
        return self.A[:m, :n]


def _sparse_shards(sp: SparseOp, mesh: Mesh) -> tuple[SparseShards, tuple]:
    """Build row-partitioned ELL packs for ``sp`` (host-side, concrete)."""
    import numpy as np

    from repro.kernels.sparse_matvec import ell_pack

    rows_n, cols_n = operator_counts(mesh)
    if cols_n > 1:
        raise NotImplementedError(
            "sparse ShardedOp supports row-sharded meshes only (no "
            "'model' axis); got mesh axes "
            f"{tuple(mesh.axis_names)}")
    from repro.core.padding import pad_dim
    m, n = sp.spshape
    m_pad = pad_dim(m, rows_n)
    m_loc = m_pad // rows_n
    data = np.asarray(sp.data)
    idx = np.asarray(sp.indices)

    vals, cols = (np.asarray(x) for x in ell_pack(data, idx, (m, n)))
    vals = np.pad(vals, ((0, m_pad - m), (0, 0)))
    cols = np.pad(cols, ((0, m_pad - m), (0, 0)))

    packs = []
    for j in range(rows_n):
        lo, hi = j * m_loc, (j + 1) * m_loc
        sel = (idx[:, 0] >= lo) & (idx[:, 0] < hi)
        loc = np.stack([idx[sel, 1], idx[sel, 0] - lo], axis=1)
        packs.append(tuple(np.asarray(x)
                           for x in ell_pack(data[sel], loc, (n, m_loc))))
    width = max(p[0].shape[1] for p in packs)
    rv = np.concatenate([np.pad(v, ((0, 0), (0, width - v.shape[1])))
                         for v, _ in packs])
    rr = np.concatenate([np.pad(r, ((0, 0), (0, width - r.shape[1])))
                         for _, r in packs])

    row_axes, _ = operator_axes(mesh)
    sh = NamedSharding(mesh, P(row_axes or None, None))
    shards = SparseShards(
        jax.device_put(jnp.asarray(vals), sh),
        jax.device_put(jnp.asarray(cols), sh),
        jax.device_put(jnp.asarray(rv), sh),
        jax.device_put(jnp.asarray(rr), sh))
    return shards, (m, n)


def sharded_operator(x, mesh: Mesh, backend: Optional[str] = None):
    """Lay any supported operand out on ``mesh`` as a sharded operator.

    Dense arrays (and ``DenseOp``) zero-pad to the mesh tiling and
    ``device_put`` under the pod-sharded layout; ``SparseOp`` builds
    row-partitioned ELL packs per shard; ``GramOp`` / ``TransposedOp``
    push the sharding onto their inner operand (so ``estimate_rank``'s
    matrix-free unwrapping and the fused Lanczos seams keep composing);
    an existing :class:`ShardedOp` passes through.
    """
    from jax.experimental import sparse as jsparse

    from repro.core.linop import LinOp
    if isinstance(x, ShardedOp):
        return x
    if isinstance(x, jsparse.BCOO):
        return sharded_operator(SparseOp.from_bcoo(x), mesh, backend)
    if isinstance(x, GramOp):
        return GramOp(sharded_operator(x.inner, mesh, backend), side=x.side)
    if isinstance(x, TransposedOp):
        return TransposedOp(sharded_operator(x.inner, mesh, backend))
    if isinstance(x, SparseOp):
        shards, lshape = _sparse_shards(x, mesh)
        return ShardedOp(shards, mesh, lshape=lshape,
                         backend=backend or x.backend)
    if isinstance(x, Operator) or isinstance(x, LinOp):
        from repro.core.operators import DenseOp
        if isinstance(x, DenseOp):
            return sharded_operator(x.A, mesh, backend or x.backend)
        raise TypeError(
            f"sharded_operator cannot lay out {type(x).__name__}; supported "
            "operands: dense arrays / DenseOp, SparseOp (row-sharded), "
            "GramOp / TransposedOp wrappers, ShardedOp")
    from repro.core.padding import pad_to
    A = jnp.asarray(x) if not isinstance(x, jax.Array) else x
    lshape = tuple(A.shape)
    A = pad_to(A, padded_operand_shape(lshape, mesh))
    return ShardedOp(place_operator(A, mesh), mesh, lshape=lshape,
                     backend=backend or "xla")
