"""Pod-sharded GK matvecs: the paper's "huge matrix" regime on a real mesh.

The operator A (m, n) is sharded ``P(("pod","data"), "model")`` — rows over
the pod+data axes, columns over model.  The Lanczos vectors live sharded on
the matching axis:

    q (m,)  P(("pod","data"))          p (n,)  P("model")

Each GK half-iteration is then ONE local GEMV + ONE psum:

    A p  : local (m_loc, n_loc) @ (n_loc,) -> psum over "model"
    Aᵀ q : local transpose GEMV           -> psum over ("pod","data")

so a 1e5 x 8e4 matrix (the paper's largest, NA for dense SVD) occupies
~60 MB per device on a 512-chip mesh and each iteration moves only vectors.
The fused three-term forms (− α q / − β p) are folded into the shard_map
body so no extra HBM pass materializes the intermediate.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.linop import LinOp

Array = jax.Array


def _row_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sharded_operator(A: Array, mesh: Mesh) -> LinOp:
    """Wrap a (possibly already device-sharded) dense A as a pod-sharded
    LinOp whose matvecs are shard_map'd local GEMVs + one psum."""
    m, n = A.shape
    rows = _row_axes(mesh)
    col = "model" if "model" in mesh.axis_names else None
    a_spec = P(rows or None, col)
    q_spec = P(rows or None)
    p_spec = P(col)

    def _mv(a_blk, p_blk, y_blk, alpha):
        out = a_blk.astype(jnp.float32) @ p_blk.astype(jnp.float32)
        if col is not None:
            out = jax.lax.psum(out, col)
        return out - alpha * y_blk.astype(jnp.float32)

    def _rmv(a_blk, q_blk, y_blk, beta):
        out = a_blk.astype(jnp.float32).T @ q_blk.astype(jnp.float32)
        if rows:
            out = jax.lax.psum(out, rows)
        return out - beta * y_blk.astype(jnp.float32)

    mv_sm = jax.shard_map(
        functools.partial(_mv),
        mesh=mesh, in_specs=(a_spec, p_spec, q_spec, P()),
        out_specs=q_spec, check_vma=False)
    rmv_sm = jax.shard_map(
        functools.partial(_rmv),
        mesh=mesh, in_specs=(a_spec, q_spec, p_spec, P()),
        out_specs=p_spec, check_vma=False)

    zero = jnp.zeros((), jnp.float32)

    def mv(p):
        return mv_sm(A, p, jnp.zeros((m,), jnp.float32), zero)

    def rmv(q):
        return rmv_sm(A, q, jnp.zeros((n,), jnp.float32), zero)

    def mv_fused(p, y, alpha):
        return mv_sm(A, p, y, jnp.asarray(alpha, jnp.float32))

    def rmv_fused(q, y, beta):
        return rmv_sm(A, q, y, jnp.asarray(beta, jnp.float32))

    return LinOp((m, n), mv, rmv, dtype=A.dtype,
                 _mv_fused=mv_fused, _rmv_fused=rmv_fused)


def place_operator(A: Array, mesh: Mesh) -> Array:
    """device_put A under the pod-sharded layout."""
    rows = _row_axes(mesh)
    col = "model" if "model" in mesh.axis_names else None
    return jax.device_put(A, NamedSharding(mesh, P(rows or None, col)))
