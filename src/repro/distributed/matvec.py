"""Pod-sharded GK matvecs: the paper's "huge matrix" regime on a real mesh.

The operator A (m, n) is sharded ``P(("pod","data"), "model")`` — rows over
the pod+data axes, columns over model.  The Lanczos vectors live sharded on
the matching axis:

    q (m,)  P(("pod","data"))          p (n,)  P("model")

Each GK half-iteration is then ONE local GEMV + ONE psum:

    A p  : local (m_loc, n_loc) @ (n_loc,) -> psum over "model"
    Aᵀ q : local transpose GEMV           -> psum over ("pod","data")

so a 1e5 x 8e4 matrix (the paper's largest, NA for dense SVD) occupies
~60 MB per device on a 512-chip mesh and each iteration moves only vectors.
The fused three-term forms (− α q / − β p) are folded into the shard_map
body so no extra HBM pass materializes the intermediate.

``ShardedOp`` is a pytree operator (``repro.core.operators``): the sharded
matrix is the only leaf, the mesh rides as static aux data, so a whole
F-SVD solve over it jits as one program and plugs into ``repro.api``
unchanged.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.operators import Operator, register_operator

Array = jax.Array


def _row_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@functools.lru_cache(maxsize=None)
def _sharded_matvecs(mesh: Mesh):
    """shard_map'd fused GEMV+psum kernels for ``mesh`` (cached per mesh).

    Both take ``(A_blk, vec, y, scalar)`` and compute the three-term Lanczos
    form; plain matvecs pass ``y=0, scalar=0``.
    """
    rows = _row_axes(mesh)
    col = "model" if "model" in mesh.axis_names else None
    a_spec = P(rows or None, col)
    q_spec = P(rows or None)
    p_spec = P(col)

    def _mv(a_blk, p_blk, y_blk, alpha):
        out = a_blk.astype(jnp.float32) @ p_blk.astype(jnp.float32)
        if col is not None:
            out = jax.lax.psum(out, col)
        return out - alpha * y_blk.astype(jnp.float32)

    def _rmv(a_blk, q_blk, y_blk, beta):
        out = a_blk.astype(jnp.float32).T @ q_blk.astype(jnp.float32)
        if rows:
            out = jax.lax.psum(out, rows)
        return out - beta * y_blk.astype(jnp.float32)

    mv_sm = compat.shard_map(
        _mv, mesh=mesh, in_specs=(a_spec, p_spec, q_spec, P()),
        out_specs=q_spec, check_vma=False)
    rmv_sm = compat.shard_map(
        _rmv, mesh=mesh, in_specs=(a_spec, q_spec, p_spec, P()),
        out_specs=p_spec, check_vma=False)
    return mv_sm, rmv_sm


@register_operator
@dataclasses.dataclass(frozen=True, eq=False)
class ShardedOp(Operator):
    """Pod-sharded dense operator: matvecs are local GEMVs + one psum.

    The (device-sharded) matrix is the pytree leaf; the mesh is static aux
    data, so the operator crosses ``jit`` boundaries whole and the GK /
    F-SVD cores (and ``repro.api.factorize``) run on it unmodified.
    Use :func:`place_operator` / :func:`sharded_operator` to lay A out
    first.
    """

    A: Array
    mesh: Mesh

    _data_fields = ("A",)
    _meta_fields = ("mesh",)

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.A.shape)

    @property
    def dtype(self):
        return self.A.dtype

    def mv(self, p):
        mv_sm, _ = _sharded_matvecs(self.mesh)
        m = self.A.shape[0]
        return mv_sm(self.A, p, jnp.zeros((m,), jnp.float32),
                     jnp.zeros((), jnp.float32))

    def rmv(self, q):
        _, rmv_sm = _sharded_matvecs(self.mesh)
        n = self.A.shape[1]
        return rmv_sm(self.A, q, jnp.zeros((n,), jnp.float32),
                      jnp.zeros((), jnp.float32))

    def mv_fused(self, p, y, alpha):
        mv_sm, _ = _sharded_matvecs(self.mesh)
        return mv_sm(self.A, p, y, jnp.asarray(alpha, jnp.float32))

    def rmv_fused(self, q, y, beta):
        _, rmv_sm = _sharded_matvecs(self.mesh)
        return rmv_sm(self.A, q, y, jnp.asarray(beta, jnp.float32))


def sharded_operator(A: Array, mesh: Mesh) -> ShardedOp:
    """Wrap a (possibly already device-sharded) dense A as a pod-sharded
    operator whose matvecs are shard_map'd local GEMVs + one psum."""
    return ShardedOp(A, mesh)


def place_operator(A: Array, mesh: Mesh) -> Array:
    """device_put A under the pod-sharded layout."""
    rows = _row_axes(mesh)
    col = "model" if "model" in mesh.axis_names else None
    return jax.device_put(A, NamedSharding(mesh, P(rows or None, col)))
