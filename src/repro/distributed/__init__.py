"""Distribution layer: logical-axis partitioning rules, pod-sharded GK
matvecs (``ShardedOp``), distributed F-SVD through the ``repro.api``
facade, and Krylov low-rank gradient compression."""
from repro.distributed.matvec import (ShardedOp, place_operator,
                                      sharded_operator)
from repro.distributed.partition import (logical_to_spec, param_shardings,
                                         spec_for_batch)

__all__ = [
    "logical_to_spec", "param_shardings", "spec_for_batch",
    "ShardedOp", "place_operator", "sharded_operator",
]
