"""Distribution layer: logical-axis partitioning rules, pod-sharded GK
matvecs, distributed F-SVD, and Krylov low-rank gradient compression."""
from repro.distributed.partition import (logical_to_spec, param_shardings,
                                         spec_for_batch)

__all__ = ["logical_to_spec", "param_shardings", "spec_for_batch"]
