"""Distributed Algorithms 1-3: GK / F-SVD / rank on a pod-sharded operator.

Thin composition: ``ShardedOp`` supplies the fused one-psum-per-half-step
Lanczos seam; the *same* ``repro.core`` solvers run unmodified on top (the
basis matrices P, Q are GSPMD-sharded over the vector axes automatically).
This is the paper's whole point carried to cluster scale: the algorithm
only ever touches A through matvecs, so distribution is a property of the
operator, not of the algorithm.

Because every registered solver accepts sharded operands directly —
``factorize(sharded_operator(A, mesh), spec)`` with ``method`` any of
"fsvd" / "rsvd" / "fsvd_blocked" — the ``"fsvd_sharded"`` name registered
here is a *shim*: it type-checks the operand, rejects host-loop specs (a
host loop on a sharded operand would round-trip full gathered vectors
every iteration) and delegates to the plain F-SVD solver.  The
:func:`sharded_fsvd` / :func:`sharded_rank` conveniences just compose
:func:`~repro.distributed.matvec.sharded_operator` with the facade.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.api import SVDSpec, estimate_rank, factorize, register_solver
from repro.api.results import Factorization, RankEstimate
from repro.api.solvers import solve_fsvd
from repro.core.gk import GKResult, gk_bidiag
from repro.distributed.matvec import (ShardedOp, place_operator,
                                      sharded_operator)

Array = jax.Array


@register_solver("fsvd_sharded")
def solve_fsvd_sharded(A, spec: SVDSpec, *, key=None, q1=None,
                       callback=None) -> Factorization:
    """Registration shim: F-SVD on a pod-sharded operator.

    ``A`` must already be a :class:`ShardedOp` (use :func:`sharded_fsvd`
    to place a dense matrix on a mesh first; ``method="auto"`` on a
    sharded operand also resolves here).  ``host_loop=True`` is
    rejected: the host loop synchronizes a gathered scalar pair every
    iteration, which on a sharded operand serializes the mesh behind the
    host round-trip — use the in-graph loop (``host_loop=None``/False).

    The method is plan-stageable (``repro.api.plan``): the compile-cache
    key includes the operand's pytree treedef, and a ``ShardedOp`` carries
    its ``Mesh`` (plus logical shape and backend) as static aux data — so
    plans on different meshes or mesh factorizations never share an
    executable, while repeat solves on the same placement reuse one.
    """
    if not isinstance(A, ShardedOp):
        raise TypeError(
            "method='fsvd_sharded' needs a ShardedOp operand; wrap the "
            "matrix with repro.distributed.sharded_fsvd(A, mesh, ...) or "
            "sharded_operator(A, mesh).")
    if spec.host_loop:
        raise ValueError(
            "method='fsvd_sharded' does not support host_loop=True: the "
            "early-exit host loop gathers device scalars every iteration, "
            "stalling the whole mesh on one host round-trip per step.  Use "
            "host_loop=None/False (the in-graph fori_loop), or run the "
            "plain 'fsvd' method if you accept the per-step sync.")
    out = solve_fsvd(A, spec.replace(host_loop=False), key=key, q1=q1,
                     callback=callback)
    return Factorization(out.U, out.s, out.V, out.iterations,
                         out.breakdown, method="fsvd_sharded")


def sharded_fsvd(A, mesh: Mesh, spec: SVDSpec, *, key=None,
                 q1=None) -> Factorization:
    """Place A (dense, ``SparseOp``, ``GramOp``/``TransposedOp`` wrapped)
    on ``mesh`` and run the facade on it."""
    return factorize(sharded_operator(A, mesh),
                     spec.replace(method="fsvd_sharded"), key=key, q1=q1)


def sharded_rank(A, mesh: Mesh, spec: Optional[SVDSpec] = None, *,
                 key=None, **overrides) -> RankEstimate:
    """Numerical rank of a pod-sharded operand through the facade.

    No special-casing: ``estimate_rank`` accepts the sharded operator
    directly (its matrix-free ``GramOp``/``TransposedOp`` unwrapping
    composes with the sharding wrappers, and its host-loop default flips
    to the in-graph loop for sharded operands)."""
    return estimate_rank(sharded_operator(A, mesh), spec, key=key,
                         **overrides)


# --------------------------------------------------------------------------
# legacy signatures (deprecated shims over the facade)
# --------------------------------------------------------------------------

def fsvd_sharded(A: Array, mesh: Mesh, r: int, k: Optional[int] = None,
                 **kw) -> Factorization:
    """Deprecated: use :func:`sharded_fsvd` with an :class:`SVDSpec`."""
    import warnings
    from repro.compat import ReproDeprecationWarning
    warnings.warn("fsvd_sharded(A, mesh, r, k) is deprecated; use "
                  "sharded_fsvd(A, mesh, SVDSpec(rank=r, max_iters=k)).",
                  ReproDeprecationWarning, stacklevel=2)
    key = kw.pop("key", None)
    q1 = kw.pop("q1", None)
    spec = SVDSpec(method="fsvd_sharded", rank=r, max_iters=k, **{
        {"eps": "tol", "relative_eps": "relative_tol"}.get(a, a): v
        for a, v in kw.items()})
    return sharded_fsvd(A, mesh, spec, key=key, q1=q1)


def gk_sharded(A: Array, mesh: Mesh, k: int, **kw) -> GKResult:
    return gk_bidiag(sharded_operator(A, mesh), k, **kw)


def rank_sharded(A: Array, mesh: Mesh, **kw) -> RankEstimate:
    """Deprecated alias of :func:`sharded_rank` (kwargs pass through in the
    legacy ``repro.core.rank.numerical_rank`` spellings)."""
    import warnings
    from repro.compat import ReproDeprecationWarning
    warnings.warn("rank_sharded(A, mesh, **kw) is deprecated; use "
                  "sharded_rank(A, mesh, SVDSpec(...)).",
                  ReproDeprecationWarning, stacklevel=2)
    key = kw.pop("key", None)
    spec = SVDSpec(
        max_iters=kw.pop("max_iters", None),
        tol=kw.pop("eps", 1e-8),
        relative_tol=kw.pop("relative_eps", True),
        reorth_passes=kw.pop("reorth_passes", 2),
        dtype=kw.pop("dtype", None),
    )
    sigma_tol = kw.pop("sigma_tol", None)
    if kw:
        raise TypeError(f"rank_sharded() got unsupported kwargs: "
                        f"{sorted(kw)}")
    return sharded_rank(A, mesh, spec, key=key, sigma_tol=sigma_tol)
