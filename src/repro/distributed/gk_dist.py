"""Distributed Algorithms 1-3: GK / F-SVD / rank on a pod-sharded operator.

Thin composition: ``ShardedOp`` supplies matvecs-with-psum; the *same*
``repro.core`` solvers run unmodified on top (the basis matrices P, Q are
GSPMD-sharded over the vector axes automatically).  This is the paper's
whole point carried to cluster scale: the algorithm only ever touches A
through matvecs, so distribution is a property of the operator, not of the
algorithm.

Importing this module registers the ``"fsvd_sharded"`` solver with
``repro.api``; it requires a :class:`ShardedOp` operand —
``factorize(ShardedOp(place_operator(A, mesh), mesh), spec)`` or the
:func:`sharded_fsvd` convenience, which places the matrix first.  Simpler
still: pass a ``ShardedOp`` to the plain ``"fsvd"`` method — the facade is
operator-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.api import SVDSpec, estimate_rank, factorize, register_solver
from repro.api.results import Factorization, RankEstimate
from repro.api.solvers import solve_fsvd
from repro.core.gk import GKResult, gk_bidiag
from repro.distributed.matvec import ShardedOp, place_operator

Array = jax.Array


@register_solver("fsvd_sharded")
def solve_fsvd_sharded(A, spec: SVDSpec, *, key=None, q1=None
                       ) -> Factorization:
    """F-SVD on a pod-sharded operator.

    ``A`` must already be a :class:`ShardedOp` (use :func:`sharded_fsvd`
    to place a dense matrix on a mesh first).  ``host_loop=None`` defaults
    to the in-graph GK loop (a host loop round-trips device vectors every
    iteration); an explicit ``host_loop=True`` is honored.
    """
    if not isinstance(A, ShardedOp):
        raise TypeError(
            "method='fsvd_sharded' needs a ShardedOp operand; wrap the "
            "matrix with repro.distributed.sharded_fsvd(A, mesh, ...) or "
            "ShardedOp(place_operator(A, mesh), mesh).")
    out = solve_fsvd(A, spec, key=key, q1=q1)
    return Factorization(out.U, out.s, out.V, out.iterations,
                         out.breakdown, method="fsvd_sharded")


def sharded_fsvd(A: Array, mesh: Mesh, spec: SVDSpec, *, key=None,
                 q1=None) -> Factorization:
    """Place A pod-sharded on ``mesh`` and run the facade on it."""
    op = ShardedOp(place_operator(A, mesh), mesh)
    return factorize(op, spec.replace(method="fsvd_sharded"), key=key, q1=q1)


def sharded_rank(A: Array, mesh: Mesh, spec: Optional[SVDSpec] = None, *,
                 key=None, **overrides) -> RankEstimate:
    """Numerical rank of a pod-sharded matrix through the facade."""
    op = ShardedOp(place_operator(A, mesh), mesh)
    spec = (spec or SVDSpec()).replace(host_loop=False)
    return estimate_rank(op, spec, key=key, **overrides)


# --------------------------------------------------------------------------
# legacy signatures (deprecated shims over the facade)
# --------------------------------------------------------------------------

def fsvd_sharded(A: Array, mesh: Mesh, r: int, k: Optional[int] = None,
                 **kw) -> Factorization:
    """Deprecated: use :func:`sharded_fsvd` with an :class:`SVDSpec`."""
    import warnings
    warnings.warn("fsvd_sharded(A, mesh, r, k) is deprecated; use "
                  "sharded_fsvd(A, mesh, SVDSpec(rank=r, max_iters=k)).",
                  DeprecationWarning, stacklevel=2)
    key = kw.pop("key", None)
    q1 = kw.pop("q1", None)
    spec = SVDSpec(method="fsvd_sharded", rank=r, max_iters=k, **{
        {"eps": "tol", "relative_eps": "relative_tol"}.get(a, a): v
        for a, v in kw.items()})
    return sharded_fsvd(A, mesh, spec, key=key, q1=q1)


def gk_sharded(A: Array, mesh: Mesh, k: int, **kw) -> GKResult:
    A = place_operator(A, mesh)
    return gk_bidiag(ShardedOp(A, mesh), k, **kw)


def rank_sharded(A: Array, mesh: Mesh, **kw) -> RankEstimate:
    """Deprecated alias of :func:`sharded_rank` (kwargs pass through in the
    legacy ``repro.core.rank.numerical_rank`` spellings)."""
    import warnings
    warnings.warn("rank_sharded(A, mesh, **kw) is deprecated; use "
                  "sharded_rank(A, mesh, SVDSpec(...)).",
                  DeprecationWarning, stacklevel=2)
    key = kw.pop("key", None)
    spec = SVDSpec(
        max_iters=kw.pop("max_iters", None),
        tol=kw.pop("eps", 1e-8),
        relative_tol=kw.pop("relative_eps", True),
        reorth_passes=kw.pop("reorth_passes", 2),
        dtype=kw.pop("dtype", None),
    )
    sigma_tol = kw.pop("sigma_tol", None)
    if kw:
        raise TypeError(f"rank_sharded() got unsupported kwargs: "
                        f"{sorted(kw)}")
    return sharded_rank(A, mesh, spec, key=key, sigma_tol=sigma_tol)
