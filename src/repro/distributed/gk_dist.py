"""Distributed Algorithms 1-3: GK / F-SVD / rank on a pod-sharded operator.

Thin composition: ``sharded_operator`` supplies matvecs-with-psum; the
*same* ``repro.core`` code runs unmodified on top (the basis matrices P, Q
are GSPMD-sharded over the vector axes automatically).  This is the paper's
whole point carried to cluster scale: the algorithm only ever touches A
through matvecs, so distribution is a property of the operator, not of the
algorithm.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.core.fsvd import FSVDResult, fsvd as _fsvd
from repro.core.gk import GKResult, gk_bidiag
from repro.core.rank import RankResult, numerical_rank as _rank
from repro.distributed.matvec import place_operator, sharded_operator

Array = jax.Array


def fsvd_sharded(A: Array, mesh: Mesh, r: int, k: Optional[int] = None,
                 **kw) -> FSVDResult:
    """Partial SVD of a pod-sharded dense matrix (Alg 2 at pod scale)."""
    A = place_operator(A, mesh)
    return _fsvd(sharded_operator(A, mesh), r, k, **kw)


def gk_sharded(A: Array, mesh: Mesh, k: int, **kw) -> GKResult:
    A = place_operator(A, mesh)
    return gk_bidiag(sharded_operator(A, mesh), k, **kw)


def rank_sharded(A: Array, mesh: Mesh, **kw) -> RankResult:
    A = place_operator(A, mesh)
    return _rank(sharded_operator(A, mesh), host_loop=False, **kw)
