"""Krylov low-rank gradient compression with error feedback.

The paper's F-SVD as a *distributed-optimization* trick (PowerSGD-shaped,
Lanczos-accurate).  In data-parallel training the gradient all-reduce moves
``m*n`` floats per 2-D parameter; instead we run GK bidiagonalization on the
**implicit mean-gradient operator**

    mv(p)  = psum(G_local @ p,  axis) / n_workers
    rmv(q) = psum(G_localᵀ @ q, axis) / n_workers

so each Lanczos iteration communicates one m-vector + one n-vector, and k
iterations deliver the top-r singular triplets of the *exact mean* gradient
(not a mean of per-worker approximations — the psum is inside the matvec).
Communication: ``k (m + n)`` vs ``m n`` floats — e.g. a 4096x14336 MLP block
at k=12 moves 0.4% of the dense bytes.

Error feedback (Seide et al. / PowerSGD): each worker accumulates what
compression dropped, ``e ← (G_local + e) − lowrank(mean)``, restoring
convergence to the uncompressed fixed point.

Usage: inside ``shard_map`` over the DP axis (the examples use a pure-DP
mesh; the multi-pod trainer applies it on the "pod" axis where the slow DCN
hop lives, keeping plain psum over ICI).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FsvdConfig
from repro.core.fsvd import fsvd as _fsvd
from repro.core.linop import LinOp

Array = jax.Array
PyTree = Any


class CompressionStats(NamedTuple):
    dense_bytes: Array        # what a plain all-reduce would move
    compressed_bytes: Array   # what the factor exchange moved
    num_compressed: int
    num_plain: int


def _as_2d(g: Array) -> Optional[tuple[int, int]]:
    if g.ndim < 2:
        return None
    n = 1
    for d in g.shape[1:]:
        n *= d
    return g.shape[0], n


def _layout(g: Array, cfg: FsvdConfig):
    """How to compress a leaf: None (plain psum), ("2d", m, n), or
    ("batched", L, m, n) for stacked scanned-layer parameters — those are
    L independent 2-D gradients, compressed per layer under vmap (which
    also batches the Lanczos all-reduces into (L, m)-shaped payloads)."""
    if g.ndim < 2:
        return None
    if g.ndim >= 3:
        L, m = g.shape[0], g.shape[1]
        n = 1
        for d in g.shape[2:]:
            n *= d
        if min(m, n) >= cfg.compression_min_dim:
            return ("batched", L, m, n)
        return None
    m, n = _as_2d(g)
    if min(m, n) >= cfg.compression_min_dim:
        return ("2d", m, n)
    return None


def _compressible(g: Array, cfg: FsvdConfig) -> bool:
    return _layout(g, cfg) is not None


def mean_grad_operator(G_local: Array, axis) -> LinOp:
    """Implicit mean-over-workers operator for a 2-D local gradient."""
    m, n = G_local.shape
    nw = jax.lax.psum(1, axis)

    def mv(p):
        return jax.lax.psum(G_local @ p, axis) / nw

    def rmv(q):
        return jax.lax.psum(G_local.T @ q, axis) / nw

    return LinOp((m, n), mv, rmv, dtype=G_local.dtype)


def compress_mean(G_local: Array, axis, rank: int, k: int,
                  key: Optional[jax.Array] = None,
                  reorth_passes: int = 2) -> tuple[Array, Array, Array]:
    """(U, s, V) of the mean gradient via distributed GK (Alg 2)."""
    op = mean_grad_operator(G_local.astype(jnp.float32), axis)
    out = _fsvd(op, rank, k, key=key, reorth_passes=reorth_passes,
                relative_eps=True)
    return out.U, out.s, out.V


def compressed_mean_grads(grads: PyTree, ef: PyTree, axis,
                          cfg: FsvdConfig,
                          key: Optional[jax.Array] = None
                          ) -> tuple[PyTree, PyTree, CompressionStats]:
    """Tree-wide compressed gradient mean with error feedback.

    ``grads`` are per-worker local gradients (inside shard_map over ``axis``);
    ``ef`` is the residual pytree from ``init_error_feedback``.
    Returns (mean_grads, new_ef, stats).
    """
    nw = jax.lax.psum(1, axis)
    if key is None:
        key = jax.random.PRNGKey(0)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = jax.tree_util.tree_flatten(ef)[0]
    out, new_ef = [], []
    dense_b = jnp.zeros((), jnp.float32)
    comp_b = jnp.zeros((), jnp.float32)
    n_comp = n_plain = 0
    # few Krylov iterations suffice for a rank-r factor-quality approximation;
    # comm grows linearly in k so keep it tight (2r is the PowerSGD-comparable
    # budget; the GK subspace converges much faster than power iteration).
    k = min(max(2 * cfg.compression_rank, cfg.compression_rank + 2),
            cfg.max_iters)

    for i, (g, e) in enumerate(zip(leaves, ef_leaves)):
        lay = _layout(g, cfg)
        if lay is None:
            out.append(jax.lax.psum(g, axis) / nw)
            new_ef.append(e)
            n_plain += 1
            continue
        sub = jax.random.fold_in(key, i)
        r = cfg.compression_rank
        if lay[0] == "2d":
            _, m, n = lay
            g2 = g.reshape(m, n).astype(jnp.float32)
            if cfg.error_feedback:
                g2 = g2 + e.reshape(m, n)
            U, s, V = compress_mean(g2, axis, r, k, key=sub)
            low = (U * s[None, :]) @ V.T
            layers = 1
        else:
            _, layers, m, n = lay
            g2 = g.reshape(layers, m, n).astype(jnp.float32)
            if cfg.error_feedback:
                g2 = g2 + e.reshape(layers, m, n)
            U, s, V = jax.vmap(
                lambda gg: compress_mean(gg, axis, r, k, key=sub))(g2)
            low = jnp.einsum("lmr,lr,lnr->lmn", U, s, V)
        if cfg.error_feedback:
            new_ef.append((g2 - low).reshape(g.shape).astype(e.dtype))
        else:
            new_ef.append(e)
        out.append(low.reshape(g.shape).astype(g.dtype))
        n_comp += 1
        dense_b = dense_b + 4.0 * layers * m * n
        # per GK iteration: one m-vector + one n-vector all-reduced (batched
        # over layers), plus the final r-column AV matmat for U
        comp_b = comp_b + 4.0 * layers * (k * (m + n) + r * m)

    stats = CompressionStats(dense_b, comp_b, n_comp, n_plain)
    return jax.tree_util.tree_unflatten(treedef, out), \
        jax.tree_util.tree_unflatten(treedef, new_ef), stats


def init_error_feedback(params: PyTree, cfg: FsvdConfig) -> PyTree:
    """Zeros for compressible leaves; scalar zeros elsewhere (cheap)."""
    def f(p):
        if _compressible(p, cfg):
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros((), jnp.float32)
    return jax.tree.map(f, params)
