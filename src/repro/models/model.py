"""Model zoo assembly: every assigned architecture behind one API.

    init_model(cfg, key)                  -> (params, logical_axes)
    loss_fn(params, batch, cfg, mesh)     -> (loss, metrics)         [train]
    prefill_step(params, batch, cfg, ..)  -> (last_logits, cache)    [prefill]
    decode_step(params, cache, batch, ..) -> (logits, new_cache)     [decode]
    init_cache(cfg, batch, max_seq)       -> cache pytree

Families: dense / moe / vlm share the decoder-LM skeleton; audio is an
encoder-decoder (whisper); ssm is a Mamba2 stack; hybrid is Zamba2 (Mamba2
backbone + one SHARED attention+MLP block applied every ``attn_every``
layers).

Scale design:
  * homogeneous layer stacks are ``lax.scan``-ned over stacked parameters —
    compile time and HLO size stay O(1) in depth (42-60 layer archs);
  * layer heterogeneity that only changes *masking* (gemma2 local/global
    alternation) is expressed as a scanned per-layer ``window`` int array,
    keeping one scan body;
  * structural heterogeneity (deepseek's dense layer 0, zamba2's shared-attn
    sites) is expressed as unrolled prefix / grouped scans;
  * the LM head + cross-entropy is sequence-chunked (``cfg.ce_chunk``) so the
    (B, S, vocab) logits tensor is never materialized at once — with 256k
    vocabularies that tensor alone would exceed a v5e HBM;
  * per-layer remat (``cfg.remat_policy``) wraps the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (ParamBag, apply_norm, init_norm, stack_bags)
from repro.models.mlp import init_mlp, mlp

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------

def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(num_layers,) int32 sliding-window per layer; GLOBAL_WINDOW = global."""
    L = cfg.num_layers
    if not cfg.attn_pattern or cfg.sliding_window is None:
        return jnp.full((L,), attn_mod.GLOBAL_WINDOW, jnp.int32)
    pat = [cfg.sliding_window if k == "local" else attn_mod.GLOBAL_WINDOW
           for k in cfg.attn_pattern]
    return jnp.asarray([pat[i % len(pat)] for i in range(L)], jnp.int32)


def _remat(f, policy: str):
    if policy == "none":
        return f
    if policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _pin_batch(x: Array, cfg: ModelConfig, mesh) -> Array:
    """Constrain (B, S, D) activations to batch sharding (see
    ``ModelConfig.pin_activations``).

    Axes that are Manual in the current trace context (e.g. "pod" inside
    the compressed-gradient shard_map) are excluded — the constraint only
    names the Auto axes it can legally pin.
    """
    if not cfg.pin_activations or mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import manual_axis_names
    from repro.distributed.partition import batch_axes
    baxes = batch_axes(mesh)
    manual = manual_axis_names()
    baxes = tuple(a for a in baxes if a not in manual)
    if not baxes:
        return x
    total = 1
    for a in baxes:
        total *= dict(mesh.shape)[a]
    if x.shape[0] % total:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(baxes, *([None] * (x.ndim - 1)))))


def _scan_layers(body, x: Array, stacked: PyTree, windows: Array,
                 caches: Optional[PyTree], policy: str):
    """Scan ``body(x, p, window, cache) -> (x, new_cache, aux)`` over layers.

    ``caches=None`` -> body gets cache=None (train / prefill); any non-None
    new_cache the body returns is stacked into the scan output.
    """
    has_cache = caches is not None

    def f(carry, xs):
        x, aux = carry
        if has_cache:
            p, w, cache = xs
        else:
            (p, w), cache = xs, None
        x, new_cache, a = body(x, p, w, cache)
        return (x, aux + a), new_cache

    f = _remat(f, policy)
    xs = (stacked, windows, caches) if has_cache else (stacked, windows)
    (x, aux), new_caches = jax.lax.scan(
        f, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


def _embed(params: dict, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head_logits(params: dict, x: Array, cfg: ModelConfig) -> Array:
    """(..., d) -> (..., V) in f32, with the final softcap."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x,
                            params["embed"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"],
                            preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _ce_sums(logits: Array, labels: Array, ignore_id: int = -1
             ) -> tuple[Array, Array]:
    """Summed token NLL + valid count, f32 (chunk-accumulation friendly)."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore_id
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    return nll.sum(), valid.sum()


def _chunked_ce(params: dict, x: Array, labels: Array, cfg: ModelConfig
                ) -> tuple[Array, Array]:
    """Sequence-chunked LM-head cross entropy. x: (B,S,d) final-normed.

    Returns (mean nll over valid tokens, n_valid).  The (B, chunk, V) logits
    block is the only vocab-sized live tensor.
    """
    B, S, _ = x.shape
    chunk = cfg.ce_chunk
    if not chunk or S % chunk or S <= chunk:
        nll, n = _ce_sums(_head_logits(params, x, cfg), labels)
        return nll / jnp.maximum(n, 1), n

    nc = S // chunk
    xc = jnp.moveaxis(x.reshape(B, nc, chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def f(carry, xs):
        nll_t, n_t = carry
        xi, li = xs
        nll, n = _ce_sums(_head_logits(params, xi, cfg), li)
        return (nll_t + nll, n_t + n), None

    # always full-remat the CE chunk body: the whole point is that the
    # (B, chunk, V) logits block must not be saved as a scan residual.
    f = _remat(f, "nothing")
    (nll, n), _ = jax.lax.scan(
        f, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xc, lc))
    return nll / jnp.maximum(n, 1), n


def _positions(B: int, S: int) -> Array:
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


class Metrics(NamedTuple):
    loss: Array
    ce: Array
    aux: Array
    n_tokens: Array


# ---------------------------------------------------------------------------
# decoder LM (dense / moe / vlm)
# ---------------------------------------------------------------------------

def _init_decoder_layer(key, cfg: ModelConfig, dtype, kind: str,
                        d_ff: Optional[int] = None) -> tuple[dict, dict]:
    bag = ParamBag(key)
    if cfg.mla is not None:
        attn_mod.init_mla(bag, cfg, dtype)
    else:
        attn_mod.init_gqa(bag, cfg, dtype)
    init_norm(bag, "attn_norm", cfg.d_model, cfg.norm, dtype)
    init_norm(bag, "mlp_norm", cfg.d_model, cfg.norm, dtype)
    if cfg.post_norm:
        init_norm(bag, "post_attn_norm", cfg.d_model, cfg.norm, dtype)
        init_norm(bag, "post_mlp_norm", cfg.d_model, cfg.norm, dtype)
    if kind == "moe":
        moe_mod.init_moe(bag, cfg, dtype)
        if cfg.moe.num_shared_experts:
            init_mlp(bag, cfg.d_model,
                     cfg.moe.num_shared_experts * cfg.moe.d_ff_shared,
                     cfg.mlp_act, dtype, name="shared_mlp")
    else:
        init_mlp(bag, cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_act, dtype)
    return bag.done()


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.moe is None:
        return ["dense"] * cfg.num_layers
    kinds = []
    for i in range(cfg.num_layers):
        is_moe = (i >= cfg.moe.moe_start_layer
                  and (i - cfg.moe.moe_start_layer) % cfg.moe.moe_every == 0)
        kinds.append("moe" if is_moe else "dense")
    return kinds


def _init_decoder_lm(cfg: ModelConfig, key) -> tuple[dict, dict]:
    dtype = jnp.dtype(cfg.param_dtype)
    bag = ParamBag(key)
    bag.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              dtype, scale=1.0)
    if not cfg.tie_embeddings:
        bag.dense("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  dtype)
    if cfg.vlm is not None:
        bag.dense("img_proj", (cfg.d_model, cfg.d_model),
                  ("img_in", "embed"), dtype)

    kinds = _layer_kinds(cfg)
    # prefix = leading dense run before the homogeneous tail (deepseek's
    # layer 0); the tail must be homogeneous for the scan.
    n_prefix = 0
    while n_prefix < len(kinds) and cfg.moe is not None \
            and kinds[n_prefix] == "dense":
        n_prefix += 1
    tail_kinds = set(kinds[n_prefix:])
    assert len(tail_kinds) <= 1, f"non-homogeneous tail: {kinds}"
    tail_kind = kinds[-1] if kinds else "dense"

    for i in range(n_prefix):
        p, lg = _init_decoder_layer(bag.next_key(), cfg, dtype, "dense")
        bag.params[f"layer{i}"] = p
        bag.logical[f"layer{i}"] = lg
    layer_bags = [
        _init_decoder_layer(bag.next_key(), cfg, dtype, tail_kind)
        for _ in range(cfg.num_layers - n_prefix)]
    bag.params["layers"], bag.logical["layers"] = stack_bags(layer_bags)
    init_norm(bag, "final_norm", cfg.d_model, cfg.norm, dtype)
    return bag.done()


def _decoder_block(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                   mesh: Optional[Mesh], window, cache, kind: str,
                   collect_kv: bool) -> tuple[Array, Optional[dict], Array]:
    attn_fn = (attn_mod.mla_attention if cfg.mla is not None
               else attn_mod.gqa_attention)
    x = _pin_batch(x, cfg, mesh)
    h = apply_norm(p["attn_norm"], x, cfg.norm)
    a, new_cache = attn_fn(p["attn"], h, positions, cfg, window=window,
                           cache=cache, collect_kv=collect_kv)
    if cfg.post_norm:
        a = apply_norm(p["post_attn_norm"], a, cfg.norm)
    x = x + a
    h = apply_norm(p["mlp_norm"], x, cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        m, aux = moe_mod.moe_block(p["moe"], h, cfg, mesh)
        if "shared_mlp" in p:
            m = m + mlp(p["shared_mlp"], h, cfg.mlp_act)
    else:
        m = mlp(p["mlp"], h, cfg.mlp_act)
    if cfg.post_norm:
        m = apply_norm(p["post_mlp_norm"], m, cfg.norm)
    return _pin_batch(x + m, cfg, mesh), new_cache, aux


def _decoder_backbone(params: dict, x: Array, positions: Array,
                      cfg: ModelConfig, mesh: Optional[Mesh],
                      caches: Optional[dict], collect_kv: bool
                      ) -> tuple[Array, Optional[dict], Array]:
    """Runs prefix layers (unrolled) + the scanned homogeneous tail."""
    kinds = _layer_kinds(cfg)
    windows = layer_windows(cfg)
    n_prefix = len([k for k in params if k.startswith("layer")
                    and k[5:].isdigit()])
    tail_kind = kinds[-1]
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix_caches = {}
    for i in range(n_prefix):
        cache_i = caches[f"layer{i}"] if caches is not None else None
        x, nc, aux = _decoder_block(
            params[f"layer{i}"], x, positions, cfg, mesh,
            windows[i], cache_i, "dense", collect_kv)
        aux_total = aux_total + aux
        if nc is not None:
            new_prefix_caches[f"layer{i}"] = nc

    def body(x, p, w, cache):
        return _decoder_block(p, x, positions, cfg, mesh, w, cache,
                              tail_kind, collect_kv)

    tail_caches = caches["layers"] if caches is not None else None
    x, new_tail, aux = _scan_layers(body, x, params["layers"],
                                    windows[n_prefix:], tail_caches,
                                    cfg.remat_policy)
    aux_total = aux_total + aux

    new_caches = None
    if caches is not None or (collect_kv and new_tail is not None):
        new_caches = dict(new_prefix_caches)
        new_caches["layers"] = new_tail
    return x, new_caches, aux_total


def _lm_inputs(params: dict, batch: dict, cfg: ModelConfig
               ) -> tuple[Array, Array, Array]:
    """Embed tokens (+ VLM image prefix). Returns (x, positions, labels)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    labels = batch.get("labels")
    if cfg.vlm is not None and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype)
        img = jnp.einsum("btd,de->bte", img, params["img_proj"])
        x = jnp.concatenate([img, x], axis=1)
        if labels is not None:
            pad = jnp.full(img.shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    B, S = x.shape[:2]
    return x, _positions(B, S), labels


# ---------------------------------------------------------------------------
# whisper (audio enc-dec)
# ---------------------------------------------------------------------------

def _init_encdec(cfg: ModelConfig, key) -> tuple[dict, dict]:
    dtype = jnp.dtype(cfg.param_dtype)
    bag = ParamBag(key)
    bag.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              dtype, scale=1.0)
    bag.dense("frame_proj", (cfg.d_model, cfg.d_model), ("img_in", "embed"),
              dtype)

    def enc_layer(k):
        b = ParamBag(k)
        attn_mod.init_gqa(b, cfg, dtype)
        init_norm(b, "attn_norm", cfg.d_model, cfg.norm, dtype)
        init_mlp(b, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
        init_norm(b, "mlp_norm", cfg.d_model, cfg.norm, dtype)
        return b.done()

    def dec_layer(k):
        b = ParamBag(k)
        attn_mod.init_gqa(b, cfg, dtype)
        init_norm(b, "attn_norm", cfg.d_model, cfg.norm, dtype)
        attn_mod.init_cross_attn(b, cfg, dtype)
        init_norm(b, "xattn_norm", cfg.d_model, cfg.norm, dtype)
        init_mlp(b, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
        init_norm(b, "mlp_norm", cfg.d_model, cfg.norm, dtype)
        return b.done()

    enc_bags = [enc_layer(bag.next_key())
                for _ in range(cfg.encdec.encoder_layers)]
    dec_bags = [dec_layer(bag.next_key()) for _ in range(cfg.num_layers)]
    bag.params["enc_layers"], bag.logical["enc_layers"] = stack_bags(enc_bags)
    bag.params["dec_layers"], bag.logical["dec_layers"] = stack_bags(dec_bags)
    init_norm(bag, "enc_norm", cfg.d_model, cfg.norm, dtype)
    init_norm(bag, "final_norm", cfg.d_model, cfg.norm, dtype)
    return bag.done()


def _whisper_encode(params: dict, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, T, d) precomputed stub embeddings -> encoder output."""
    x = jnp.einsum("btd,de->bte", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frame_proj"])
    B, T = x.shape[:2]
    pos = _positions(B, T)

    def body(x, p, w, _):
        h = apply_norm(p["attn_norm"], x, cfg.norm)
        a, _ = attn_mod.gqa_attention(p["attn"], h, pos, cfg, window=w,
                                      causal=False)
        x = x + a
        h = apply_norm(p["mlp_norm"], x, cfg.norm)
        return x + mlp(p["mlp"], h, cfg.mlp_act), None, jnp.zeros((), jnp.float32)

    L = cfg.encdec.encoder_layers
    windows = jnp.full((L,), attn_mod.GLOBAL_WINDOW, jnp.int32)
    x, _, _ = _scan_layers(body, x, params["enc_layers"], windows, None,
                           cfg.remat_policy)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def _whisper_decode_stack(params: dict, x: Array, positions: Array,
                          cfg: ModelConfig, enc_out: Optional[Array],
                          caches: Optional[dict], collect_kv: bool
                          ) -> tuple[Array, Optional[dict]]:
    """Decoder layers.  Cross-attention K/V come from ``enc_out`` during
    train/prefill (computed per layer inside the scan) and from the cache
    during decode.

    ``caches`` is the flat stacked dict {"self": {k,v}, "cross_k", "cross_v"}
    with a leading decoder-layer dim.  In decode mode only the self cache is
    re-emitted through the scan (cross K/V are static) and merged back after.
    """
    def body(x, p, w, cache):
        self_cache = cache["self"] if cache is not None else None
        h = apply_norm(p["attn_norm"], x, cfg.norm)
        a, new_self = attn_mod.gqa_attention(p["attn"], h, positions, cfg,
                                             window=w, cache=self_cache,
                                             collect_kv=collect_kv)
        x = x + a
        h = apply_norm(p["xattn_norm"], x, cfg.norm)
        if cache is not None:
            kv = (cache["cross_k"], cache["cross_v"])
        else:
            kv = attn_mod.encode_cross_kv(p["xattn"], enc_out)
        x = x + attn_mod.cross_attention(p["xattn"], h, kv, cfg)
        h = apply_norm(p["mlp_norm"], x, cfg.norm)
        x = x + mlp(p["mlp"], h, cfg.mlp_act)
        new_cache = None
        if cache is not None:
            new_cache = {"self": new_self}
        elif collect_kv:
            new_cache = {"self": new_self, "cross_k": kv[0], "cross_v": kv[1]}
        return x, new_cache, jnp.zeros((), jnp.float32)

    L = cfg.num_layers
    windows = jnp.full((L,), attn_mod.GLOBAL_WINDOW, jnp.int32)
    x, new_caches, _ = _scan_layers(body, x, params["dec_layers"], windows,
                                    caches, cfg.remat_policy)
    if caches is not None:
        new_caches = {"self": new_caches["self"],
                      "cross_k": caches["cross_k"],
                      "cross_v": caches["cross_v"]}
    return x, new_caches


# ---------------------------------------------------------------------------
# mamba2 (ssm) and zamba2 (hybrid)
# ---------------------------------------------------------------------------

def _init_ssm_layer(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    bag = ParamBag(key)
    ssm_mod.init_ssm(bag, cfg, dtype)
    init_norm(bag, "norm", cfg.d_model, cfg.norm, dtype)
    return bag.done()


def _init_mamba(cfg: ModelConfig, key) -> tuple[dict, dict]:
    dtype = jnp.dtype(cfg.param_dtype)
    bag = ParamBag(key)
    bag.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              dtype, scale=1.0)
    if not cfg.tie_embeddings:
        bag.dense("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  dtype)
    bags = [_init_ssm_layer(bag.next_key(), cfg, dtype)
            for _ in range(cfg.num_layers)]
    bag.params["layers"], bag.logical["layers"] = stack_bags(bags)
    init_norm(bag, "final_norm", cfg.d_model, cfg.norm, dtype)
    return bag.done()


def _ssm_stack(params_stacked: PyTree, x: Array, cfg: ModelConfig,
               caches: Optional[PyTree], collect_kv: bool, policy: str
               ) -> tuple[Array, Optional[PyTree]]:
    def body(x, p, w, cache):
        h = apply_norm(p["norm"], x, cfg.norm)
        y, nc = ssm_mod.ssm_block(p["ssm"], h, cfg, cache,
                                  collect_state=collect_kv)
        return x + y, nc, jnp.zeros((), jnp.float32)

    L = jax.tree.leaves(params_stacked)[0].shape[0]
    windows = jnp.zeros((L,), jnp.int32)   # unused by ssm
    x, new_caches, _ = _scan_layers(body, x, params_stacked, windows, caches,
                                    policy)
    return x, new_caches


def _init_zamba(cfg: ModelConfig, key) -> tuple[dict, dict]:
    dtype = jnp.dtype(cfg.param_dtype)
    bag = ParamBag(key)
    bag.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              dtype, scale=1.0)
    bags = [_init_ssm_layer(bag.next_key(), cfg, dtype)
            for _ in range(cfg.num_layers)]
    bag.params["layers"], bag.logical["layers"] = stack_bags(bags)
    shared = bag.sub("shared")
    attn_mod.init_gqa(shared, cfg, dtype)
    init_norm(shared, "attn_norm", cfg.d_model, cfg.norm, dtype)
    init_mlp(shared, cfg.d_model, cfg.hybrid.shared_attn_d_ff, cfg.mlp_act,
             dtype)
    init_norm(shared, "mlp_norm", cfg.d_model, cfg.norm, dtype)
    init_norm(bag, "final_norm", cfg.d_model, cfg.norm, dtype)
    return bag.done()


def n_attn_sites(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.hybrid.attn_every


def _shared_attn_block(shared: dict, x: Array, positions: Array,
                       cfg: ModelConfig, cache, collect_kv: bool
                       ) -> tuple[Array, Optional[dict]]:
    h = apply_norm(shared["attn_norm"], x, cfg.norm)
    a, new_cache = attn_mod.gqa_attention(shared["attn"], h, positions, cfg,
                                          cache=cache, collect_kv=collect_kv)
    x = x + a
    h = apply_norm(shared["mlp_norm"], x, cfg.norm)
    return x + mlp(shared["mlp"], h, cfg.mlp_act), new_cache


def _zamba_backbone(params: dict, x: Array, positions: Array,
                    cfg: ModelConfig, caches: Optional[dict],
                    collect_kv: bool) -> tuple[Array, Optional[dict]]:
    """Grouped scan: ``attn_every`` ssm layers then the shared attn block,
    repeated ``n_sites`` times; trailing ssm layers close the stack.

    caches = {"ssm": stacked (L, ...), "attn": stacked (n_sites, ...)}.
    """
    every = cfg.hybrid.attn_every
    L = cfg.num_layers
    sites = n_attn_sites(cfg)
    body_n = sites * every
    shared = params["shared"]

    def split(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    def regroup(tree):
        return jax.tree.map(
            lambda a: a[:body_n].reshape((sites, every) + a.shape[1:]), tree)

    grouped = regroup(params["layers"])
    tail_params = split(params["layers"], body_n, L)
    g_ssm_caches = regroup(caches["ssm"]) if caches is not None else None
    attn_caches = caches["attn"] if caches is not None else None
    tail_caches = (split(caches["ssm"], body_n, L)
                   if caches is not None else None)

    def group_body(carry, xs):
        x = carry
        if caches is not None:
            gp, gssm, gattn = xs
        else:
            gp, = xs
            gssm = gattn = None
        x, new_ssm = _ssm_stack(gp, x, cfg, gssm, collect_kv,
                                cfg.remat_policy)
        x, new_attn = _shared_attn_block(shared, x, positions, cfg, gattn,
                                         collect_kv)
        return x, (new_ssm, new_attn)

    xs = ((grouped, g_ssm_caches, attn_caches) if caches is not None
          else (grouped,))
    x, (new_ssm_g, new_attn) = jax.lax.scan(group_body, x, xs)

    x, new_tail = _ssm_stack(tail_params, x, cfg, tail_caches, collect_kv,
                             cfg.remat_policy) if body_n < L else (x, None)

    new_caches = None
    if caches is not None or collect_kv:
        def flatten_groups(tree):
            return jax.tree.map(
                lambda a: a.reshape((body_n,) + a.shape[2:]), tree)
        new_body = flatten_groups(new_ssm_g)
        if new_tail is not None:
            new_ssm = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), new_body, new_tail)
        else:
            new_ssm = new_body
        new_caches = {"ssm": new_ssm, "attn": new_attn}
    return x, new_caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig, key) -> tuple[dict, dict]:
    if cfg.family in ("dense", "moe", "vlm"):
        return _init_decoder_lm(cfg, key)
    if cfg.family == "audio":
        return _init_encdec(cfg, key)
    if cfg.family == "ssm":
        return _init_mamba(cfg, key)
    if cfg.family == "hybrid":
        return _init_zamba(cfg, key)
    raise ValueError(f"unknown family {cfg.family!r}")


def _backbone_hidden(params: dict, batch: dict, cfg: ModelConfig,
                     mesh: Optional[Mesh], caches, collect_kv
                     ) -> tuple[Array, Optional[dict], Array, Optional[Array]]:
    """Family dispatch: returns (hidden(B,S,d) normed, caches, aux, labels)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe", "vlm"):
        x, positions, labels = _lm_inputs(params, batch, cfg)
        x, new_caches, aux = _decoder_backbone(params, x, positions, cfg,
                                               mesh, caches, collect_kv)
    elif cfg.family == "audio":
        tokens = batch["tokens"]
        labels = batch.get("labels")
        x = _embed(params, tokens, cfg)
        B, S = x.shape[:2]
        enc_out = (_whisper_encode(params, batch["frames"], cfg)
                   if "frames" in batch else None)
        x, new_caches = _whisper_decode_stack(
            params, x, _positions(B, S), cfg, enc_out, caches, collect_kv)
    elif cfg.family == "ssm":
        x = _embed(params, batch["tokens"], cfg)
        labels = batch.get("labels")
        ssm_caches = caches["ssm"] if caches is not None else None
        x, new_ssm = _ssm_stack(params["layers"], x, cfg, ssm_caches,
                                collect_kv, cfg.remat_policy)
        new_caches = ({"ssm": new_ssm}
                      if (new_ssm is not None) else None)
    elif cfg.family == "hybrid":
        x = _embed(params, batch["tokens"], cfg)
        labels = batch.get("labels")
        B, S = x.shape[:2]
        positions = batch.get("positions")
        if positions is None:
            positions = _positions(B, S)
        x, new_caches = _zamba_backbone(params, x, positions, cfg, caches,
                                        collect_kv)
    else:
        raise ValueError(cfg.family)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, new_caches, aux, labels


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            mesh: Optional[Mesh] = None) -> tuple[Array, Metrics]:
    """Training loss (next-token CE + MoE aux)."""
    x, _, aux, labels = _backbone_hidden(params, batch, cfg, mesh,
                                         caches=None, collect_kv=False)
    ce, n = _chunked_ce(params, x, labels, cfg)
    loss = ce
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss, Metrics(loss=loss, ce=ce, aux=aux, n_tokens=n)


def prefill_step(params: dict, batch: dict, cfg: ModelConfig,
                 mesh: Optional[Mesh] = None) -> tuple[Array, dict]:
    """Run the full prompt, return (last-position logits (B,V), kv cache)."""
    x, caches, _, _ = _backbone_hidden(params, batch, cfg, mesh,
                                       caches=None, collect_kv=True)
    logits = _head_logits(params, x[:, -1, :], cfg)
    return logits, caches


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                mesh: Optional[Mesh] = None) -> tuple[Array, dict]:
    """One-token decode.  batch = {"tokens": (B,1), "positions": (B,1)}."""
    tokens, positions = batch["tokens"], batch["positions"]
    x = _embed(params, tokens, cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        x, new_caches, _ = _decoder_backbone(params, x, positions, cfg, mesh,
                                             cache, collect_kv=False)
        # positions flow through _decoder_backbone via closure; decode uses
        # the caller-provided positions
    elif cfg.family == "audio":
        x, new_caches = _whisper_decode_stack(params, x, positions, cfg,
                                              None, cache, collect_kv=False)
    elif cfg.family == "ssm":
        x, new_ssm = _ssm_stack(params["layers"], x, cfg, cache["ssm"],
                                False, cfg.remat_policy)
        new_caches = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        x, new_caches = _zamba_backbone(params, x, positions, cfg, cache,
                                        collect_kv=False)
    else:
        raise ValueError(cfg.family)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _head_logits(params, x[:, -1, :], cfg)
    return logits, new_caches


def pad_cache_to(cache: dict, cfg: ModelConfig, max_seq: int) -> dict:
    """Pad the *sequence* axis of attention caches from prefill length S to
    ``max_seq`` so decode can append tokens at positions >= S.

    SSM states and whisper cross-attention K/V have no growable axis and are
    left untouched.
    """
    def pad(tree, axis):
        def f(a):
            if a.shape[axis] >= max_seq:
                return a
            widths = [(0, 0)] * a.ndim
            widths[axis] = (0, max_seq - a.shape[axis])
            return jnp.pad(a, widths)
        return jax.tree.map(f, tree)

    if cfg.family in ("dense", "moe", "vlm"):
        out = {}
        for k, v in cache.items():
            out[k] = pad(v, 2 if k == "layers" else 1)
        return out
    if cfg.family == "audio":
        return {"self": pad(cache["self"], 2),
                "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    if cfg.family == "ssm":
        return cache
    if cfg.family == "hybrid":
        return {"ssm": cache["ssm"], "attn": pad(cache["attn"], 2)}
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    """Zeroed decode cache for every family (shape source for input_specs)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        make = (attn_mod.init_mla_cache if cfg.mla is not None
                else attn_mod.init_gqa_cache)
        one = make(cfg, batch, max_seq, dtype)
        kinds = _layer_kinds(cfg)
        n_prefix = (0 if cfg.moe is None
                    else next((i for i, k in enumerate(kinds) if k == "moe"),
                              0))
        n_tail = cfg.num_layers - n_prefix
        cache = {f"layer{i}": jax.tree.map(jnp.copy, one)
                 for i in range(n_prefix)}
        cache["layers"] = jax.tree.map(
            lambda a: jnp.zeros((n_tail,) + a.shape, a.dtype), one)
        return cache
    if cfg.family == "audio":
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        self_c = attn_mod.init_gqa_cache(cfg, batch, max_seq, dtype)
        return {
            "self": jax.tree.map(
                lambda a: jnp.zeros((L,) + a.shape, a.dtype), self_c),
            "cross_k": jnp.zeros((L, batch, max_seq, h, hd), dtype),
            "cross_v": jnp.zeros((L, batch, max_seq, h, hd), dtype),
        }
    if cfg.family == "ssm":
        one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return {"ssm": jax.tree.map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)}
    if cfg.family == "hybrid":
        one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        attn_one = attn_mod.init_gqa_cache(cfg, batch, max_seq, dtype)
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype),
                one),
            "attn": jax.tree.map(
                lambda a: jnp.zeros((n_attn_sites(cfg),) + a.shape, a.dtype),
                attn_one),
        }
    raise ValueError(cfg.family)
