"""Shared layer primitives: norms, initializers, RoPE, activations, softcap.

Everything is a pure function over explicit parameter pytrees; parameter
initialization returns ``(params, logical_axes)`` twins so the distribution
layer (``repro.distributed.partition``) can map logical axis names to mesh
axes without the model code knowing about meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# parameter spec plumbing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamBag:
    """Collects (param, logical-axes) pairs during init.

    ``logical`` mirrors the params pytree with tuples of logical axis names
    (strings) per array dimension, e.g. ``("embed", "heads", "head_dim")``.
    """

    key: jax.Array
    params: dict = dataclasses.field(default_factory=dict)
    logical: dict = dataclasses.field(default_factory=dict)

    def next_key(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, name: str, shape: Sequence[int], axes: Sequence[str],
              dtype, scale: Optional[float] = None, mode: str = "normal"):
        """He/LeCun-style init: normal with std = scale or 1/sqrt(fan_in)."""
        shape = tuple(shape)
        if scale is None:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5
        if mode == "zeros":
            w = jnp.zeros(shape, dtype)
        else:
            w = (scale * jax.random.normal(self.next_key(), shape)).astype(dtype)
        self.params[name] = w
        self.logical[name] = tuple(axes)
        return w

    def ones(self, name: str, shape: Sequence[int], axes: Sequence[str], dtype):
        self.params[name] = jnp.ones(tuple(shape), dtype)
        self.logical[name] = tuple(axes)

    def zeros(self, name: str, shape: Sequence[int], axes: Sequence[str], dtype):
        self.params[name] = jnp.zeros(tuple(shape), dtype)
        self.logical[name] = tuple(axes)

    def sub(self, name: str) -> "ParamBag":
        child = ParamBag(self.next_key())
        self.params[name] = child.params
        self.logical[name] = child.logical
        return child

    def done(self) -> tuple[dict, dict]:
        return self.params, self.logical


def stack_bags(bags: list[tuple[dict, dict]], axis_name: str = "layers"
               ) -> tuple[dict, dict]:
    """Stack per-layer (params, logical) pairs along a new leading axis."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *[b[0] for b in bags])
    logical = jax.tree.map(lambda ax: (axis_name,) + tuple(ax),
                           bags[0][1], is_leaf=lambda x: isinstance(x, tuple))
    return params, logical


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(bag: ParamBag, name: str, dim: int, kind: str, dtype):
    sub = bag.sub(name)
    sub.ones("scale", (dim,), ("embed",), dtype)
    if kind == "layernorm":
        sub.zeros("bias", (dim,), ("embed",), dtype)


def apply_norm(p: dict, x: Array, kind: str, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        # gemma convention: scale as (1 + w); generic rmsnorm uses w directly.
        return (y * p["scale"].astype(jnp.float32)).astype(dt)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(dt)
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rotary_dim: Optional[int] = None
               ) -> Array:
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(x: Array, positions: Array, theta: float,
               rotary_frac: float = 1.0) -> Array:
    """Apply RoPE to ``x: (..., S, H, D)`` with ``positions: (..., S)``.

    ``rotary_frac < 1`` rotates only the first ``frac * D`` dims (StableLM's
    partial-rotary convention); the remainder passes through untouched.
    """
    d = x.shape[-1]
    rd = int(d * rotary_frac)
    rd -= rd % 2
    if rd == 0:
        return x
    xr, xp = x[..., :rd], x[..., rd:]
    inv = rope_freqs(d, theta, rd)                           # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, rd/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, rd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rd < d else out


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activate(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind in ("gelu_mlp", "gelu_exact"):
        return jax.nn.gelu(x, approximate=False)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


def causal_mask(q_pos: Array, k_pos: Array,
                window: Optional[int] = None) -> Array:
    """Boolean (..., Sq, Sk) mask: True = attend. Local window if given."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return ok


def cross_entropy(logits: Array, labels: Array,
                  ignore_id: int = -1) -> tuple[Array, Array]:
    """Mean token cross-entropy in f32. Returns (loss, n_valid)."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore_id
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, valid.sum()
