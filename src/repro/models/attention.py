"""Attention blocks: GQA (full / sliding-window local, logit softcap), MLA
(DeepSeek-V2 multi-head latent attention with absorbed decode), and
encoder-decoder cross attention.

Shapes: activations are ``(B, S, D)``; per-head tensors ``(B, S, H, hd)``.
Decode path updates KV caches with a one-hot blend (never a dynamic scatter)
so sequence-sharded caches lower cleanly under GSPMD.

Layer-pattern handling: the sliding window is passed as a *scalar* ``window``
(huge sentinel = global attention) so alternating local/global stacks (gemma2)
can be expressed as a scanned per-layer int array — one homogeneous scan body,
no per-layer Python branching.

Memory: ``impl="chunked"`` computes attention in query chunks via ``lax.scan``
so the (Sq, Sk) logits matrix is never materialized at once — required for
the 32k prefill cells (a full 32k x 32k f32 logits tensor is 4 GiB *per head
per sequence*).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBag, apply_rope

Array = jax.Array

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
GLOBAL_WINDOW = jnp.iinfo(jnp.int32).max // 2   # sentinel: "no window"


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_gqa(bag: ParamBag, cfg: ModelConfig, dtype, name: str = "attn"):
    sub = bag.sub(name)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    sub.dense("wq", (d, h, hd), ("embed", "heads", "head_dim"), dtype)
    sub.dense("wk", (d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype)
    sub.dense("wv", (d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype)
    sub.dense("wo", (h, hd, d), ("heads", "head_dim", "embed"), dtype)
    if cfg.qkv_bias:
        sub.zeros("bq", (h, hd), ("heads", "head_dim"), dtype)
        sub.zeros("bk", (kv, hd), ("kv_heads", "head_dim"), dtype)
        sub.zeros("bv", (kv, hd), ("kv_heads", "head_dim"), dtype)


def init_mla(bag: ParamBag, cfg: ModelConfig, dtype, name: str = "attn"):
    mla = cfg.mla
    sub = bag.sub(name)
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    sub.dense("w_dq", (d, mla.q_lora_rank), ("embed", "q_lora"), dtype)
    sub.ones("q_norm", (mla.q_lora_rank,), ("q_lora",), dtype)
    sub.dense("w_uq", (mla.q_lora_rank, h, dn + dr),
              ("q_lora", "heads", "head_dim"), dtype)
    sub.dense("w_dkv", (d, mla.kv_lora_rank + dr), ("embed", "kv_lora"), dtype)
    sub.ones("kv_norm", (mla.kv_lora_rank,), ("kv_lora",), dtype)
    sub.dense("w_uk", (mla.kv_lora_rank, h, dn),
              ("kv_lora", "heads", "head_dim"), dtype)
    sub.dense("w_uv", (mla.kv_lora_rank, h, dv),
              ("kv_lora", "heads", "head_dim"), dtype)
    sub.dense("wo", (h, dv, d), ("heads", "head_dim", "embed"), dtype)


def init_cross_attn(bag: ParamBag, cfg: ModelConfig, dtype, name: str = "xattn"):
    sub = bag.sub(name)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    sub.dense("wq", (d, h, hd), ("embed", "heads", "head_dim"), dtype)
    sub.dense("wk", (d, h, hd), ("embed", "heads", "head_dim"), dtype)
    sub.dense("wv", (d, h, hd), ("embed", "heads", "head_dim"), dtype)
    sub.dense("wo", (h, hd, d), ("heads", "head_dim", "embed"), dtype)


# ---------------------------------------------------------------------------
# core attend
# ---------------------------------------------------------------------------

def _attend_full(q: Array, k: Array, v: Array, mask: Optional[Array],
                 scale: float, cap: Optional[float]) -> Array:
    """q: (B,Sq,H,hd)  k/v: (B,Sk,H,hd|hv)  mask: (B,Sq,Sk) bool or None."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    if mask is not None:
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhv->bqhv", probs, v)


def _causal_window_mask(qpos: Array, kpos: Array, window) -> Array:
    """(B,Sq,Sk) bool. ``window`` may be a traced int scalar (scan-friendly)."""
    ok = kpos[:, None, :] <= qpos[:, :, None]
    ok &= (qpos[:, :, None] - kpos[:, None, :]) < window
    return ok


def _attend_online(q: Array, k: Array, v: Array, qpos: Array, kpos: Array,
                   window, scale: float, cap: Optional[float],
                   q_chunk: int, kv_chunk: int, causal: bool) -> Array:
    """Flash-style online-softmax attention at the HLO level.

    Double scan: query chunks outer, KV chunks inner with running
    (max, denominator, accumulator) statistics — the (Sq, Sk) score matrix
    never exists; every intermediate is a (B, Cq, H, Ck) tile sized to fit
    VMEM on the TPU target.  Numerically identical to full softmax (exact
    online rescaling, not an approximation).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    hv = v.shape[-1]
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, Sk, q_chunk,
                                                      kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    qpc = jnp.moveaxis(qpos.reshape(B, nq, q_chunk), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, kv_chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, kv_chunk, H, hv), 1, 0)
    kpc = jnp.moveaxis(kpos.reshape(B, nk, kv_chunk), 1, 0)

    def q_step(_, xs):
        qi, qpi = xs                                     # (B,Cq,H,hd),(B,Cq)

        # checkpointed: the VJP of the kv scan then RECOMPUTES the (Cq, Ck)
        # probability tile from (q, k) per step instead of stashing all
        # nq*nk tiles (= the full S^2 matrix) as residuals — this is the
        # flash-attention backward expressed at the HLO level.
        @jax.checkpoint
        def kv_step(carry, kxs):
            m, l, acc = carry
            kj, vj, kpj = kxs
            s = jnp.einsum("bqhd,bkhd->bqhk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if cap is not None:
                s = cap * jnp.tanh(s / cap)
            if causal:
                ok = _causal_window_mask(qpi, kpj, window)    # (B,Cq,Ck)
                s = jnp.where(ok[:, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))                 # (B,Cq,H)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] \
                + jnp.einsum("bqhk,bkhv->bqhv", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, H, hv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_step, None, (qc, qpc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hv)


def attend(q: Array, k: Array, v: Array, qpos: Array, kpos: Array, *,
           window, scale: float, cap: Optional[float],
           impl: str = "full", q_chunk: int = 1024,
           causal: bool = True) -> Array:
    """Masked attention with selectable implementation.

    ``full``    — materialize the (Sq, Sk) score matrix (baseline);
    ``chunked`` — query-chunked full softmax (peak-memory relief);
    ``online``  — flash-style online softmax (no S^2 buffer at all);
    ``auto``    — chunked when Sq > 8192 else full.
    ``window``: int scalar (or traced scalar); GLOBAL_WINDOW for global.
    ``causal=False`` (encoder self-attention) attends everywhere.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if impl == "auto":
        impl = "chunked" if (Sq > 8192 and Sq % q_chunk == 0) else "full"
    if impl == "online":
        qc = min(q_chunk, Sq)
        kvc = min(q_chunk, Sk)
        if Sq % qc == 0 and Sk % kvc == 0 and Sq > 1:
            return _attend_online(q, k, v, qpos, kpos, window, scale, cap,
                                  qc, kvc, causal)
        impl = "full"
    if impl != "chunked" or Sq <= q_chunk:
        mask = _causal_window_mask(qpos, kpos, window) if causal else None
        return _attend_full(q, k, v, mask, scale, cap)

    assert Sq % q_chunk == 0, (Sq, q_chunk)
    nc = Sq // q_chunk
    qc = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, hd), 1, 0)
    pc = jnp.moveaxis(qpos.reshape(B, nc, q_chunk), 1, 0)

    def one(_, xs):
        qi, qpi = xs
        mask = _causal_window_mask(qpi, kpos, window) if causal else None
        return None, _attend_full(qi, k, v, mask, scale, cap)

    _, outs = jax.lax.scan(one, None, (qc, pc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, -1)


def _repeat_kv(x: Array, h: int) -> Array:
    kv = x.shape[2]
    if kv == h:
        return x
    return jnp.repeat(x, h // kv, axis=2)


def _blend(cache: Array, new: Array, pos: Array,
           impl: str = "blend") -> Array:
    """Write ``new: (B,1,...)`` into ``cache: (B,S,...)`` at positions ``pos:
    (B,)``.

    ``blend`` — one-hot convex blend: reads AND rewrites the whole cache
    every step (scatter-free, safe under sequence sharding — the long_500k
    layout).  ``dus`` — per-row dynamic_update_slice: writes one token slot
    (the decode-bandwidth fix; requires the sequence axis unsharded, i.e.
    the batch-sharded decode_32k layout).
    """
    if impl == "dus":
        def upd(c, n, p):
            return jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), p, axis=0)
        return jax.vmap(upd)(cache, new, pos)
    S = cache.shape[1]
    oh = jax.nn.one_hot(pos, S, dtype=cache.dtype)        # (B, S)
    oh = oh.reshape(oh.shape + (1,) * (cache.ndim - 2))
    return cache * (1 - oh) + oh * new.astype(cache.dtype)


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------

def gqa_attention(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                  window=GLOBAL_WINDOW, cache: Optional[dict] = None,
                  collect_kv: bool = False, causal: bool = True,
                  ) -> tuple[Array, Optional[dict]]:
    """GQA self-attention.

    Train: ``x: (B,S,D)``, ``positions: (B,S)``, ``cache=None``.
    Prefill: additionally ``collect_kv=True`` -> returns {"k","v"} as the
    decode cache (kv-head layout, pre-repeat).
    Decode: ``x: (B,1,D)``, ``positions: (B,1)`` = current index,
    ``cache = {"k": (B,Smax,Kv,hd), "v": ...}``; returns updated cache.
    ``window`` is a (possibly traced) int scalar; GLOBAL_WINDOW = global attn.
    """
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    scale = hd ** -0.5
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)

    if cache is None:
        ctx = attend(q, _repeat_kv(k, h), _repeat_kv(v, h), positions,
                     positions, window=window, scale=scale,
                     cap=cfg.attn_logit_softcap, impl=cfg.attn_impl,
                     q_chunk=cfg.q_chunk, causal=causal)
        new_cache = {"k": k, "v": v} if collect_kv else None
    else:
        pos = positions[:, 0]                              # (B,)
        ck = _blend(cache["k"], k, pos, cfg.cache_update)
        cv = _blend(cache["v"], v, pos, cfg.cache_update)
        S = ck.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(S, dtype=positions.dtype)[None, :],
                                (x.shape[0], S))
        ctx = attend(q, _repeat_kv(ck, h), _repeat_kv(cv, h), positions, kpos,
                     window=window, scale=scale, cap=cfg.attn_logit_softcap,
                     impl="full")
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _rmsn(x: Array, w: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def mla_attention(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                  window=GLOBAL_WINDOW, cache: Optional[dict] = None,
                  collect_kv: bool = False, causal: bool = True,
                  ) -> tuple[Array, Optional[dict]]:
    """Multi-head latent attention.

    Cache stores only the latents: ``{"ckv": (B,Smax,kv_lora), "krope":
    (B,Smax,dr)}`` — the MLA memory win.  Decode uses the *absorbed* form
    (q folded through W_uk, context combined in latent space) so per-head
    K/V are never materialized over the cache length.
    """
    mla = cfg.mla
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    scale = (dn + dr) ** -0.5

    cq = _rmsn(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    qfull = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = qfull[..., :dn], qfull[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, krope = ckv_full[..., :mla.kv_lora_rank], ckv_full[..., mla.kv_lora_rank:]
    ckv = _rmsn(ckv, p["kv_norm"])
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # full sequence: materialize per-head K/V (train / prefill)
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                      k_nope.shape[:3] + (dr,))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        ctx = attend(q, k, v, positions, positions, window=window,
                     scale=scale, cap=cfg.attn_logit_softcap,
                     impl=cfg.attn_impl, q_chunk=cfg.q_chunk)
        out = jnp.einsum("bshv,hvd->bsd", ctx, p["wo"])
        new_cache = {"ckv": ckv, "krope": krope} if collect_kv else None
        return out, new_cache

    # --- absorbed decode ---
    pos = positions[:, 0]
    c_ckv = _blend(cache["ckv"], ckv, pos, cfg.cache_update)   # (B,S,r)
    c_kr = _blend(cache["krope"], krope, pos, cfg.cache_update)  # (B,S,dr)
    S = c_ckv.shape[1]
    # fold q through W_uk: (B,1,H,dn) x (r,H,dn) -> (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, c_ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, c_kr,
                           preferred_element_type=jnp.float32)) * scale
    kpos = jnp.arange(S, dtype=positions.dtype)[None, :]
    mask = kpos[:, None, :] <= pos[:, None, None]
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(c_ckv.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_ckv)     # (B,1,H,r)
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["w_uv"])   # (B,1,H,dv)
    out = jnp.einsum("bshv,hvd->bsd", ctx, p["wo"])
    return out, {"ckv": c_ckv, "krope": c_kr}


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(p: dict, x: Array, enc_kv: tuple[Array, Array],
                    cfg: ModelConfig) -> Array:
    """x: (B,S,D); enc_kv: precomputed (K, V) each (B,T,H,hd)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    B, Sq = x.shape[:2]
    T = enc_kv[0].shape[1]
    qpos = jnp.zeros((B, Sq), jnp.int32)
    kpos = jnp.zeros((B, T), jnp.int32)
    ctx = attend(q, enc_kv[0], enc_kv[1], qpos, kpos, window=GLOBAL_WINDOW,
                 scale=hd ** -0.5, cap=None, causal=False,
                 impl=cfg.attn_impl, q_chunk=cfg.q_chunk)
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


def encode_cross_kv(p: dict, enc_out: Array) -> tuple[Array, Array]:
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return k, v


def init_gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, max_seq, kv, hd), dtype),
            "v": jnp.zeros((batch, max_seq, kv, hd), dtype)}


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    mla = cfg.mla
    return {"ckv": jnp.zeros((batch, max_seq, mla.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, mla.qk_rope_head_dim), dtype)}
