"""Expert-parallel mixture-of-experts block.

Design (see DESIGN.md §MoE): experts are sharded over the ``model`` mesh axis
(EP) and additionally over ``data`` (FSDP); tokens are sharded over the batch
axes and *replicated* across ``model``.  Each device:

  1. computes the router for its token block (cheap, duplicated across EP),
  2. builds a fixed-capacity ``(E_local, C, D)`` buffer holding exactly the
     tokens routed to *its local experts* (capacity-drop, scatter with
     ``mode='drop'`` so out-of-capacity assignments vanish),
  3. runs the gated expert MLP as one batched einsum over local experts,
  4. scatter-adds gated results back to token positions and ``psum``s over
     the EP axis to combine contributions from all expert owners.

This avoids the GShard one-hot dispatch einsum (whose FLOPs/memory rival the
expert compute itself) and keeps every gather/scatter device-local inside
``shard_map``.  An all-to-all dispatch variant is the documented hillclimb
alternative (§Perf).

Memory note: the buffer-side *gather* (``x[tok_for_slot]``) and buffer-side
*scatter-add* formulations are chosen so the ``(T, k, D)`` per-assignment
tensor is never materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import ParamBag, activate

Array = jax.Array


def init_moe(bag: ParamBag, cfg: ModelConfig, dtype, name: str = "moe"):
    moe = cfg.moe
    d = cfg.d_model
    sub = bag.sub(name)
    sub.dense("w_router", (d, moe.num_experts), ("embed", "experts_dim"),
              jnp.float32)
    sub.dense("w_gate", (moe.num_experts, d, moe.d_ff_expert),
              ("experts", "embed", "mlp"), dtype)
    sub.dense("w_up", (moe.num_experts, d, moe.d_ff_expert),
              ("experts", "embed", "mlp"), dtype)
    sub.dense("w_down", (moe.num_experts, moe.d_ff_expert, d),
              ("experts", "mlp", "embed"), dtype)


def _batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _expert_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _local_moe(x: Array, wr: Array, wg: Array, wu: Array, wd: Array,
               *, moe: MoEConfig, act: str, ep_axis: Optional[str],
               fsdp_axes: tuple[str, ...], renorm: bool) -> tuple[Array, Array]:
    """shard_map body. x: (B_loc, S, D) tokens local; experts local on
    ``ep_axis``; expert weights additionally sharded over ``fsdp_axes`` on
    their d_model dim (all-gathered here, FSDP-style)."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    E = moe.num_experts
    k = moe.top_k

    for ax in fsdp_axes:
        wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
    E_loc = wg.shape[0]

    # --- router (f32) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                 # (T,k)
    if renorm:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- local-expert selection ---
    if ep_axis is not None:
        my_lo = jax.lax.axis_index(ep_axis) * E_loc
    else:
        my_lo = 0
    flat_e = eidx.reshape(-1)                             # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_gate = gates.reshape(-1)
    local_e = flat_e - my_lo
    mine = (local_e >= 0) & (local_e < E_loc)
    key = jnp.where(mine, local_e, E_loc)                 # E_loc = "not mine"
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    counts = jnp.bincount(key, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * k) - starts[sorted_e]

    C = max(int(moe.capacity_factor * T * k / E) , 8)
    keep = (sorted_e < E_loc) & (slot < C)
    # token id per buffer slot; dropped slots point out-of-bounds (=T)
    e_idx = jnp.where(keep, sorted_e, E_loc)
    s_idx = jnp.where(keep, slot, C)
    tok_for_slot = jnp.full((E_loc + 1, C + 1), T, jnp.int32)
    tok_for_slot = tok_for_slot.at[e_idx, s_idx].set(
        flat_tok[order].astype(jnp.int32), mode="drop")
    gate_for_slot = jnp.zeros((E_loc + 1, C + 1), jnp.float32)
    gate_for_slot = gate_for_slot.at[e_idx, s_idx].set(
        flat_gate[order], mode="drop")
    tok_for_slot = tok_for_slot[:E_loc, :C]
    gate_for_slot = gate_for_slot[:E_loc, :C]

    # --- gather -> batched expert MLP -> scatter-add ---
    buf = jnp.take(xt, tok_for_slot.reshape(-1), axis=0, mode="fill",
                   fill_value=0).reshape(E_loc, C, D)
    h_g = jnp.einsum("ecd,edf->ecf", buf, wg)
    h_u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = activate(h_g, act) * h_u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
    out_buf = out_buf * gate_for_slot[..., None].astype(out_buf.dtype)

    y = jnp.zeros((T + 1, D), out_buf.dtype)
    y = y.at[tok_for_slot.reshape(-1)].add(out_buf.reshape(-1, D), mode="drop")
    y = y[:T]
    if ep_axis is not None:
        y = jax.lax.psum(y, ep_axis)

    # --- aux load-balance loss (Switch style), averaged globally ---
    frac_tokens = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    frac_probs = probs.mean(0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    all_axes = tuple(a for a in (fsdp_axes + ((ep_axis,) if ep_axis else ()))
                     if a is not None)
    if all_axes:
        n = functools.reduce(lambda a, b: a * b,
                             [jax.lax.psum(1, ax) for ax in all_axes], 1)
        aux = jax.lax.psum(aux, all_axes) / n
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_block(p: dict, x: Array, cfg: ModelConfig, mesh: Optional[Mesh],
              renorm: bool = True) -> tuple[Array, Array]:
    """Apply the EP MoE block. Returns (y, aux_loss)."""
    moe = cfg.moe
    if mesh is None:
        # single-device path (smoke tests without a mesh)
        y, aux = _local_moe(x, p["w_router"], p["w_gate"], p["w_up"],
                            p["w_down"], moe=moe, act=cfg.mlp_act,
                            ep_axis=None, fsdp_axes=(), renorm=renorm)
        return y, aux

    baxes = _batch_axes(mesh)
    ep = _expert_axis(mesh)
    fsdp = tuple(a for a in baxes if a == "data")
    body = functools.partial(_local_moe, moe=moe, act=cfg.mlp_act,
                             ep_axis=ep, fsdp_axes=fsdp, renorm=renorm)
    wspec_gu = P(ep, "data" if "data" in mesh.axis_names else None, None)
    wspec_d = P(ep, None, "data" if "data" in mesh.axis_names else None)
    y, aux = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(baxes or None, None, None),   # x: batch-sharded tokens
                  P(None, None),                  # router
                  wspec_gu, wspec_gu, wspec_d),
        out_specs=(P(baxes or None, None, None), P()),
        check_vma=False,
    )(x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
