"""Mamba2 (SSD — state-space duality) block, chunked matmul form.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060):
within-chunk quadratic ("attention-like") term + inter-chunk state recurrence
via ``lax.scan``.  The recurrence state ``(B, H, P, N)`` is the decode cache —
O(1) per generated token, which is why the ``long_500k`` cell is assigned to
the SSM/hybrid architectures.

SSD internals run in float32 (cumulative-sum exponentials); projections stay
in the model dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBag

Array = jax.Array


def init_ssm(bag: ParamBag, cfg: ModelConfig, dtype, name: str = "ssm"):
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state
    sub = bag.sub(name)
    sub.dense("wz", (d, d_in), ("embed", "ssm_inner"), dtype)
    sub.dense("wx", (d, d_in), ("embed", "ssm_inner"), dtype)
    sub.dense("wB", (d, G * N), ("embed", "ssm_state"), dtype)
    sub.dense("wC", (d, G * N), ("embed", "ssm_state"), dtype)
    sub.dense("wdt", (d, H), ("embed", "ssm_heads"), dtype)
    sub.zeros("dt_bias", (H,), ("ssm_heads",), jnp.float32)
    # A_log init ~ log(uniform[1,16]) (mamba2 default)
    sub.params["A_log"] = jnp.log(
        1.0 + 15.0 * jax.random.uniform(sub.next_key(), (H,))).astype(jnp.float32)
    sub.logical["A_log"] = ("ssm_heads",)
    sub.ones("D_skip", (H,), ("ssm_heads",), jnp.float32)
    conv_dim = d_in + 2 * G * N
    sub.dense("conv_w", (ssm.d_conv, conv_dim), ("conv_k", "ssm_inner"), dtype,
              scale=ssm.d_conv ** -0.5)
    sub.zeros("conv_b", (conv_dim,), ("ssm_inner",), dtype)
    sub.ones("out_norm", (d_in,), ("ssm_inner",), dtype)
    sub.dense("w_out", (d_in, d), ("ssm_inner", "embed"), dtype)


def _causal_depthwise_conv(x: Array, w: Array, b: Array) -> Array:
    """x: (B,S,C); w: (K,C) depthwise causal conv + silu."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.promote_types(x.dtype, w.dtype))
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _gated_rmsnorm(y: Array, z: Array, w: Array, eps: float = 1e-6) -> Array:
    """Mamba2 output norm: RMSNorm(y * silu(z))."""
    y32 = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), -1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32))


def _ssd_chunked(xd: Array, a: Array, Bm: Array, Cm: Array, L: int,
                 h0: Optional[Array] = None) -> tuple[Array, Array]:
    """Chunked SSD scan.

    xd: (B,S,H,P)  — dt-premultiplied inputs (f32)
    a:  (B,S,H)    — dt * A  (negative, f32)
    Bm/Cm: (B,S,G,N); heads map to groups by ``H // G`` blocks.
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, Pd = xd.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    NC = S // L
    xc = xd.reshape(Bsz, NC, L, H, Pd)
    ac = a.reshape(Bsz, NC, L, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, NC, L, G, N), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(Bsz, NC, L, G, N), rep, axis=3)

    acs = jnp.cumsum(ac, axis=2)                                 # inclusive
    # --- intra-chunk quadratic term ---
    seg = acs[:, :, :, None, :] - acs[:, :, None, :, :]          # (B,NC,l,s,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: the masked (future) entries have seg > 0 and exp(seg)
    # overflows; an inf in the untaken where-branch turns the softmax VJP
    # into 0 * inf = NaN (fwd was fine, grads were not).
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    Lmat = jnp.exp(seg)
    CB = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc)
    y_diag = jnp.einsum("bclsh,bclsh,bcshp->bclhp", CB, Lmat, xc)

    # --- chunk states and inter-chunk recurrence ---
    decay_states = jnp.exp(acs[:, :, -1:, :] - acs)              # (B,NC,L,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchnp", Bc, decay_states, xc)
    chunk_total = jnp.exp(acs[:, :, -1, :])                      # (B,NC,H)

    def step(h, inp):
        st, tot = inp                                            # (B,H,N,P),(B,H)
        h_prev = h
        h = h * tot[:, :, None, None] + st
        return h, h_prev

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, Pd), xd.dtype)
    hT, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                        # (B,NC,H,N,P)

    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp", Cc, h_prevs, jnp.exp(acs))
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    # state layout (B,H,P,N) for the decode cache
    return y, jnp.swapaxes(hT, -1, -2)


def ssm_block(p: dict, x: Array, cfg: ModelConfig,
              cache: Optional[dict] = None, collect_state: bool = False
              ) -> tuple[Array, Optional[dict]]:
    """Mamba2 block.

    Train: ``cache=None`` -> full chunked SSD (no state returned).
    Prefill: ``cache=None, collect_state=True`` -> returns the final SSD
    state + conv window as the decode cache.
    Decode: ``cache={"h": (B,H,P,N), "conv": (B,K-1,conv_dim)}``.
    """
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    G, N, Pd = ssm.n_groups, ssm.d_state, ssm.head_dim
    Bsz, S, _ = x.shape

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])
    Braw = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Craw = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    xBC = jnp.concatenate([xin, Braw, Craw], axis=-1)
    if cache is None:
        xBC_raw = xBC
        xBC = _causal_depthwise_conv(xBC, p["conv_w"], p["conv_b"]).astype(x.dtype)
        K = ssm.d_conv
        if collect_state:
            padded = jnp.pad(xBC_raw, ((0, 0), (max(0, K - 1 - S), 0), (0, 0)))
            new_conv = padded[:, -(K - 1):, :]
        else:
            new_conv = None
    else:
        window = jnp.concatenate([cache["conv"], xBC], axis=1)   # (B,K,conv)
        out = (window * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
        xBC = jax.nn.silu(out)[:, None, :].astype(x.dtype)
        new_conv = window[:, 1:, :]

    xin = xBC[..., :d_in]
    Bm = xBC[..., d_in:d_in + G * N].reshape(Bsz, S, G, N).astype(jnp.float32)
    Cm = xBC[..., d_in + G * N:].reshape(Bsz, S, G, N).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    xh = xin.reshape(Bsz, S, H, Pd).astype(jnp.float32)
    xd = xh * dt[..., None]
    a = dt * A

    if cache is None:
        L = min(ssm.chunk_size, S)
        pad = (-S) % L
        if pad:
            # zero-pad to a chunk multiple: xd/B/C = 0 adds nothing to the
            # state and a = 0 (decay exp(0)=1) preserves it, so the final
            # state is exact despite padding.
            xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, hT = _ssd_chunked(xd, a, Bm, Cm, L)
        y = y[:, :S]
        new_cache = ({"h": hT.astype(jnp.float32), "conv": new_conv}
                     if collect_state else None)
    else:
        h = cache["h"].astype(jnp.float32)                      # (B,H,P,N)
        rep = H // G
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1)                  # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
        h = (h * jnp.exp(a[:, 0])[:, :, None, None]
             + xd[:, 0][..., None] * Bh[:, :, None, :])         # (B,H,P,N)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch)[:, None]         # (B,1,H,P)
        hT = h
        new_cache = {"h": hT.astype(cache["h"].dtype), "conv": new_conv}

    y = y + xh * p["D_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, d_in)
    y = _gated_rmsnorm(y, z, p["out_norm"]).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.head_dim
    conv_dim = d_in + 2 * ssm.n_groups * ssm.d_state
    return {
        "h": jnp.zeros((batch, H, ssm.head_dim, ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
    }
