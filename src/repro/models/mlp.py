"""Feed-forward blocks: gated (SwiGLU / GeGLU) and plain 2-layer MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBag, activate

Array = jax.Array


def init_mlp(bag: ParamBag, d_model: int, d_ff: int, act: str, dtype,
             name: str = "mlp"):
    sub = bag.sub(name)
    gated = act in ("silu", "gelu")
    if gated:
        sub.dense("w_gate", (d_model, d_ff), ("embed", "mlp"), dtype)
        sub.dense("w_up", (d_model, d_ff), ("embed", "mlp"), dtype)
    else:
        sub.dense("w_up", (d_model, d_ff), ("embed", "mlp"), dtype)
    sub.dense("w_down", (d_ff, d_model), ("mlp", "embed"), dtype)


def mlp(p: dict, x: Array, act: str) -> Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = activate(gate, act) * up
    else:
        h = activate(up, act)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
