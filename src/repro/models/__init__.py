"""Model zoo: layers, attention (GQA/MLA/cross), MoE, SSD, and the per-family
assembly in ``repro.models.model``."""
from repro.models.model import (decode_step, init_cache, init_model, loss_fn,
                                prefill_step)

__all__ = ["decode_step", "init_cache", "init_model", "loss_fn",
           "prefill_step"]
