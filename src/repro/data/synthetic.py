"""Synthetic data generators (pure functions of (seed, step))."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

Array = jax.Array


class LMBatchSpec(NamedTuple):
    batch: int
    seq_len: int
    vocab: int
    num_image_tokens: int = 0     # vlm stub
    num_frames: int = 0           # audio stub
    d_model: int = 0


def spec_for(cfg: ModelConfig, shape: ShapeConfig,
             batch_override: Optional[int] = None) -> LMBatchSpec:
    B = batch_override or shape.global_batch
    S = shape.seq_len
    img = audio = 0
    if cfg.family == "vlm":
        img = cfg.vlm.num_image_tokens
        S = S - img                       # text tokens fill the remainder
    if cfg.family == "audio":
        audio = shape.seq_len
    return LMBatchSpec(B, S, cfg.vocab_size, img, audio, cfg.d_model)


def lm_batch(spec: LMBatchSpec, seed: int, step: int) -> dict:
    """One deterministic LM training batch.

    Tokens follow a repeating-ngram distribution (so tiny models can actually
    learn structure in convergence tests, unlike iid-uniform tokens).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.randint(k1, (spec.batch, 8), 0, spec.vocab)
    reps = -(-(spec.seq_len + 1) // 8)
    stream = jnp.tile(base, (1, reps))[:, :spec.seq_len + 1]
    noise = jax.random.randint(k2, stream.shape, 0, spec.vocab)
    flip = jax.random.bernoulli(k3, 0.05, stream.shape)
    stream = jnp.where(flip, noise, stream)
    batch = {"tokens": stream[:, :-1], "labels": stream[:, 1:]}
    if spec.num_image_tokens:
        batch["img_embeds"] = jax.random.normal(
            k3, (spec.batch, spec.num_image_tokens, spec.d_model),
            jnp.float32) * 0.02
    if spec.num_frames:
        batch["frames"] = jax.random.normal(
            k3, (spec.batch, spec.num_frames, spec.d_model),
            jnp.float32) * 0.02
    return batch


def host_slice(batch: dict, host_id: int, num_hosts: int) -> dict:
    """Per-host shard of a global batch (multi-host input pipeline)."""
    def f(x):
        per = x.shape[0] // num_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(f, batch)


# ---------------------------------------------------------------------------
# RSL pairs (the paper's application, §6.3)
# ---------------------------------------------------------------------------

class RSLDataset(NamedTuple):
    X: Array          # (N, d1) domain-1 samples (MNIST-like)
    V: Array          # (N, d2) domain-2 samples (USPS-like)
    y: Array          # (N,) ±1 similarity labels
    Wu: Array         # planted metric factors: W* = Wu @ Wv (never dense)
    Wv: Array

    @property
    def W_true(self) -> Array:
        """Dense planted metric — small-dim diagnostics only."""
        return self.Wu @ self.Wv

    def true_spectrum(self) -> Array:
        """Singular values of W* from its factors (no dense SVD)."""
        Ru = jnp.linalg.qr(self.Wu)[1]
        Rv = jnp.linalg.qr(self.Wv.T)[1]
        return jnp.linalg.svd(Ru @ Rv.T, compute_uv=False)


def make_rsl_dataset(key, n: int, d1: int, d2: int, rank: int,
                     noise: float = 0.1) -> RSLDataset:
    """Plant a rank-``rank`` metric W* = Wu Wv; label pairs by
    sign(xᵀW*v + noise).  Mimics the paper's MNIST-vs-USPS setup (two
    domains of different dimension, similarity decided by a low-rank
    bilinear form).  Scores go through the factors, so the 1e8-entry
    metric of the end-to-end driver is never materialized.
    """
    kx, kv, kw1, kw2, kn = jax.random.split(key, 5)
    X = jax.random.normal(kx, (n, d1)) / (d1 ** 0.25)
    V = jax.random.normal(kv, (n, d2)) / (d2 ** 0.25)
    scale = (d1 * d2) ** -0.25
    Wu = jax.random.normal(kw1, (d1, rank)) * scale
    Wv = jax.random.normal(kw2, (rank, d2))
    score = jnp.einsum("nr,nr->n", X @ Wu, (V @ Wv.T))
    score = score + noise * jnp.std(score) * jax.random.normal(kn, (n,))
    return RSLDataset(X, V, jnp.sign(score), Wu, Wv)


def rsl_batch(ds: RSLDataset, seed: int, step: int, batch: int) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    idx = jax.random.randint(key, (batch,), 0, ds.X.shape[0])
    return {"x": ds.X[idx], "v": ds.V[idx], "y": ds.y[idx]}


# ---------------------------------------------------------------------------
# Matrix-free problem generators (sparse / Kronecker operands for the
# fsvd_blocked / operator-algebra test-and-benchmark surface)
# ---------------------------------------------------------------------------

class MatrixFreeProblem(NamedTuple):
    op: "object"          # repro.core.operators Operator — the solver input
    dense: Array          # materialized reference (small dims / oracles only)


def make_sparse_problem(key, m: int, n: int, *, density: float = 0.02,
                        rank: Optional[int] = None,
                        backend: str = "xla") -> MatrixFreeProblem:
    """Random sparse operand with a dense oracle.

    ``rank=None``: iid Gaussian values on a Bernoulli(density) mask
    (full-rank w.p. 1).  ``rank=r``: product of two sparse factors
    ``S₁ (m, r) @ S₂ (r, n)`` — exactly rank ≤ r and still sparse for small
    density, the matrix-free analogue of :func:`conftest.make_lowrank`.
    """
    from repro.core.operators import SparseOp
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if rank is None:
        mask = jax.random.bernoulli(k1, density, (m, n))
        dense = jnp.where(mask, jax.random.normal(k2, (m, n)), 0.0)
    else:
        d = density ** 0.5
        S1 = jnp.where(jax.random.bernoulli(k1, d, (m, rank)),
                       jax.random.normal(k2, (m, rank)), 0.0)
        S2 = jnp.where(jax.random.bernoulli(k3, d, (rank, n)),
                       jax.random.normal(k4, (rank, n)), 0.0)
        dense = S1 @ S2
    return MatrixFreeProblem(SparseOp.fromdense(dense, backend=backend),
                             dense)


def make_kron_problem(key, ma: int, na: int, mb: int, nb: int
                      ) -> MatrixFreeProblem:
    """Kronecker operand ``A ⊗ B`` with its dense oracle.

    The product's singular values are the outer product of the factors'
    spectra — ground truth comes from two small SVDs even when the product
    itself would be huge.
    """
    from repro.core.operators import DenseOp, KroneckerOp
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (ma, na)) / (ma * na) ** 0.25
    B = jax.random.normal(k2, (mb, nb)) / (mb * nb) ** 0.25
    return MatrixFreeProblem(KroneckerOp(DenseOp(A), DenseOp(B)),
                             jnp.kron(A, B))
