"""Deterministic synthetic data pipelines.

Two generators:
  * LM token streams (per-arch vocab; optional VLM patch embeddings / audio
    frame embeddings per the family's stub frontend),
  * RSL similarity pairs (the paper's application; MNIST/USPS-like synthetic
    domains with a planted low-rank ground-truth metric).

Determinism & sharding: batches are a pure function of (seed, step), so any
host can regenerate any step — restart-safe without data-loader checkpoints,
and each host materializes only its shard (``host_slice``).
"""
from repro.data.synthetic import (LMBatchSpec, lm_batch, make_rsl_dataset,
                                  rsl_batch)

__all__ = ["LMBatchSpec", "lm_batch", "make_rsl_dataset", "rsl_batch"]
