"""Quickstart: the paper's three algorithms in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fsvd, numerical_rank, rsvd

# A "huge" low-rank matrix (the paper's synthetic setup, CPU-sized here):
# A = M @ N with Gaussian factors -> numerical rank exactly 50.
key = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(key)
A = jax.random.normal(k1, (4000, 50)) @ jax.random.normal(k2, (50, 2000))

# --- Algorithm 3: numerical rank, no user parameters ---
rank = numerical_rank(A)
print(f"numerical rank: {int(rank.rank)} "
      f"(GK terminated after {int(rank.gk_iterations)} iterations)")

# --- Algorithm 2: accurate partial SVD (top 10 triplets) ---
out = fsvd(A, r=10, k=120, host_loop=True)
s_true = jnp.linalg.svd(A, compute_uv=False)[:10]
print("F-SVD sigma:", [f"{x:.1f}" for x in out.s])
print("max |sigma - svd|:", float(jnp.max(jnp.abs(out.s - s_true))))

# --- the R-SVD baseline with the default oversampling (p=10) ---
rs = rsvd(A, 10, p=10)
print("R-SVD(default) max err:", float(jnp.max(jnp.abs(rs.s - s_true))))

# --- F-SVD through the Pallas kernels (TPU path; interpret on CPU) ---
from repro.core.linop import from_dense
out_k = fsvd(from_dense(A, use_kernels=True), r=4, k=60, host_loop=True)
print("kernel-path sigma:", [f"{x:.1f}" for x in out_k.s])
