"""Quickstart: the paper's three algorithms through the `repro.api` facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import DenseOp, SVDSpec, estimate_rank, factorize

# A "huge" low-rank matrix (the paper's synthetic setup, CPU-sized here):
# A = M @ N with Gaussian factors -> numerical rank exactly 50.
key = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(key)
A = jax.random.normal(k1, (4000, 50)) @ jax.random.normal(k2, (50, 2000))

# --- Algorithm 3: numerical rank, no user parameters ---
est = estimate_rank(A, key=key)
print(f"numerical rank: {int(est.rank)} "
      f"(GK terminated after {int(est.iterations)} iterations)")

# --- Algorithm 2: accurate partial SVD (top 10 triplets) ---
spec = SVDSpec(method="fsvd", rank=10, max_iters=120, host_loop=True)
out = factorize(A, spec, key=key)
s_true = jnp.linalg.svd(A, compute_uv=False)[:10]
print("F-SVD sigma:", [f"{x:.1f}" for x in out.s])
print("max |sigma - svd|:", float(jnp.max(jnp.abs(out.s - s_true))))

# --- the R-SVD baseline: same call, different spec ---
rs = factorize(A, SVDSpec(method="rsvd", rank=10, oversample=10), key=key)
print("R-SVD(default) max err:", float(jnp.max(jnp.abs(rs.s - s_true))))

# --- F-SVD through the Pallas kernels (TPU path; interpret on CPU) ---
out_k = factorize(DenseOp(A, backend="pallas"),
                  spec.replace(rank=4, max_iters=60), key=key)
print("kernel-path sigma:", [f"{x:.1f}" for x in out_k.s])

# --- batched partial SVD: vmap the facade over a stacked DenseOp ---
As = jnp.stack([A[:500, :400], A[500:1000, 400:800]])
batched = jax.vmap(
    lambda op: factorize(op, SVDSpec(method="fsvd", rank=4, max_iters=40),
                         key=key))(DenseOp(As))
print("batched sigma shape:", batched.s.shape)   # (2, 4)

# --- Table-2 error metrics + warm-start seam ---
print("errors:", {k: (float(v) if v is not None else None)
                  for k, v in out.errors(A).items()})
out2 = factorize(A, spec, q1=out.warm_start())   # warm-started GK
print("warm-start sigma[0]:", float(out2.s[0]))
