"""End-to-end driver: the paper's RSL application at 1e8-parameter scale.

Learns a rank-5 similarity metric W in R^{10000 x 10000} (1e8 entries — the
paper's "huge matrix" regime) with Riemannian mini-batch SGD (Alg 4), using
the F-SVD retraction (Alg 2) on an IMPLICIT operator: the dense W is never
materialized anywhere in the training loop — the point, tangent vectors and
retraction all live in factored form, so memory is O((d1+d2) r) ~ 100k
floats instead of 1e8.

This loop is the paper's §V workload made literal: thousands of partial
SVDs of operators that *drift slowly* between steps.  Two tracking layers
exploit that:

  * the retraction runs in *tracking* mode (``RSGDOptions.track``,
    default): each step's F-SVD warm-starts from the current point's own
    factors inside the compiled step — no cold random-start solve per
    step (``--no-track`` restores the paper's literal cold retraction);
  * the gradient-spectrum monitor is a ``repro.api.Session`` on the
    drifting batch-gradient operator: warm-started refine solves with a
    restart-vs-refine decision from the subspace angle, residual history
    for free, and checkpointable state (``--session-dir``).

A dense-SVD retraction at this size is ~1e12 flops/step; the F-SVD step is
~1e7.  Run it:

    PYTHONPATH=src python examples/train_rsl.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import SVDSpec, session
from repro.core import manifold as mf
from repro.core import rsgd
from repro.data.synthetic import make_rsl_dataset, rsl_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d1", type=int, default=10000)
    ap.add_argument("--d2", type=int, default=10000)
    ap.add_argument("--rank", type=int, default=5)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3.0)
    ap.add_argument("--fsvd-iters", type=int, default=20,
                    help="paper Fig 2: 20 = 'lower iter', 35 = 'higher'")
    ap.add_argument("--n-train", type=int, default=8192)
    ap.add_argument("--no-track", action="store_true",
                    help="cold keyed retraction solves (paper-literal "
                         "Alg 4) instead of warm-started tracking")
    ap.add_argument("--grad-spectrum", action="store_true",
                    help="track the batch-gradient operator's top spectrum "
                         "with a repro.api.Session (logged every 50 steps)")
    ap.add_argument("--session-dir", default=None,
                    help="checkpoint/resume the gradient-spectrum session "
                         "state under this directory")
    args = ap.parse_args()

    print(f"[rsl] W: {args.d1} x {args.d2} rank {args.rank} "
          f"({args.d1 * args.d2 / 1e6:.0f}M entries, never materialized)")
    key = jax.random.PRNGKey(0)
    ds = make_rsl_dataset(key, args.n_train, args.d1, args.d2, args.rank,
                          noise=0.05)
    W = mf.random_point(jax.random.fold_in(key, 1), args.d1, args.d2,
                        args.rank)
    opts = rsgd.RSGDOptions(lr=args.lr, fsvd_iters=args.fsvd_iters,
                            track=not args.no_track)
    mode = ("tracking (warm-started F-SVD)" if opts.track
            else "cold keyed F-SVD (paper-literal)")
    print(f"[rsl] retraction: {mode}")
    step = rsgd.make_step(opts)

    grad_sess = None
    if args.grad_spectrum:
        b0 = rsl_batch(ds, 0, 0, args.batch)
        g0 = rsgd.batch_euclidean_grad(W, b0["x"], b0["v"], b0["y"],
                                       opts.loss, opts.weight_decay)
        # the gradient drifts slowly along the trajectory: a Session
        # re-solves it warm from the previous step's Ritz basis.
        grad_sess = session(g0.op, SVDSpec(method="fsvd", rank=args.rank),
                            key=jax.random.fold_in(key, 2))
        if args.session_dir and grad_sess.load_latest(args.session_dir):
            print(f"[rsl] gradient-spectrum session resumed at solve "
                  f"{grad_sess.solves}")

    b = rsl_batch(ds, 0, 0, args.batch)
    jax.block_until_ready(step(W, b["x"], b["v"], b["y"], key))  # compile
    t0 = time.perf_counter()
    for t in range(args.steps):
        b = rsl_batch(ds, 0, t, args.batch)
        W, loss = step(W, b["x"], b["v"], b["y"], jax.random.fold_in(key, t))
        if t % 50 == 0:
            acc = float(rsgd.accuracy(W, b["x"], b["v"], b["y"]))
            msg = (f"[rsl] step {t:4d}: loss {float(loss):.4f} "
                   f"batch-acc {acc * 100:.1f}%")
            if grad_sess is not None:
                g = rsgd.batch_euclidean_grad(W, b["x"], b["v"], b["y"],
                                              opts.loss, opts.weight_decay)
                gf = grad_sess.update(g.op)
                rec = grad_sess.history[-1]
                msg += (f" | grad sigma1 {float(gf.s[0]):.3e} "
                        f"({rec['kind']}, {rec['iterations']} GK iters)")
            print(msg)
    jax.block_until_ready(W.s)
    dt = time.perf_counter() - t0
    acc = float(rsgd.accuracy(W, ds.X, ds.V, ds.y))
    print(f"[rsl] {args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.1f} ms/step); train acc {acc*100:.1f}%")
    print(f"[rsl] learned spectrum: {[f'{x:.2f}' for x in W.s]}")
    s_true = ds.true_spectrum()
    print(f"[rsl] planted spectrum (top-5): "
          f"{[f'{x:.2f}' for x in s_true[:5]]}")
    if grad_sess is not None:
        counts = grad_sess.counts()
        print(f"[rsl] gradient-spectrum session: {grad_sess.solves} solves "
              f"({counts['refine']} refined, {counts['restart']} restarts)")
        if args.session_dir:
            grad_sess.save(args.session_dir)
            print(f"[rsl] session state saved to {args.session_dir}")


if __name__ == "__main__":
    main()
