"""LM training with Krylov low-rank gradient compression (reduced config).

Data-parallel training where the per-layer gradient all-reduce is replaced
by the paper's GK factorization of the implicit mean-gradient operator
(repro.distributed.compression): each Lanczos iteration moves one m-vector +
one n-vector instead of the full m x n gradient.  Uses 8 fake CPU devices.

    python examples/train_lm.py --steps 30 --compress
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_arch                     # noqa: E402
from repro.configs.base import FsvdConfig, OptimConfig  # noqa: E402
from repro.data.synthetic import LMBatchSpec, lm_batch  # noqa: E402
from repro.distributed import compression as C         # noqa: E402
from repro.launch.mesh import make_mesh                # noqa: E402
from repro.models import model as model_mod            # noqa: E402
from repro.optim import make_optimizer                 # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--rank", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    fcfg = FsvdConfig(compression_rank=args.rank, compression_min_dim=64,
                      max_iters=2 * args.rank)
    ocfg = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps)
    mesh = make_mesh((8,), ("data",))
    opt_init, opt_update = make_optimizer(ocfg)

    params, _ = model_mod.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = opt_init(params)
    ef = C.init_error_feedback(params, fcfg)

    def local_loss(params, batch):
        return model_mod.loss_fn(params, batch, cfg)[0]

    def dp_step(params, opt_state, ef, batch):
        """shard_map over 'data': local grads -> (compressed) mean -> adamw."""
        def body(params, opt_state, ef, batch):
            # params/opt replicated; batch sharded on batch axis
            grads = jax.grad(local_loss)(params, batch)
            if args.compress:
                mean, ef, stats = C.compressed_mean_grads(
                    grads, ef, "data", fcfg)
                ratio = stats.compressed_bytes / jnp.maximum(
                    stats.dense_bytes, 1.0)
            else:
                nw = jax.lax.psum(1, "data")
                mean = jax.tree.map(lambda g: jax.lax.psum(g, "data") / nw,
                                    grads)
                ratio = jnp.ones(())
            loss = jax.lax.pmean(local_loss(params, batch), "data")
            new_params, new_opt, _ = opt_update(params, opt_state, mean)
            return new_params, new_opt, ef, loss, ratio

        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(), P("data")),
            out_specs=(P(), P(), P(), P(), P()),
            check_vma=False)(params, opt_state, ef, batch)

    step = jax.jit(dp_step, donate_argnums=(0, 1, 2))
    spec = LMBatchSpec(args.batch, args.seq, cfg.vocab_size)
    t0 = time.perf_counter()
    for t in range(args.steps):
        batch = lm_batch(spec, 0, t)
        params, opt_state, ef, loss, ratio = step(params, opt_state, ef,
                                                  batch)
        if t % 5 == 0:
            print(f"[lm] step {t:3d}: loss {float(loss):.4f} "
                  f"comm-bytes ratio {float(ratio):.4f}")
    dt = time.perf_counter() - t0
    mode = "compressed" if args.compress else "dense"
    print(f"[lm] {args.steps} {mode} DP steps in {dt:.1f}s; "
          f"final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
