"""Batched LM serving with a KV cache (reduced config on CPU).

Prefill once, then greedy-decode with the per-family cache (GQA KV / MLA
latents / SSD states).  Demonstrates the serve path every decode dry-run
cell lowers.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.launch.serve import generate
from repro.models import model as model_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params, _ = model_mod.init_model(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = 0.02 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model))

    out = generate(params, cfg, prompts, args.gen, frames)   # compile+run
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, args.gen, frames)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"[serve] {args.arch} ({cfg.family}): {toks} tokens in {dt:.2f}s "
          f"-> {toks/dt:.1f} tok/s (batch {args.batch})")
    print("[serve] continuations:")
    for row in out[:, args.prompt_len:]:
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
