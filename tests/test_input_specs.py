"""Input-spec assembly: every (arch x shape) cell builds a coherent
(struct, sharding) pair — structure equality, no allocation, divisibility
fallbacks.  Uses a 1-device ("data","model")=(1,1) mesh; the 512-device
layouts are proven by the dry-run itself."""
import jax
import pytest

from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch, get_shape
from repro.configs.base import OptimConfig
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_mesh

CELLS = [(a, s) for a in sorted(ARCHS) for s in sorted(SHAPES)
         if cell_applicable(a, s)[0]]


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_inputs_build(arch, shape, mesh):
    cfg = get_arch(arch)
    cell = ispec.cell_inputs(cfg, get_shape(shape), OptimConfig(), mesh)
    flat_struct = jax.tree_util.tree_leaves(cell["args_struct"])
    flat_shard = jax.tree_util.tree_leaves(cell["in_shardings"])
    assert len(flat_struct) == len(flat_shard)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in flat_struct)
    # struct and shardings must share tree structure
    assert (jax.tree_util.tree_structure(cell["args_struct"])
            == jax.tree_util.tree_structure(cell["in_shardings"]))


def test_abstract_init_no_allocation():
    cfg = get_arch("deepseek-v2-236b")      # 236B params: must NOT allocate
    struct, logical = ispec.abstract_init(cfg)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(struct)
            if hasattr(x, "size"))
    assert n > 200e9                        # it really is the 236B config
    total, active = None, None


def test_applicability_matrix():
    """40 cells total: 32 lowered + 8 documented skips (DESIGN.md §4)."""
    total = len(ARCHS) * len(SHAPES)
    skips = [(a, s) for a in ARCHS for s in SHAPES
             if not cell_applicable(a, s)[0]]
    assert total == 40
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    assert {"mamba2-780m", "zamba2-1.2b"}.isdisjoint({a for a, _ in skips})


def test_decode_cache_long500k_seq_sharded():
    """B=1 long-context cells shard the cache sequence axis over 'data'.

    Uses an AbstractMesh — spec construction must never need real devices
    (exactly what lets the dry-run reason about 512-chip layouts)."""
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((2, 1), ("data", "model"))
    cfg = get_arch("zamba2-1.2b")
    struct, shard = ispec.cache_struct_and_shardings(
        cfg, get_shape("long_500k"), mesh)
    kshard = shard["attn"]["k"]
    assert "data" in str(kshard.spec)


def test_train_batch_vlm_audio_extras():
    b_vlm = ispec.train_batch_struct(get_arch("llava-next-34b"),
                                     get_shape("train_4k"))
    assert "img_embeds" in b_vlm
    assert b_vlm["tokens"].shape[1] + b_vlm["img_embeds"].shape[1] == 4096
    b_aud = ispec.train_batch_struct(get_arch("whisper-base"),
                                     get_shape("train_4k"))
    assert "frames" in b_aud
