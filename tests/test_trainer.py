"""Fault-tolerant trainer: NaN guard, resume, straggler watchdog, drain."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_arch
from repro.configs.base import (CheckpointConfig, OptimConfig, RuntimeConfig,
                                ShapeConfig)
from repro.data.synthetic import LMBatchSpec, lm_batch
from repro.runtime import Trainer, build_train_step
from repro.runtime.steps import init_state
from repro.runtime.trainer import StragglerWatchdog


def _setup(tmp_path, every=10, async_write=False):
    cfg = get_arch("stablelm-1.6b").reduced(num_layers=2)
    opt = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 4),
                    optim=opt,
                    checkpoint=CheckpointConfig(directory=str(tmp_path),
                                                every_steps=every,
                                                async_write=async_write),
                    runtime=RuntimeConfig(max_nan_skips=3, log_every=0))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(cfg, opt))
    spec = LMBatchSpec(4, 32, cfg.vocab_size)
    return cfg, run, state, step, spec


def test_loss_decreases(tmp_path):
    cfg, run, state, step, spec = _setup(tmp_path)
    tr = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state,
                 install_sigterm=False, log_fn=lambda s: None)
    hist = tr.run(40)
    assert np.mean([h["loss"] for h in hist[-5:]]) \
        < np.mean([h["loss"] for h in hist[:5]])


def test_resume_continues_from_checkpoint(tmp_path):
    cfg, run, state, step, spec = _setup(tmp_path, every=10)
    tr = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state,
                 install_sigterm=False, log_fn=lambda s: None)
    tr.run(15)   # checkpoints at 10 and a final one at 15

    state2 = init_state(cfg, run.optim, jax.random.PRNGKey(42))
    tr2 = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state2,
                  install_sigterm=False, log_fn=lambda s: None)
    assert tr2.maybe_resume()
    assert tr2.step == 15
    # resumed params identical to saved ones
    a = jax.tree.leaves(tr.state)[0]
    b = jax.tree.leaves(tr2.state)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nan_guard_skips_and_aborts(tmp_path):
    cfg, run, state, step, spec = _setup(tmp_path)

    def poisoned_batch(s):
        b = lm_batch(spec, 0, s)
        # out-of-range label -> gather fetches garbage? No: labels are used
        # via take_along_axis on logits; poison via an inf in img-less path
        # is cleanest through a huge token embedding lookup — instead poison
        # the model by passing label ids < -1 (masked) and tokens NaN-free:
        # easier: wrap the step below.
        return b

    # wrap the jitted step to inject a NaN loss every step
    def bad_step(state, batch):
        new_state, metrics = step(state, batch)
        metrics = dict(metrics)
        metrics["loss"] = jnp.asarray(jnp.nan)
        metrics["skipped"] = jnp.asarray(1, jnp.int32)
        return state, metrics   # state unchanged = skip semantics

    tr = Trainer(run, bad_step, poisoned_batch, state,
                 install_sigterm=False, log_fn=lambda s: None)
    with pytest.raises(RuntimeError, match="consecutive"):
        tr.run(10)
    assert tr.consecutive_nans >= 4


def test_in_graph_nan_guard_preserves_state():
    cfg = get_arch("stablelm-1.6b").reduced(num_layers=1)
    opt = OptimConfig(lr=1e-3)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(cfg, opt, nan_guard=True))
    spec = LMBatchSpec(2, 16, cfg.vocab_size)
    batch = lm_batch(spec, 0, 0)
    # poison the embedding row of a token that actually OCCURS in the batch
    tok0 = int(batch["tokens"][0, 0])
    bad_params = dict(state.params)
    bad_params["embed"] = state.params["embed"].at[tok0].set(jnp.nan)
    bad_state = state._replace(params=bad_params)
    new_state, metrics = step(bad_state, batch)
    assert int(metrics["skipped"]) == 1
    a = jax.tree.leaves(bad_state.params)
    b = jax.tree.leaves(new_state.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_straggler_watchdog_flags_outlier():
    wd = StragglerWatchdog(zscore=3.0, window=50)
    for i in range(30):
        assert not wd.observe(i, 0.1 + 0.001 * (i % 3))
    assert wd.observe(31, 1.5)          # 10x step time -> alarm
    assert len(wd.alarms) == 1


def test_sigterm_drain(tmp_path):
    cfg, run, state, step, spec = _setup(tmp_path, every=1000)
    tr = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state,
                 install_sigterm=False, log_fn=lambda s: None)

    orig_step = tr.train_step
    def step_then_term(st, b):
        out = orig_step(st, b)
        if tr.step == 5:
            tr._on_sigterm(None, None)    # simulate SIGTERM mid-run
        return out
    tr.train_step = step_then_term
    tr.run(50)
    assert tr.step == 6                    # drained right after step 5
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 6   # final checkpoint written


def test_trainer_checkpoints_and_resumes_solver_session(tmp_path):
    """A tracking Session handed to the trainer checkpoints alongside the
    model state and resumes warm: the restarted trainer's session starts
    from the saved factorization instead of a cold solve."""
    from repro.api import SVDSpec, session

    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (24, 4)) @ jax.random.normal(k2, (4, 18))
    sess = session(A, SVDSpec(method="fsvd", rank=3, max_iters=12), key=key)
    sess.solve()

    cfg, run, state, step, spec = _setup(tmp_path, every=10)
    tr = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state,
                 install_sigterm=False, log_fn=lambda s: None,
                 session=sess)
    tr.run(5)       # final checkpoint (+ session state) at step 5

    sess2 = session(A, SVDSpec(method="fsvd", rank=3, max_iters=12),
                    key=key)
    state2 = init_state(cfg, run.optim, jax.random.PRNGKey(9))
    tr2 = Trainer(run, step, lambda s: lm_batch(spec, 0, s), state2,
                  install_sigterm=False, log_fn=lambda s: None,
                  session=sess2)
    assert tr2.maybe_resume()
    assert sess2.fact is not None and sess2.solves == sess.solves
    np.testing.assert_array_equal(np.asarray(sess2.fact.s),
                                  np.asarray(sess.fact.s))
    # the resumed session refines (warm) rather than re-solving cold
    sess2.update(A + 1e-3 * jax.random.normal(key, A.shape))
    assert sess2.history[-1]["kind"] == "refine"
