"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
in interpret mode (the kernel body runs in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 48, 4), (300, 200, 17), (1024, 512, 64), (100, 700, 5),
          (512, 128, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_matvec_fused(m, n, k, dt):
    key = jax.random.PRNGKey(m * n)
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (m, n)).astype(dt)
    p = jax.random.normal(ks[1], (n,))
    y = jax.random.normal(ks[2], (m,))
    got = ops.matvec_fused(A, p, y, 0.37)
    want = ref.matvec_fused(A, p, y, 0.37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_rmatvec_fused(m, n, k, dt):
    key = jax.random.PRNGKey(m + n)
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (m, n)).astype(dt)
    q = jax.random.normal(ks[1], (m,))
    y = jax.random.normal(ks[2], (n,))
    got = ops.rmatvec_fused(A, q, y, 1.7)
    want = ref.rmatvec_fused(A, q, y, 1.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("passes", [1, 2])
def test_reorth(m, n, k, passes):
    key = jax.random.PRNGKey(k)
    ks = jax.random.split(key, 2)
    Q = jnp.linalg.qr(jax.random.normal(ks[0], (m, k)))[0]
    v = jax.random.normal(ks[1], (m,))
    got = ops.reorth(v, Q, passes)
    want = ref.reorth(v, Q, passes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the result is orthogonal to the basis
    assert float(jnp.max(jnp.abs(Q.T @ got))) < 1e-4 * float(
        jnp.linalg.norm(v))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_lowrank_matmul(m, n, k, dt):
    key = jax.random.PRNGKey(m - n + k)
    ks = jax.random.split(key, 3)
    U = jax.random.normal(ks[0], (m, k)).astype(dt)
    s = jnp.abs(jax.random.normal(ks[1], (k,)))
    Vt = jax.random.normal(ks[2], (k, n)).astype(dt)
    got = ops.lowrank_matmul(U, s, Vt)
    want = ref.lowrank_matmul(U, s, Vt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


# --------------------------------------------------------------------------
# ragged shapes: wrappers pad to tile multiples with zeros — exactness of
# that claim is locked in on non-tile-multiple (m, n) well away from any
# (8, 128)-ish boundary, including the sparse ELL kernel.
# --------------------------------------------------------------------------

RAGGED = [(300, 517), (257, 129), (127, 383), (300, 200)]


@pytest.mark.parametrize("m,n", RAGGED)
def test_matvec_fused_ragged(m, n):
    ks = jax.random.split(jax.random.PRNGKey(m ^ n), 3)
    A = jax.random.normal(ks[0], (m, n))
    p = jax.random.normal(ks[1], (n,))
    y = jax.random.normal(ks[2], (m,))
    np.testing.assert_allclose(np.asarray(ops.matvec_fused(A, p, y, 0.9)),
                               np.asarray(ref.matvec_fused(A, p, y, 0.9)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n", RAGGED)
def test_rmatvec_fused_ragged(m, n):
    ks = jax.random.split(jax.random.PRNGKey(m + 3 * n), 3)
    A = jax.random.normal(ks[0], (m, n))
    q = jax.random.normal(ks[1], (m,))
    y = jax.random.normal(ks[2], (n,))
    np.testing.assert_allclose(np.asarray(ops.rmatvec_fused(A, q, y, 0.4)),
                               np.asarray(ref.rmatvec_fused(A, q, y, 0.4)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k", [(300, 17), (517, 5), (129, 31)])
def test_reorth_ragged(m, k):
    ks = jax.random.split(jax.random.PRNGKey(m * k), 2)
    Q = jnp.linalg.qr(jax.random.normal(ks[0], (m, k)))[0]
    v = jax.random.normal(ks[1], (m,))
    np.testing.assert_allclose(np.asarray(ops.reorth(v, Q, 2)),
                               np.asarray(ref.reorth(v, Q, 2)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n", RAGGED)
def test_lowrank_matmul_ragged(m, n):
    ks = jax.random.split(jax.random.PRNGKey(m - n), 3)
    U = jax.random.normal(ks[0], (m, 7))
    s = jnp.abs(jax.random.normal(ks[1], (7,)))
    Vt = jax.random.normal(ks[2], (7, n))
    np.testing.assert_allclose(np.asarray(ops.lowrank_matmul(U, s, Vt)),
                               np.asarray(ref.lowrank_matmul(U, s, Vt)),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# fused GK step pipeline: matvec + CGS products + norm in one kernel chain.
# f32 acceptance is 1e-5 (relative to the candidate's scale) — the kernel
# and the oracle both accumulate f32, so only blocking order differs.
# --------------------------------------------------------------------------

GK_STEP_SHAPES = [(64, 48, 4), (300, 517, 17), (257, 129, 31),
                  (127, 383, 9), (1024, 512, 64), (300, 200, 5)]


def _step_inputs(m, n, k, seed, left=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    A = jax.random.normal(ks[0], (m, n))
    x = jax.random.normal(ks[1], (n if left else m,))
    y = jax.random.normal(ks[2], (m if left else n,))
    Q = jnp.linalg.qr(jax.random.normal(ks[3], (m if left else n, k)))[0]
    return A, x, y, Q


@pytest.mark.parametrize("m,n,k", GK_STEP_SHAPES)
@pytest.mark.parametrize("passes", [1, 2, 3])
def test_gk_step_fused(m, n, k, passes):
    A, p, y, Q = _step_inputs(m, n, k, m * n + k)
    got_u, got_b = ops.gk_step_fused(A, p, y, 0.37, Q, passes)
    want_u, want_b = ref.gk_step(A, p, y, 0.37, Q, passes)
    scale = float(jnp.max(jnp.abs(want_u)))
    np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                               rtol=1e-5, atol=1e-5 * scale)
    np.testing.assert_allclose(float(got_b), float(want_b), rtol=1e-5)
    # the pipeline's CGS output is orthogonal to the basis
    if passes >= 2:
        assert float(jnp.max(jnp.abs(Q.T @ got_u))) < 1e-4 * scale


@pytest.mark.parametrize("m,n,k", GK_STEP_SHAPES)
@pytest.mark.parametrize("passes", [1, 2])
def test_gk_rstep_fused(m, n, k, passes):
    A, q, y, P = _step_inputs(m, n, k, m + 3 * n + k, left=False)
    got_v, got_a = ops.gk_rstep_fused(A, q, y, 1.7, P, passes)
    want_v, want_a = ref.gk_rstep(A, q, y, 1.7, P, passes)
    scale = float(jnp.max(jnp.abs(want_v)))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5 * scale)
    np.testing.assert_allclose(float(got_a), float(want_a), rtol=1e-5)


@pytest.mark.parametrize("m,n,k", [(300, 517, 17), (1024, 512, 64)])
def test_gk_step_fused_bf16_storage(m, n, k):
    """bf16 A/basis storage, f32 accumulation: tracks the f32 oracle to
    bf16 input-rounding accuracy."""
    A, p, y, Q = _step_inputs(m, n, k, m ^ n)
    got_u, got_b = ops.gk_step_fused(A.astype(jnp.bfloat16), p, y, 0.37,
                                     Q.astype(jnp.bfloat16), 2)
    want_u, want_b = ref.gk_step(A, p, y, 0.37, Q, 2)
    scale = float(jnp.max(jnp.abs(want_u)))
    np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                               rtol=3e-2, atol=3e-2 * scale)
    np.testing.assert_allclose(float(got_b), float(want_b), rtol=3e-2)


def test_gk_step_tile_override():
    A, p, y, Q = _step_inputs(512, 384, 32, 99)
    want_u, want_b = ref.gk_step(A, p, y, 0.9, Q, 2)
    for bm, bn in [(128, 128), (512, 384), (64, 256), (2048, 512)]:
        got_u, got_b = ops.gk_step_fused(A, p, y, 0.9, Q, 2, bm=bm, bn=bn)
        np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(float(got_b), float(want_b), rtol=1e-5)


# --------------------------------------------------------------------------
# sparse ELL matvec kernel
# --------------------------------------------------------------------------

def _random_sparse(key, m, n, density):
    km, kv = jax.random.split(key)
    mask = jax.random.bernoulli(km, density, (m, n))
    return jnp.where(mask, jax.random.normal(kv, (m, n)), 0.0)


@pytest.mark.parametrize("m,n,density",
                         [(300, 517, 0.02), (257, 129, 0.1),
                          (64, 48, 0.3), (128, 1000, 0.005)])
def test_sparse_matvec_vs_ref(m, n, density):
    from repro.kernels.sparse_matvec import ell_pack
    A = _random_sparse(jax.random.PRNGKey(m * n), m, n, density)
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    idx = jnp.stack(jnp.nonzero(A), axis=1)
    vals, cols = ell_pack(A[idx[:, 0], idx[:, 1]], idx, (m, n))
    got = ops.sparse_matvec(vals, cols, x)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.sparse_matvec(vals, cols, x)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ x),
                               rtol=2e-4, atol=2e-4)


def test_sparse_matvec_empty_rows_and_duplicates():
    """Rows with zero entries and duplicate COO coordinates (sum semantics)
    both survive the ELL pack."""
    from repro.kernels.sparse_matvec import ell_pack
    data = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    idx = jnp.asarray([[0, 1], [0, 1], [3, 0], [3, 2]])   # row 0 duplicated;
    x = jnp.asarray([1.0, 10.0, 100.0])                   # rows 1, 2 empty
    vals, cols = ell_pack(data, idx, (5, 3))
    got = ops.sparse_matvec(vals, cols, x)
    np.testing.assert_allclose(np.asarray(got),
                               [30.0, 0.0, 0.0, 403.0, 0.0], rtol=1e-6)


def test_sparse_matvec_tile_override():
    from repro.kernels.sparse_matvec import ell_pack
    A = _random_sparse(jax.random.PRNGKey(5), 200, 150, 0.05)
    x = jax.random.normal(jax.random.PRNGKey(6), (150,))
    idx = jnp.stack(jnp.nonzero(A), axis=1)
    vals, cols = ell_pack(A[idx[:, 0], idx[:, 1]], idx, (200, 150))
    for bm in (32, 64, 256):
        np.testing.assert_allclose(
            np.asarray(ops.sparse_matvec(vals, cols, x, bm=bm)),
            np.asarray(A @ x), rtol=2e-4, atol=2e-4)


def test_kernel_tile_override():
    """Non-default block shapes still correct (hillclimb knob)."""
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (512, 384))
    p = jax.random.normal(jax.random.fold_in(key, 1), (384,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (512,))
    for bm, bn in [(128, 128), (512, 384), (64, 256)]:
        got = ops.matvec_fused(A, p, y, 0.1, bm=bm, bn=bn)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.matvec_fused(A, p, y, 0.1)),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# sparse-sign sketch apply (gather-only ELL: fixed ζ slots per sketch row)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b", [(300, 64, 24), (128, 130, 16),
                                   (70, 16, 48), (48, 48, 48)])
def test_sketch_matmat_vs_ref(n, d, b):
    from repro.core.sketch import make_sketch
    sk = make_sketch(jax.random.PRNGKey(n * d + b), n, d,
                     kind="sparse_sign", dtype=jnp.float32)
    X = jax.random.normal(jax.random.PRNGKey(2), (n, b))
    got = ops.sketch_matmat(sk.signs, sk.idx, X)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.sketch_matmat(sk.signs,
                                                            sk.idx, X)),
                               rtol=2e-5, atol=2e-5)
    # both must equal the dense TᵀX with the scatter-built T (duplicate
    # slot indices sum — same semantics on both paths)
    dense = np.asarray(sk.dense())
    np.testing.assert_allclose(np.asarray(got), dense.T @ np.asarray(X),
                               rtol=2e-5, atol=2e-5)


def test_sketch_matmat_tile_override():
    from repro.core.sketch import make_sketch
    sk = make_sketch(jax.random.PRNGKey(9), 200, 96, kind="sparse_sign",
                     dtype=jnp.float32)
    X = jax.random.normal(jax.random.PRNGKey(10), (200, 32))
    want = np.asarray(ref.sketch_matmat(sk.signs, sk.idx, X))
    for bd in (32, 96, 256):
        np.testing.assert_allclose(
            np.asarray(ops.sketch_matmat(sk.signs, sk.idx, X, bd=bd)),
            want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# COO scatter-add (count-sketch fold primitive — the first scatter kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,m,d", [(300, 37, 20), (128, 128, 128),
                                   (1, 5, 3), (513, 260, 130)])
def test_scatter_add_vs_ref(E, m, d):
    ks = jax.random.split(jax.random.PRNGKey(E * m + d), 3)
    rows = jax.random.randint(ks[0], (E,), 0, m, jnp.int32)
    cols = jax.random.randint(ks[1], (E,), 0, d, jnp.int32)
    vals = jax.random.normal(ks[2], (E,))
    got = ops.scatter_add(rows, cols, vals, (m, d))
    want = ref.scatter_add(rows, cols, vals, (m, d))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)
    dense = np.zeros((m, d), np.float64)
    np.add.at(dense, (np.asarray(rows), np.asarray(cols)),
              np.asarray(vals, np.float64))
    np.testing.assert_allclose(np.asarray(got), dense, rtol=1e-5, atol=1e-5)


def test_scatter_add_duplicate_slots_bitexact():
    """Forced collisions: duplicate coordinates SUM, and on dyadic values
    (exact f32 addition) the kernel matches the dense einsum oracle
    bit-for-bit — the acceptance contract for count-sketch semantics."""
    rows = jnp.asarray([3, 3, 3, 0, 3, 1, 1], jnp.int32)
    cols = jnp.asarray([1, 1, 1, 0, 1, 2, 2], jnp.int32)
    vals = jnp.asarray([0.25, 0.5, 1.25, -2.0, -0.75, 8.0, -8.0],
                       jnp.float32)
    got = np.asarray(ops.scatter_add(rows, cols, vals, (5, 4)))
    want = np.asarray(ref.scatter_add(rows, cols, vals, (5, 4)))
    np.testing.assert_array_equal(got, want)
    assert got[3, 1] == np.float32(1.25)       # 0.25+0.5+1.25-0.75
    assert got[1, 2] == np.float32(0.0)        # +8 and -8 annihilate
    assert got[0, 0] == np.float32(-2.0)


def test_scatter_add_empty_and_padding():
    """E=0 returns zeros; block-multiple padding entries (0,0,0) are exact
    — an all-duplicates stream at (0, 0) must not double-count pads."""
    z = ops.scatter_add(jnp.zeros((0,), jnp.int32),
                        jnp.zeros((0,), jnp.int32),
                        jnp.zeros((0,), jnp.float32), (4, 6))
    np.testing.assert_array_equal(np.asarray(z), np.zeros((4, 6)))
    E = 200                                     # pads to 256 at be=128
    rows = jnp.zeros((E,), jnp.int32)
    cols = jnp.zeros((E,), jnp.int32)
    vals = jnp.ones((E,), jnp.float32)
    got = np.asarray(ops.scatter_add(rows, cols, vals, (3, 3)))
    assert got[0, 0] == np.float32(E)
    assert np.abs(got).sum() == np.float32(E)


def test_scatter_add_block_override():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    E, m, d = 400, 50, 60
    rows = jax.random.randint(ks[0], (E,), 0, m, jnp.int32)
    cols = jax.random.randint(ks[1], (E,), 0, d, jnp.int32)
    vals = jax.random.normal(ks[2], (E,))
    want = np.asarray(ref.scatter_add(rows, cols, vals, (m, d)))
    for be in (32, 100, 512):
        np.testing.assert_allclose(
            np.asarray(ops.scatter_add(rows, cols, vals, (m, d), be=be)),
            want, rtol=2e-6, atol=2e-6)
