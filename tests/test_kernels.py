"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
in interpret mode (the kernel body runs in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 48, 4), (300, 200, 17), (1024, 512, 64), (100, 700, 5),
          (512, 128, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_matvec_fused(m, n, k, dt):
    key = jax.random.PRNGKey(m * n)
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (m, n)).astype(dt)
    p = jax.random.normal(ks[1], (n,))
    y = jax.random.normal(ks[2], (m,))
    got = ops.matvec_fused(A, p, y, 0.37)
    want = ref.matvec_fused(A, p, y, 0.37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_rmatvec_fused(m, n, k, dt):
    key = jax.random.PRNGKey(m + n)
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (m, n)).astype(dt)
    q = jax.random.normal(ks[1], (m,))
    y = jax.random.normal(ks[2], (n,))
    got = ops.rmatvec_fused(A, q, y, 1.7)
    want = ref.rmatvec_fused(A, q, y, 1.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("passes", [1, 2])
def test_reorth(m, n, k, passes):
    key = jax.random.PRNGKey(k)
    ks = jax.random.split(key, 2)
    Q = jnp.linalg.qr(jax.random.normal(ks[0], (m, k)))[0]
    v = jax.random.normal(ks[1], (m,))
    got = ops.reorth(v, Q, passes)
    want = ref.reorth(v, Q, passes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the result is orthogonal to the basis
    assert float(jnp.max(jnp.abs(Q.T @ got))) < 1e-4 * float(
        jnp.linalg.norm(v))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_lowrank_matmul(m, n, k, dt):
    key = jax.random.PRNGKey(m - n + k)
    ks = jax.random.split(key, 3)
    U = jax.random.normal(ks[0], (m, k)).astype(dt)
    s = jnp.abs(jax.random.normal(ks[1], (k,)))
    Vt = jax.random.normal(ks[2], (k, n)).astype(dt)
    got = ops.lowrank_matmul(U, s, Vt)
    want = ref.lowrank_matmul(U, s, Vt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dt))


def test_kernel_tile_override():
    """Non-default block shapes still correct (hillclimb knob)."""
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (512, 384))
    p = jax.random.normal(jax.random.fold_in(key, 1), (384,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (512,))
    for bm, bn in [(128, 128), (512, 384), (64, 256)]:
        got = ops.matvec_fused(A, p, y, 0.1, bm=bm, bn=bn)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.matvec_fused(A, p, y, 0.1)),
                                   rtol=2e-4, atol=2e-4)
