"""Hypothesis property battery for the count-sketch kernel and the
sketch-resident fold path (PR 10 satellite).

Three properties, straight from the math:

* **Unbiasedness** — the hashed-sign ensemble is an oblivious embedding,
  ``E[T Tᵀ] = I``, so averaging ``T Tᵀ x`` over independent seeds must
  converge on ``x`` at the Monte-Carlo rate.
* **Duplicate-slot exactness** — the scatter-add kernel must agree with
  the dense one-hot einsum oracle *bit for bit* under forced hash
  collisions (entries drawn from a tiny index set, dyadic values so
  every partial sum is exactly representable — any disagreement is a
  summation-semantics bug, not roundoff).
* **Fold/sketch commutation** — folding a COO batch into a resident
  sketch equals sketching the updated operand with the same seeds, to
  f32 roundoff.  This is the invariant the whole serving path rests on.

Skips cleanly when hypothesis is absent (dev/CI requirement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property sweep needs hypothesis (dev requirement)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import SVDSpec  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.sketchres import apply_entries, sketch_operand  # noqa: E402
from repro.sketchres.state import _dense, _hashed  # noqa: E402

SPEC = SVDSpec(method="gnystrom", rank=4, oversample=4)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 10), st.integers(0, 2**31 - 1))
def test_hashed_ensemble_unbiased(n, seed):
    """E[T Tᵀ x] = x: the seed-averaged reconstruction converges on the
    identity at the 1/√K Monte-Carlo rate."""
    d, K = 64, 160
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    x /= np.linalg.norm(x)
    acc = np.zeros(n, np.float64)
    base = jax.random.PRNGKey(seed)
    for i in range(K):
        slots, signs = _hashed(jax.random.fold_in(base, i), n, d, 4)
        T = np.asarray(_dense(slots, signs, d), np.float64)
        acc += T @ (T.T @ x)
    err = np.linalg.norm(acc / K - x)
    # per-seed variance of (TTᵀx)_i is O(‖x‖²/d); K-fold averaging takes
    # the error to ~√(n/(dK)) ≈ 0.03 here — 0.2 is a 6σ-ish margin
    assert err < 0.2


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_scatter_add_duplicates_bitexact_vs_oracle(e, m, d, seed):
    """Forced collisions (tiny destination grid) with dyadic values: the
    Pallas kernel, the ops wrapper and the dense-einsum oracle must agree
    bit for bit — duplicates SUM."""
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.integers(0, m, e), jnp.int32)
    cols = jnp.asarray(rng.integers(0, d, e), jnp.int32)
    # dyadic grid: every value and every partial sum is exact in f32
    vals = jnp.asarray(rng.integers(-8, 9, e) * 0.25, jnp.float32)
    want = np.asarray(ref.scatter_add(rows, cols, vals, (m, d)))
    got = np.asarray(ops.scatter_add(rows, cols, vals, (m, d)))
    np.testing.assert_array_equal(got, want)
    # and against the integer ground truth (no float semantics at all)
    dense = np.zeros((m, d), np.float64)
    np.add.at(dense, (np.asarray(rows), np.asarray(cols)),
              np.asarray(vals, np.float64))
    np.testing.assert_array_equal(got, dense.astype(np.float32))


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 40), st.integers(8, 40), st.integers(1, 120),
       st.integers(0, 2**31 - 1))
def test_fold_commutes_with_sketch(m, n, e, seed):
    """apply_entries(sketch(A), Δ) == sketch(A + Δ) with the same seeds,
    to f32 roundoff — sketch linearity, the fold's correctness law."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    rows = rng.integers(0, m, e).astype(np.int32)
    cols = rng.integers(0, n, e).astype(np.int32)
    vals = rng.standard_normal(e).astype(np.float32)
    folded = apply_entries(sketch_operand(A, SPEC, key=key),
                           rows, cols, vals)
    A2 = np.asarray(A).copy()
    np.add.at(A2, (rows, cols), vals)
    fresh = sketch_operand(jnp.asarray(A2), SPEC, key=key)
    for got, want in ((folded.Y, fresh.Y), (folded.Z, fresh.Z)):
        scale = max(float(jnp.linalg.norm(want)), 1e-12)
        diff = float(jnp.linalg.norm(got.astype(jnp.float32)
                                     - want.astype(jnp.float32)))
        assert diff < 1e-5 * scale
