"""Fixed-rank manifold geometry: tangent-space invariants, metric, and
retraction correctness (QR closed form vs F-SVD implicit form)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manifold as mf


@pytest.fixture
def point(rng):
    return mf.random_point(rng, 60, 45, 5)


def test_point_orthonormal(point):
    np.testing.assert_allclose(np.asarray(point.U.T @ point.U), np.eye(5),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(point.V.T @ point.V), np.eye(5),
                               atol=1e-5)


def test_tangent_constraints(rng, point):
    G = jax.random.normal(jax.random.PRNGKey(3), (60, 45))
    xi = mf.project_tangent(point, G)
    assert float(jnp.max(jnp.abs(point.U.T @ xi.Up))) < 1e-5
    assert float(jnp.max(jnp.abs(point.V.T @ xi.Vp))) < 1e-5


def test_projection_idempotent(rng, point):
    G = jax.random.normal(jax.random.PRNGKey(3), (60, 45))
    xi = mf.project_tangent(point, G)
    xi2 = mf.project_tangent(point, mf.tangent_to_dense(point, xi))
    np.testing.assert_allclose(np.asarray(xi.M), np.asarray(xi2.M),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(xi.Up), np.asarray(xi2.Up),
                               atol=1e-5)


def test_projection_is_metric_projection(rng, point):
    """<G - P(G), Z> = 0 for any tangent Z (orthogonal projection)."""
    kg, kz = jax.random.split(jax.random.PRNGKey(4))
    G = jax.random.normal(kg, (60, 45))
    xi = mf.project_tangent(point, G)
    Z = mf.project_tangent(point, jax.random.normal(kz, (60, 45)))
    resid = G - mf.tangent_to_dense(point, xi)
    ip = float(jnp.vdot(resid, mf.tangent_to_dense(point, Z)))
    assert abs(ip) < 1e-3


def test_inner_matches_dense(rng, point):
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    xi = mf.project_tangent(point, jax.random.normal(k1, (60, 45)))
    zt = mf.project_tangent(point, jax.random.normal(k2, (60, 45)))
    dense = float(jnp.vdot(mf.tangent_to_dense(point, xi),
                           mf.tangent_to_dense(point, zt)))
    assert abs(float(mf.inner(xi, zt)) - dense) < 1e-3 * (1 + abs(dense))


@pytest.mark.parametrize("step", [0.05, 0.5])
def test_retractions_agree(rng, point, step):
    """QR closed form == F-SVD implicit retraction (both = rank-r SVD of
    W + t xi)."""
    G = jax.random.normal(jax.random.PRNGKey(6), (60, 45))
    xi = mf.project_tangent(point, G)
    Wq = mf.retract_qr(point, xi, -step)
    Wf = mf.retract_fsvd(point, xi, -step, fsvd_iters=25)
    np.testing.assert_allclose(np.asarray(mf.to_dense(Wq)),
                               np.asarray(mf.to_dense(Wf)),
                               atol=1e-3)


def test_retraction_first_order(rng, point):
    """R_W(t xi) = W + t xi + O(t^2)."""
    G = jax.random.normal(jax.random.PRNGKey(7), (60, 45))
    xi = mf.project_tangent(point, G)
    W0 = mf.to_dense(point)
    Xi = mf.tangent_to_dense(point, xi)
    errs = []
    for t in (1e-2, 5e-3):
        Rt = mf.to_dense(mf.retract_qr(point, xi, t))
        errs.append(float(jnp.linalg.norm(Rt - (W0 + t * Xi))))
    # halving t should shrink the error ~4x (second order)
    assert errs[1] < errs[0] / 2.5


def test_linop_matches_dense(rng, point):
    G = jax.random.normal(jax.random.PRNGKey(8), (60, 45))
    xi = mf.project_tangent(point, G)
    op = mf.as_linop(point, xi, 0.3)
    dense = mf.to_dense(point) + 0.3 * mf.tangent_to_dense(point, xi)
    p = jax.random.normal(jax.random.PRNGKey(9), (45,))
    q = jax.random.normal(jax.random.PRNGKey(10), (60,))
    np.testing.assert_allclose(np.asarray(op.mv(p)), np.asarray(dense @ p),
                               rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(op.rmv(q)), np.asarray(dense.T @ q),
                               rtol=2e-4, atol=1e-4)
