"""Differential battery: every registered solver vs ``jnp.linalg.svd``.

One shared matrix zoo (low-rank+noise, graded / flat spectra,
ill-conditioned, rectangular both ways) and per-method tolerances: the GK
solvers must track dense SVD at f32 roundoff; the sketch is held to its
looser HMT guarantee.  Separately, a densify-guard proves the matrix-free
solver path (``fsvd_blocked`` on ``SparseOp`` / ``KroneckerOp``, and
``estimate_rank`` on ``TransposedOp`` / ``GramOp``) never materializes the
dense matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import SVDSpec, estimate_rank, factorize
from repro.core.operators import (DenseOp, GramOp, KroneckerOp, Operator,
                                  SparseOp, TransposedOp, as_operator)
from repro.data.synthetic import make_kron_problem, make_sparse_problem

R = 8                                    # triplets requested throughout


def _spectrum_matrix(key, m, n, s):
    """Dense matrix with the exact singular values ``s`` (len min(m, n))."""
    k1, k2 = jax.random.split(key)
    U = jnp.linalg.qr(jax.random.normal(k1, (m, min(m, n))))[0]
    V = jnp.linalg.qr(jax.random.normal(k2, (n, min(m, n))))[0]
    return (U * jnp.asarray(s)[None, :]) @ V.T


def _zoo():
    key = jax.random.PRNGKey(1234)
    ks = jax.random.split(key, 8)
    d = min(80, 60)
    zoo = {
        # name: (matrix, has_spectral_gap_at_R)
        "lowrank_noise": (
            make_lowrank(ks[0], 100, 70, R)
            + 1e-4 * jax.random.normal(ks[1], (100, 70)), True),
        "graded": (_spectrum_matrix(ks[2], 80, 60,
                                    0.7 ** jnp.arange(d)), False),
        # near-flat, multiplicity-free: an *exactly* flat spectrum is
        # unreachable for single-vector GK (the Krylov space of a repeated
        # singular value is one-dimensional — breakdown after one step is
        # the mathematically correct answer), so the zoo spaces the values
        # by 2e-3 and sizes the matrix so k can cover the full spectrum.
        "flat": (_spectrum_matrix(ks[3], 48, 48,
                                  1.0 - 0.002 * jnp.arange(48)), False),
        "illcond": (_spectrum_matrix(
            ks[4], 60, 60, jnp.logspace(0, -6, 60)), False),
        "tall": (make_lowrank(ks[5], 150, 40, R)
                 + 1e-4 * jax.random.normal(ks[6], (150, 40)), True),
        "wide": (make_lowrank(ks[6], 40, 110, R)
                 + 1e-4 * jax.random.normal(ks[7], (40, 110)), True),
    }
    return zoo


ZOO = _zoo()

# per-method accuracy demanded on singular values, as max |ŝ − s| / s_max —
# the scale on which f32 Lanczos accuracy is actually defined (per-value
# relative error is unbounded at the f32 noise floor for tiny tail values).
SOLVERS = {
    "fsvd": dict(stol=5e-4, spec=dict(max_iters=48)),
    "fsvd_blocked": dict(stol=5e-4, spec=dict()),
    "rsvd": dict(stol=5e-2, spec=dict(power_iters=3, oversample=10)),
    "fsvd_sharded": dict(stol=5e-4, spec=dict(max_iters=48)),
    # Krylov-accurate in 4 passes: block 16 saturates the 48-dim "flat"
    # case (16 start + 2 expansions) where power iteration stalls.
    "rbk": dict(stol=5e-4, spec=dict(passes=4, sketch_dim=16)),
    # single-pass: the sketch must cover the spectrum it is asked to
    # resolve, so on the zoo's gapless "flat" matrix the panel width has
    # to reach the full 48 dims — narrower sketches pay the ~σ_{k+1}
    # tail penalty that is information-theoretic, not a bug.
    "gnystrom": dict(stol=1e-3, spec=dict(sketch_dim=48)),
}


def _run(method, A, key, precision=None):
    cfg = SOLVERS[method]
    spec = SVDSpec(method=method, rank=R, precision=precision,
                   **cfg["spec"])
    if method == "fsvd_sharded":
        import repro.distributed.gk_dist  # noqa: F401  (registers solver)
        from repro.distributed.matvec import ShardedOp, place_operator
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((jax.device_count(),), ("data",))
        operand = ShardedOp(place_operator(A, mesh), mesh)
    else:
        operand = A
    return factorize(operand, spec, key=key)


@pytest.mark.parametrize("method", sorted(SOLVERS))
@pytest.mark.parametrize("name", sorted(ZOO))
def test_singular_value_parity(method, name):
    A, _ = ZOO[name]
    s_true = jnp.linalg.svd(A, compute_uv=False)
    out = _run(method, A, jax.random.PRNGKey(7))
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(s_true[:R])))
    assert err / float(s_true[0]) < SOLVERS[method]["stol"], \
        f"{method} on {name}: σ error {err:.2e} vs σ_max {float(s_true[0]):.2e}"


# bf16 mixed precision: bases stored half-width, f32 accumulation.  The
# σ scale is bounded by basis orthonormality, which bf16 storage floors
# at ~eps_bf16·√k — tolerances widen accordingly (still ≪ the spectrum).
BF16_STOL = {"fsvd": 5e-2, "fsvd_sharded": 5e-2, "fsvd_blocked": 8e-2,
             "rsvd": 1e-1, "rbk": 1e-1, "gnystrom": 5e-2}


@pytest.mark.parametrize("method", sorted(SOLVERS))
@pytest.mark.parametrize("name", sorted(ZOO))
def test_singular_value_parity_bf16(method, name):
    A, _ = ZOO[name]
    s_true = jnp.linalg.svd(A, compute_uv=False)
    out = _run(method, A, jax.random.PRNGKey(7), precision="bf16")
    err = np.max(np.abs(np.asarray(out.s, np.float32)
                        - np.asarray(s_true[:R])))
    assert err / float(s_true[0]) < BF16_STOL[method], \
        f"{method} on {name} (bf16): σ error {err:.2e} " \
        f"vs σ_max {float(s_true[0]):.2e}"


@pytest.mark.parametrize("method", ["fsvd", "fsvd_blocked"])
def test_bf16_subspace_still_aligned(method):
    """With a spectral gap at R, even the bf16-stored basis must recover
    the dominant right subspace to ~storage accuracy."""
    A, _ = ZOO["lowrank_noise"]
    _, _, Vt = jnp.linalg.svd(A, full_matrices=False)
    out = _run(method, A, jax.random.PRNGKey(11), precision="bf16")
    cos = jnp.linalg.svd(Vt[:R] @ np.asarray(out.V, np.float32),
                         compute_uv=False)
    assert float(jnp.min(cos)) > 0.995


@pytest.mark.parametrize("method", sorted(SOLVERS))
@pytest.mark.parametrize("name",
                         [n for n in sorted(ZOO) if ZOO[n][1]])
def test_subspace_parity(method, name):
    """Where the spectrum has a gap at R, the computed right subspace must
    align with the dense-SVD one: all principal-angle cosines ≈ 1."""
    A, _ = ZOO[name]
    _, _, Vt = jnp.linalg.svd(A, full_matrices=False)
    out = _run(method, A, jax.random.PRNGKey(11))
    cos = jnp.linalg.svd(Vt[:R] @ out.V, compute_uv=False)
    floor = 0.99 if method == "rsvd" else 0.9999
    assert float(jnp.min(cos)) > floor, \
        f"{method} on {name}: min principal cosine {float(jnp.min(cos)):.6f}"


@pytest.mark.parametrize("method", ["fsvd", "fsvd_blocked"])
def test_reconstruction_residual(method):
    """On an exactly rank-R input the rank-R reconstruction is exact."""
    A = make_lowrank(jax.random.PRNGKey(3), 90, 60, R)
    out = _run(method, A, jax.random.PRNGKey(5))
    rel = float(jnp.linalg.norm(A - out.reconstruct())
                / jnp.linalg.norm(A))
    assert rel < 1e-4


# ---------------------------------------------------------------------------
# sharded-vs-single-device differential battery (8 forced host devices)
# ---------------------------------------------------------------------------
#
# Every registered solver runs on the same zoo twice — once on the plain
# operand, once sharded over an in-process 8-device mesh — and must agree
# on singular values to 1e-5·σ_max (f32) and on the dominant subspace where
# the spectrum has a gap.  Separately, σ must be *bit-identical* across
# every mesh shape that factorizes the 8 devices into row axes: the fused
# step's stacked psum always reduces over all 8 row shards with identical
# local block shapes, so the reduction tree (and hence rounding) does not
# depend on how the row axes are spelled.  (A "model" axis changes the
# local GEMV shapes — covered by the tolerance-level parity instead.)

ROW_MESHES = [((8,), ("data",)), ((2, 4), ("pod", "data")),
              ((4, 2), ("pod", "data"))]
ALL_MESHES = ROW_MESHES + [((4, 2), ("data", "model")),
                           ((2, 2, 2), ("pod", "data", "model"))]


def _sharded_run(method, A, key, mesh, precision=None):
    import repro.distributed.gk_dist  # noqa: F401  (registers solver)
    from repro.distributed.matvec import sharded_operator
    cfg = SOLVERS[method]
    spec = SVDSpec(method=method, rank=R, precision=precision,
                   **cfg["spec"])
    return factorize(sharded_operator(A, mesh), spec, key=key)


def _single_run(method, A, key):
    """Single-device reference for ``method`` (fsvd_sharded references a
    1-device mesh — the solver requires a sharded operand by contract)."""
    if method == "fsvd_sharded":
        from repro.launch.mesh import make_mesh
        return _sharded_run(method, A, key, make_mesh((1,), ("data",)))
    cfg = SOLVERS[method]
    return factorize(A, SVDSpec(method=method, rank=R, **cfg["spec"]),
                     key=key)


@pytest.mark.distributed
@pytest.mark.parametrize("method", sorted(SOLVERS))
@pytest.mark.parametrize("name", sorted(ZOO))
def test_sharded_matches_single_device(method, name, mesh8):
    A, has_gap = ZOO[name]
    key = jax.random.PRNGKey(7)
    ref = _single_run(method, A, key)
    out = _sharded_run(method, A, key, mesh8)
    smax = float(jnp.linalg.svd(A, compute_uv=False)[0])
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(ref.s)))
    assert err / smax < 1e-5, \
        f"{method} on {name}: sharded σ deviates {err:.2e} vs σ_max {smax:.2e}"
    if has_gap:
        cos = jnp.linalg.svd(np.asarray(ref.V).T @ np.asarray(out.V),
                             compute_uv=False)
        floor = 0.99 if method == "rsvd" else 0.9999
        assert float(jnp.min(cos)) > floor, \
            f"{method} on {name}: sharded/single subspaces diverge " \
            f"(min cos {float(jnp.min(cos)):.6f})"


@pytest.mark.distributed
@pytest.mark.parametrize("name", ["lowrank_noise", "illcond", "wide"])
@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_sharded_parity_on_model_axis_meshes(method, name):
    """Meshes with a "model" (column) axis change the local GEMV shapes —
    values must still track the single-device run at f32 tolerance."""
    from repro.launch.mesh import make_mesh
    A, _ = ZOO[name]
    key = jax.random.PRNGKey(7)
    ref = _single_run(method, A, key)
    smax = float(jnp.linalg.svd(A, compute_uv=False)[0])
    for shape, axes in ALL_MESHES[len(ROW_MESHES):]:
        out = _sharded_run(method, A, key, make_mesh(shape, axes))
        err = np.max(np.abs(np.asarray(out.s) - np.asarray(ref.s)))
        assert err / smax < 1e-5, \
            f"{method} on {name} mesh {shape}{axes}: σ deviates {err:.2e}"


@pytest.mark.distributed
@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_sigma_bitwise_across_row_mesh_factorizations(method):
    """σ bits must not depend on how the 8 row shards are spelled as mesh
    axes — (8,), (2,4) and (4,2) all reduce the same 8 local blocks."""
    from repro.launch.mesh import make_mesh
    A, _ = ZOO["lowrank_noise"]
    key = jax.random.PRNGKey(7)
    sigs = [np.asarray(_sharded_run(method, A, key,
                                    make_mesh(shape, axes)).s)
            for shape, axes in ROW_MESHES]
    for s, (shape, axes) in zip(sigs[1:], ROW_MESHES[1:]):
        np.testing.assert_array_equal(
            sigs[0], s,
            err_msg=f"{method}: σ bits differ between (8,)('data',) and "
                    f"{shape}{axes}")


@pytest.mark.distributed
@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_sigma_bitwise_run_to_run(method, mesh8):
    A, _ = ZOO["graded"]
    key = jax.random.PRNGKey(7)
    s1 = np.asarray(_sharded_run(method, A, key, mesh8).s)
    s2 = np.asarray(_sharded_run(method, A, key, mesh8).s)
    np.testing.assert_array_equal(s1, s2)


# ---------------------------------------------------------------------------
# densify guard: the matrix-free paths must never materialize the operand
# ---------------------------------------------------------------------------

class _DensifyGuard(Operator):
    """Forwards the matvec protocol; trips on any densification attempt —
    ``to_dense`` or a matmat wide enough to be the identity trick."""

    def __init__(self, inner):
        self._inner = inner
        self.width_cap = max(min(inner.shape) - 1, 1)

    shape = property(lambda self: self._inner.shape)
    dtype = property(lambda self: self._inner.dtype)

    def mv(self, p):
        return self._inner.mv(p)

    def rmv(self, q):
        return self._inner.rmv(q)

    def matmat(self, V):
        assert V.shape[1] <= self.width_cap, \
            f"matmat width {V.shape[1]} is a densification in disguise"
        return self._inner.matmat(V)

    def rmatmat(self, Q):
        assert Q.shape[1] <= self.width_cap, \
            f"rmatmat width {Q.shape[1]} is a densification in disguise"
        return self._inner.rmatmat(Q)

    def to_dense(self):
        raise AssertionError("solver densified a matrix-free operand")

    @property
    def T(self):
        return _DensifyGuard(self._inner.T)


def test_fsvd_blocked_sparse_never_densifies():
    """Acceptance: factorize(SparseOp, fsvd_blocked, k=20) matches dense SVD
    to ≤ 1e-4 per-value relative error without materializing the matrix."""
    prob = make_sparse_problem(jax.random.PRNGKey(21), 250, 180,
                               density=0.05)
    s_true = jnp.linalg.svd(prob.dense, compute_uv=False)[:20]
    out = factorize(_DensifyGuard(prob.op),
                    SVDSpec(method="fsvd_blocked", rank=20),
                    key=jax.random.PRNGKey(2))
    rel = np.abs(np.asarray(out.s) - np.asarray(s_true)) \
        / np.asarray(s_true)
    assert rel.max() < 1e-4


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fsvd_blocked_sparse_backends_agree(backend):
    prob = make_sparse_problem(jax.random.PRNGKey(23), 150, 120,
                               density=0.08, backend=backend)
    s_true = jnp.linalg.svd(prob.dense, compute_uv=False)[:10]
    out = factorize(prob.op, SVDSpec(method="fsvd_blocked", rank=10),
                    key=jax.random.PRNGKey(4))
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=1e-4)


def test_fsvd_blocked_kronecker_never_densifies():
    """The Kronecker operand streams through without materializing A ⊗ B."""
    prob = make_kron_problem(jax.random.PRNGKey(31), 18, 14, 15, 12)
    s_true = jnp.linalg.svd(prob.dense, compute_uv=False)[:R]
    out = factorize(_DensifyGuard(prob.op),
                    SVDSpec(method="fsvd_blocked", rank=R),
                    key=jax.random.PRNGKey(6))
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(s_true)))
    assert err / float(s_true[0]) < 1e-4


def test_fsvd_blocked_respects_memory_budget():
    """max_basis caps the retained basis; accuracy survives the restarts."""
    A = make_lowrank(jax.random.PRNGKey(41), 200, 150, 12) \
        + 1e-4 * jax.random.normal(jax.random.PRNGKey(42), (200, 150))
    s_true = jnp.linalg.svd(A, compute_uv=False)[:10]

    class _BudgetGuard(_DensifyGuard):
        max_seen = 0

        def matmat(self, V):
            _BudgetGuard.max_seen = max(_BudgetGuard.max_seen, V.shape[1])
            return super().matmat(V)

    out = factorize(_BudgetGuard(DenseOp(A)),
                    SVDSpec(method="fsvd_blocked", rank=10, block_size=4,
                            max_basis=22), key=jax.random.PRNGKey(8))
    assert _BudgetGuard.max_seen <= 22
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(s_true)))
    assert err / float(s_true[0]) < 5e-4


# ---------------------------------------------------------------------------
# estimate_rank regressions: TransposedOp / GramOp stay matrix-free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wrap", ["transposed", "gram_ata", "gram_aat",
                                  "gram_of_transposed"])
def test_estimate_rank_matrix_free(wrap):
    A = make_lowrank(jax.random.PRNGKey(51), 80, 60, 7)
    op = DenseOp(A)
    wrapped = {
        "transposed": TransposedOp(op),
        "gram_ata": GramOp(op, side="ata"),
        "gram_aat": GramOp(op, side="aat"),
        "gram_of_transposed": GramOp(TransposedOp(op)),
    }[wrap]
    est = estimate_rank(_DensifyGuard(wrapped) if wrap == "transposed"
                        else wrapped, key=jax.random.PRNGKey(9))
    assert int(est.rank) == 7


def test_estimate_rank_gram_not_underestimated():
    """σ(AᵀA) = σ(A)² squares the condition number: on an ill-conditioned
    input, GK on the Gram chain would drop small-but-real singular values
    below the breakdown threshold.  The matrix-free unwrapping must keep
    the count identical to running on A itself."""
    A = _spectrum_matrix(jax.random.PRNGKey(61), 50, 40,
                         jnp.concatenate([jnp.logspace(0, -2, 20),
                                          jnp.zeros(20)]))
    direct = estimate_rank(A, key=jax.random.PRNGKey(10))
    viagram = estimate_rank(GramOp(DenseOp(A)), key=jax.random.PRNGKey(10))
    assert int(viagram.rank) == int(direct.rank) == 20


def test_estimate_rank_sparse_operand():
    prob = make_sparse_problem(jax.random.PRNGKey(71), 120, 90,
                               density=0.1, rank=9)
    est = estimate_rank(_DensifyGuard(prob.op), key=jax.random.PRNGKey(12))
    assert int(est.rank) == int(jnp.linalg.matrix_rank(prob.dense))


# ---------------------------------------------------------------------------
# pass-budget guard: the sketch solvers carry explicit operator-touch
# contracts — gnystrom sees the operand exactly ONCE (the fused
# sketch_pass sweep), rbk exactly 2·passes+1 product sweeps
# ---------------------------------------------------------------------------

class _PassCountGuard(Operator):
    """Counts operator touches.  Each mv/rmv/matmat/rmatmat is one sweep;
    a fused ``sketch_pass`` is ONE sweep (both products come out of the
    same pass over the data).  Overrunning ``budget`` raises inside the
    solver, so a regression fails at the offending call site."""

    def __init__(self, inner, budget):
        self._inner = inner
        self.budget = budget
        self.counts = {"mv": 0, "rmv": 0, "matmat": 0, "rmatmat": 0,
                       "sketch_pass": 0}

    shape = property(lambda self: self._inner.shape)
    dtype = property(lambda self: self._inner.dtype)

    def _tick(self, kind):
        self.counts[kind] += 1
        assert sum(self.counts.values()) <= self.budget, \
            f"operator touched beyond its {self.budget}-sweep budget: " \
            f"{self.counts}"

    def mv(self, p):
        self._tick("mv")
        return self._inner.mv(p)

    def rmv(self, q):
        self._tick("rmv")
        return self._inner.rmv(q)

    def matmat(self, V):
        self._tick("matmat")
        return self._inner.matmat(V)

    def rmatmat(self, Q):
        self._tick("rmatmat")
        return self._inner.rmatmat(Q)

    def sketch_pass(self, omega, psi):
        self._tick("sketch_pass")
        return (self._inner.matmat(omega.dense()),
                self._inner.rmatmat(psi.dense()))

    def to_dense(self):
        raise AssertionError("sketch solver densified the operand")


def test_gnystrom_touches_operator_exactly_once():
    """Both gnystrom sketches must come out of one fused sweep; the core
    matrix ΨᵀAΩ is then assembled from the captured panels without ever
    touching the operator again."""
    A = make_lowrank(jax.random.PRNGKey(21), 120, 96, R)
    guard = _PassCountGuard(as_operator(A), budget=1)
    out = factorize(guard, SVDSpec(method="gnystrom", rank=R),
                    key=jax.random.PRNGKey(7))
    assert guard.counts["sketch_pass"] == 1
    assert sum(guard.counts.values()) == 1, guard.counts
    s_true = jnp.linalg.svd(A, compute_uv=False)
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(s_true[:R])))
    assert err / float(s_true[0]) < 1e-3   # exactly rank-R: near-exact


def test_rbk_respects_pass_budget():
    """rbk's sweep count is 2·passes+1: each Krylov expansion is one
    forward + one adjoint product, plus the final AV for extraction.  The
    96-dim right space with block 16 leaves q_eff == passes (no static
    clamp), so the budget is exact, not an upper bound."""
    passes = 3
    A = make_lowrank(jax.random.PRNGKey(22), 120, 96, R)
    guard = _PassCountGuard(as_operator(A), budget=2 * passes + 1)
    out = factorize(guard,
                    SVDSpec(method="rbk", rank=R, passes=passes,
                            sketch_dim=16),
                    key=jax.random.PRNGKey(7))
    assert guard.counts["matmat"] == passes + 1
    assert guard.counts["rmatmat"] == passes
    assert guard.counts["sketch_pass"] == 0
    assert int(out.iterations) == 2 * passes + 1
    s_true = jnp.linalg.svd(A, compute_uv=False)
    err = np.max(np.abs(np.asarray(out.s) - np.asarray(s_true[:R])))
    assert err / float(s_true[0]) < 1e-4
