"""Plan cache under concurrency: N threads hammering one key stage
exactly one executable (single-flight), counters stay coherent, bounded
LRU eviction is accounted, and concurrent solves + Session checkpointing
cannot deadlock (the serve dispatch worker and client threads exercise
exactly this interleaving)."""
import importlib
import threading

import jax
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (SVDSpec, clear_plan_cache, plan, plan_cache_stats,
                       trace_count)

# ``repro.api`` re-exports a ``plan`` *function*, which shadows the
# submodule under ``import repro.api.plan as ...`` — resolve the module
# itself for monkeypatching its cache bound.
plan_mod = importlib.import_module("repro.api.plan")
from repro.api.session import Session

KEY = jax.random.PRNGKey(11)
SPEC = SVDSpec(method="fsvd", rank=4, max_iters=24)

N_THREADS = 8
PER_THREAD = 4


@pytest.fixture
def fresh_cache():
    clear_plan_cache(reset_stats=True)
    yield
    clear_plan_cache(reset_stats=True)


def _hammer(fn, n_threads=N_THREADS):
    """Run ``fn(thread_idx)`` on every thread behind a start barrier; a
    thread still alive after the join timeout is a deadlock, not slowness."""
    errors = []
    barrier = threading.Barrier(n_threads)

    def worker(i):
        try:
            barrier.wait(timeout=30)
            fn(i)
        except Exception as exc:       # noqa: BLE001 — surface in-test
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    assert not any(t.is_alive() for t in threads), \
        "deadlock: worker threads never finished"
    assert not errors, errors


def test_one_key_many_threads_traces_once(fresh_cache):
    A = make_lowrank(jax.random.PRNGKey(0), 64, 48, 4)
    s_true = np.linalg.svd(np.asarray(A), compute_uv=False)[:4]
    results = [None] * N_THREADS

    def solve_loop(i):
        for j in range(PER_THREAD):
            f = plan(SPEC, like=A).solve(
                A, key=jax.random.fold_in(KEY, i * PER_THREAD + j))
            results[i] = np.asarray(f.s)

    _hammer(solve_loop)
    assert trace_count() == 1          # single-flight: one trace, period
    stats = plan_cache_stats()
    assert stats["entries"] == 1
    assert stats["misses"] == 1        # only the builder missed
    assert stats["hits"] == N_THREADS * PER_THREAD - 1
    for s in results:
        assert np.max(np.abs(s - s_true)) / s_true[0] < 1e-2


def test_distinct_keys_race_without_cross_talk(fresh_cache):
    """Threads racing DIFFERENT cache keys (per-thread operand shape)
    stage exactly one executable each — no lost entries, no duplicate
    traces, no deadlock between concurrent builders."""
    mats = [make_lowrank(jax.random.PRNGKey(i), 40 + 8 * i, 32, 4)
            for i in range(4)]

    def solve_loop(i):
        A = mats[i % len(mats)]
        for j in range(PER_THREAD):
            plan(SPEC, like=A).solve(A, key=jax.random.fold_in(KEY, j))

    _hammer(solve_loop)
    assert trace_count() == len(mats)
    stats = plan_cache_stats()
    assert stats["entries"] == len(mats)
    assert stats["misses"] == len(mats)
    assert stats["hits"] == N_THREADS * PER_THREAD - len(mats)


def test_eviction_accounting_under_tiny_cache(fresh_cache, monkeypatch):
    monkeypatch.setattr(plan_mod, "_CACHE_SIZE", 2)
    mats = [make_lowrank(jax.random.PRNGKey(i), 40 + 8 * i, 24, 4)
            for i in range(4)]
    for A in mats:
        plan(SPEC, like=A).solve(A, key=KEY)
    stats = plan_cache_stats()
    assert stats["entries"] <= 2
    assert stats["evictions"] == 2
    assert stats["misses"] == 4
    # an evicted key re-stages (miss), a resident one hits
    plan(SPEC, like=mats[0]).solve(mats[0], key=KEY)
    assert plan_cache_stats()["misses"] == 5
    plan(SPEC, like=mats[0]).solve(mats[0], key=KEY)
    assert plan_cache_stats()["hits"] == 1


def test_concurrent_solves_and_session_checkpointing(fresh_cache,
                                                     tmp_path):
    """The serve interleaving: a Session updating + checkpointing (which
    re-enters the plan cache for its refine executables) while other
    threads run plain plan solves.  Must complete without deadlock and
    with every path numerically intact."""
    A = make_lowrank(jax.random.PRNGKey(1), 48, 32, 4)
    B = make_lowrank(jax.random.PRNGKey(2), 56, 40, 4)
    rng = np.random.default_rng(0)
    session_iters = []

    def run(i):
        if i == 0:
            sess = Session(np.asarray(A), SPEC, key=jax.random.key(0),
                           track_residuals=False)
            for _ in range(3):
                drift = np.asarray(A) + 1e-4 * rng.standard_normal(
                    A.shape).astype(np.float32)
                sess.update(drift, key=jax.random.fold_in(KEY, 99))
                sess.save(str(tmp_path), keep=1)
                session_iters.append(sess.history[-1]["iterations"])
        else:
            for j in range(PER_THREAD):
                plan(SPEC, like=B).solve(
                    B, key=jax.random.fold_in(KEY, i * PER_THREAD + j))

    _hammer(run, n_threads=4)
    assert len(session_iters) == 3
    assert session_iters[-1] < session_iters[0]    # refine beat cold
    restored = Session.restore(str(tmp_path), np.asarray(A),
                               key=jax.random.key(0))
    assert restored.fact is not None
