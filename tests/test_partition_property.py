"""Hypothesis battery for ``repro.distributed.partition`` operator placement.

Three laws, each over every mesh factorization of the 8 forced host
devices (axis names drawn from the canonical ("pod", "data", "model")
layout, so row-only and row+column layouts are both covered):

  * placement round-trips: ``sharded_operator`` (pad + device_put) then
    gather reproduces the operand bit-for-bit, whatever the shape's
    divisibility;
  * shard shapes tile: the per-device block shape times the shard counts
    reconstructs the (padded) global shape, and every addressable shard of
    a placed operand has exactly that block shape;
  * ``ShardedOp.T`` commutes with placement: transposing the sharded
    operator equals sharding the transposed matrix — matvecs agree to
    f32 roundoff and the materialized operators agree exactly.

All tests carry the ``distributed`` marker (auto-skipped below 8 devices;
the CI distributed job provides them).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.distributed.partition import (operator_counts,  # noqa: E402
                                         padded_operand_shape,
                                         place_operator, shard_shape)

pytestmark = pytest.mark.distributed

# every factorization of 8 into mesh axes under the canonical names
MESHES = [((8,), ("data",)),
          ((8,), ("model",)),
          ((2, 4), ("pod", "data")),
          ((4, 2), ("pod", "data")),
          ((4, 2), ("data", "model")),
          ((2, 4), ("data", "model")),
          ((2, 2, 2), ("pod", "data", "model"))]


def _meshes():
    from repro.launch.mesh import make_mesh
    return [make_mesh(shape, axes) for shape, axes in MESHES]


_mesh_ix = st.integers(min_value=0, max_value=len(MESHES) - 1)
_dims = st.integers(min_value=1, max_value=48)
_seeds = st.integers(min_value=0, max_value=2**31 - 1)


@given(ix=_mesh_ix, m=_dims, n=_dims, seed=_seeds)
@settings(deadline=None)
def test_place_gather_round_trips_exactly(ix, m, n, seed):
    from repro.distributed.matvec import sharded_operator
    mesh = _meshes()[ix]
    A = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    op = sharded_operator(A, mesh)
    assert op.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(op.to_dense()), np.asarray(A))


@given(ix=_mesh_ix, m=_dims, n=_dims)
@settings(deadline=None)
def test_shard_shapes_tile_the_operand(ix, m, n):
    mesh = _meshes()[ix]
    r, c = operator_counts(mesh)
    mp, np_ = padded_operand_shape((m, n), mesh)
    blk = shard_shape((mp, np_), mesh)
    assert blk[0] * r == mp and blk[1] * c == np_
    assert 0 <= mp - m < r and 0 <= np_ - n < c
    A = place_operator(jnp.zeros((mp, np_)), mesh)
    shapes = {tuple(s.data.shape) for s in A.addressable_shards}
    assert shapes == {blk}


@given(ix=_mesh_ix, m=_dims, n=_dims, seed=_seeds)
@settings(deadline=None)
def test_transpose_commutes_with_placement(ix, m, n, seed):
    from repro.distributed.matvec import sharded_operator
    mesh = _meshes()[ix]
    A = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    t_then_place = sharded_operator(A.T, mesh)
    place_then_t = sharded_operator(A, mesh).T
    assert tuple(place_then_t.shape) == tuple(t_then_place.shape) == (n, m)
    # materialized operators agree exactly (dots against identity columns
    # involve no accumulation) ...
    np.testing.assert_array_equal(np.asarray(t_then_place.to_dense()),
                                  np.asarray(A.T))
    np.testing.assert_array_equal(np.asarray(place_then_t.to_dense()),
                                  np.asarray(A.T))
    # ... and matvecs agree to f32 roundoff (different reduction layouts)
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (m,))
    scale = float(jnp.linalg.norm(A)) + 1e-30
    diff = jnp.max(jnp.abs(t_then_place.mv(q) - place_then_t.mv(q)))
    assert float(diff) / scale < 1e-5