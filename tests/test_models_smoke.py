"""Per-arch reduced smoke tests: one forward/train step on CPU asserting
output shapes + no NaNs, plus prefill→decode consistency per family.
The FULL configs are exercised only via the dry-run (no allocation here)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import model as M

B, S = 2, 32
ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, seq=S, labels=True):
    tok = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if labels:
        batch["labels"] = jnp.roll(tok, -1, axis=1)
    if cfg.vlm is not None:
        batch["img_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.vlm.num_image_tokens, cfg.d_model))
    if cfg.encdec is not None:
        batch["frames"] = 0.02 * jax.random.normal(key, (B, seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_arch(arch).reduced()
    params, logical = M.init_model(cfg, jax.random.PRNGKey(0))
    # logical axes mirror params
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(
                logical, is_leaf=lambda x: isinstance(x, tuple)))
    loss, met = M.loss_fn(params, _batch(cfg, jax.random.PRNGKey(1)), cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(met.aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_finite_grads(arch):
    cfg = get_arch(arch).reduced()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # at least one nonzero gradient per tree
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(S tokens), token S) == prefill(S+1 tokens) last logits.

    MoE archs use a large capacity factor: with tiny smoke batches the
    default 1.25 capacity drops tokens (correct-but-lossy routing), which
    would make the two paths legitimately differ."""
    cfg = get_arch(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    full = _batch(cfg, key, seq=S + 1, labels=False)
    ref_logits, _ = M.prefill_step(params, full, cfg)

    part = {k: (v[:, :S] if k in ("tokens",) else v)
            for k, v in full.items()}
    if "frames" in part:
        part["frames"] = full["frames"][:, :S + 1]
    logits_s, cache = M.prefill_step(params, part, cfg)
    cache = M.pad_cache_to(cache, cfg, S + 1 + (
        cfg.vlm.num_image_tokens if cfg.vlm is not None else 0))
    pos0 = S + (cfg.vlm.num_image_tokens if cfg.vlm is not None else 0)
    dec_batch = {"tokens": full["tokens"][:, S:S + 1],
                 "positions": jnp.full((B, 1), pos0, jnp.int32)}
    dec_logits, _ = M.decode_step(params, cache, dec_batch, cfg)
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(dec_logits - ref_logits))) / scale
    assert err < 2e-2, f"{arch}: rel err {err}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cache_shapes(arch):
    cfg = get_arch(arch).reduced()
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, 64))
    assert jax.tree_util.tree_leaves(cache)   # non-empty for every family


def test_gemma2_local_global_windows():
    from repro.models.model import layer_windows
    from repro.models.attention import GLOBAL_WINDOW
    cfg = get_arch("gemma2-9b")
    w = np.asarray(layer_windows(cfg))
    assert w.shape == (42,)
    assert w[0] == 4096 and w[1] == GLOBAL_WINDOW   # local/global alternation
    assert (w[0::2] == 4096).all() and (w[1::2] == GLOBAL_WINDOW).all()


def test_chunked_attention_matches_full():
    """cfg.attn_impl='chunked' == 'full' on the same inputs."""
    cfg = get_arch("stablelm-1.6b").reduced()
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", q_chunk=8)
    cfg_f = dataclasses.replace(cfg, attn_impl="full")
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = M.loss_fn(params, batch, cfg_c)
    l2, _ = M.loss_fn(params, batch, cfg_f)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_chunked_ce_matches_unchunked():
    cfg = get_arch("stablelm-1.6b").reduced()
    cfg_c = dataclasses.replace(cfg, ce_chunk=8)
    cfg_f = dataclasses.replace(cfg, ce_chunk=0)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = M.loss_fn(params, batch, cfg_c)
    l2, _ = M.loss_fn(params, batch, cfg_f)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_sliding_window_masks_long_range():
    """A local layer cannot see past its window."""
    import repro.models.attention as A
    cfg = get_arch("gemma2-9b").reduced(sliding_window=4, num_layers=1)
    bag_key = jax.random.PRNGKey(0)
    from repro.models.layers import ParamBag
    bag = ParamBag(bag_key)
    A.init_gqa(bag, cfg, jnp.float32)
    p = bag.params["attn"]
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    pos = jnp.arange(16)[None]
    out1, _ = A.gqa_attention(p, x, pos, cfg, window=4)
    # perturb token 0: outputs at positions >= 4 must be unchanged
    x2 = x.at[0, 0].add(10.0)
    out2, _ = A.gqa_attention(p, x2, pos, cfg, window=4)
    np.testing.assert_allclose(np.asarray(out1[0, 4:]),
                               np.asarray(out2[0, 4:]), atol=1e-5)
    assert float(jnp.max(jnp.abs(out1[0, :4] - out2[0, :4]))) > 1e-3
