"""Plan layer: compile-once semantics (trace-counter proofs), operator-
aware auto resolution, cache keying by shape/dtype/kind/mesh, eager
fallbacks, and the ConvergenceInfo diagnostics channel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (RecordingCallback, SVDSpec, clear_plan_cache,
                       factorize, factorize_jit, plan, plan_cache_stats,
                       resolve_method, trace_count)
from repro.core.operators import (DenseOp, GramOp, KroneckerOp, LowRankOp,
                                  SparseOp)

KEY = jax.random.PRNGKey(7)


@pytest.fixture
def compile_counter():
    """Fresh plan cache + a callable returning traces since fixture setup.

    Clearing the cache forces the first post-fixture solve to stage a new
    executable, so `counter() == 1` after two identical solves is a real
    compile-once proof (the trace counter increments inside the traced
    body — it cannot tick without an actual retrace)."""
    clear_plan_cache()
    base = trace_count()
    return lambda: trace_count() - base


@pytest.fixture(scope="module")
def A():
    return make_lowrank(jax.random.PRNGKey(0), 96, 72, 10)


SPEC = SVDSpec(method="fsvd", rank=6, max_iters=24)


def test_compile_once_two_plans(A, compile_counter):
    k1, k2 = jax.random.split(KEY)
    f1 = plan(SPEC, like=A).solve(A, key=k1)
    f2 = plan(SPEC, like=A).solve(A, key=k2)
    assert compile_counter() == 1          # one trace for two plan().solve()
    stats = plan_cache_stats()
    assert stats["hits"] >= 1 and stats["entries"] == 1
    s_true = jnp.linalg.svd(A, compute_uv=False)[:6]
    np.testing.assert_allclose(np.asarray(f1.s), np.asarray(s_true),
                               rtol=1e-3)
    assert f1.s.shape == f2.s.shape


def test_facade_shares_plan_cache(A, compile_counter):
    factorize(A, SPEC, key=KEY)
    factorize(A, SPEC, key=jax.random.fold_in(KEY, 1))
    p = plan(SPEC, like=A)
    p.solve(A, key=jax.random.fold_in(KEY, 2))
    assert compile_counter() == 1


def test_new_shape_or_spec_stages_new_executable(A, compile_counter):
    plan(SPEC, like=A).solve(A, key=KEY)
    assert compile_counter() == 1
    B = make_lowrank(jax.random.PRNGKey(1), 64, 48, 10)
    plan(SPEC, like=B).solve(B, key=KEY)       # new shape
    assert compile_counter() == 2
    plan(SPEC.replace(rank=4), like=A).solve(A, key=KEY)   # new spec
    assert compile_counter() == 3
    # repeats of all three stay cached
    plan(SPEC, like=A).solve(A, key=KEY)
    plan(SPEC, like=B).solve(B, key=KEY)
    plan(SPEC.replace(rank=4), like=A).solve(A, key=KEY)
    assert compile_counter() == 3


def test_operand_kind_keys_cache(A, compile_counter):
    """Same shapes, different operator pytree kind -> different entry."""
    p = plan(SPEC, like=A)
    dense_key = p.operand_key(DenseOp(A))
    pallas_key = p.operand_key(DenseOp(A, backend="pallas"))
    lr = LowRankOp(jnp.ones((96, 2)), jnp.ones((2,)), jnp.ones((2, 72)))
    assert dense_key != pallas_key            # backend is static aux
    assert dense_key != p.operand_key(lr)
    assert dense_key == p.operand_key(DenseOp(A + 1.0))   # values don't key


def test_warm_start_q1_structure_keys_cache(A, compile_counter):
    p = plan(SPEC, like=A)
    f = p.solve(A, key=KEY)
    assert compile_counter() == 1
    p.solve(A, q1=f.warm_start())              # q1 present: new structure
    assert compile_counter() == 2
    p.solve(A, q1=f.warm_start())
    assert compile_counter() == 2


def test_host_loop_spec_falls_back_eager(A, compile_counter):
    spec = SPEC.replace(host_loop=True)
    f = plan(spec, like=A).solve(A, key=KEY)
    assert compile_counter() == 0              # never staged
    assert not plan(spec, like=A).staged
    s_true = jnp.linalg.svd(A, compute_uv=False)[:6]
    np.testing.assert_allclose(np.asarray(f.s), np.asarray(s_true),
                               rtol=1e-3)


def test_legacy_linop_falls_back_eager(A, compile_counter):
    from repro.core.linop import LinOp
    op = LinOp(shape=tuple(A.shape), dtype=A.dtype,
               mv=lambda p: A @ p, rmv=lambda q: A.T @ q)
    f = plan(SPEC, like=op).solve(op, key=KEY)
    assert compile_counter() == 0
    s_true = jnp.linalg.svd(A, compute_uv=False)[:6]
    np.testing.assert_allclose(np.asarray(f.s), np.asarray(s_true),
                               rtol=1e-3)


def test_factorize_jit_handles_share_one_executable(A, compile_counter):
    fn1 = factorize_jit(SPEC)
    fn2 = factorize_jit(SPEC)
    q1 = jnp.ones((A.shape[0],), jnp.float32)
    o1 = fn1(A, KEY, q1)
    o2 = fn2(A, KEY, q1)
    assert compile_counter() == 1
    np.testing.assert_allclose(np.asarray(o1.s), np.asarray(o2.s))


def test_estimate_rank_ingraph_shares_cache(A, compile_counter):
    from repro.api import estimate_rank
    spec = SVDSpec(host_loop=False, max_iters=40)
    e1 = estimate_rank(A, spec, key=KEY)
    e2 = estimate_rank(A, spec, key=jax.random.fold_in(KEY, 1))
    assert compile_counter() == 1
    assert int(e1.rank) == int(e2.rank) == 10


def test_with_info_and_callback(A, compile_counter):
    p = plan(SPEC, like=A)
    cb = RecordingCallback()
    fact, info = p.solve(A, key=KEY, with_info=True, callback=cb)
    assert info.residuals.shape == (24,)       # per-iteration betas
    assert int(info.iterations) == int(fact.iterations)
    assert bool(info.breakdown) == bool(fact.breakdown)
    assert cb.info is not None
    # host-loop path delivers per-step scalars through the same protocol
    cb2 = RecordingCallback()
    factorize(A, SPEC.replace(host_loop=True), key=KEY, callback=cb2)
    assert len(cb2.steps) > 0
    assert all("beta" in m for _, m in cb2.steps)
    assert cb2.info is not None and cb2.info.method == "gk"


def test_auto_resolution_operator_aware(A):
    loose = SVDSpec(method="auto", tol=1e-2)
    # dense heuristic unchanged (spec-only view stays backward compatible)
    assert resolve_method(loose) == "rsvd"
    assert resolve_method(SVDSpec(method="auto")) == "fsvd"
    assert resolve_method(SVDSpec(method="auto", power_iters=2)) == "rsvd"
    # sparse / Gram / Kronecker operands never take the dense-only branch
    sp = SparseOp.fromdense(jnp.eye(8))
    assert resolve_method(loose, sp) == "fsvd_blocked"
    assert resolve_method(loose, GramOp(DenseOp(A))) == "fsvd_blocked"
    assert resolve_method(loose, sp.T) == "fsvd_blocked"
    kron = KroneckerOp(DenseOp(jnp.eye(4)), DenseOp(jnp.eye(5)))
    assert resolve_method(SVDSpec(method="auto", power_iters=3),
                          kron) == "fsvd_blocked"
    # plain dense operands keep the tol/power-iters trade-off heuristic
    assert resolve_method(loose, DenseOp(A)) == "rsvd"
    assert resolve_method(SVDSpec(method="auto"), DenseOp(A)) == "fsvd"
    # auto factorize on a sparse operand runs the blocked solver
    out = factorize(sp, SVDSpec(method="auto", rank=3, tol=1e-2), key=KEY)
    assert out.method == "fsvd_blocked"


def test_auto_resolution_normalizes_non_operators(A):
    """Regression: resolve_method used to duck-type with hasattr(like,
    'mv'), so a NON-operator operand carrying a stray ``mv`` attribute
    skipped ``as_operator`` normalization and took the spec-only dense
    branch.  Anything that is not already an Operator must be normalized
    first, so operand-aware routing sees the real operator kind."""
    class _ArrayWithStrayMv(np.ndarray):
        # not an Operator: `mv` here is unrelated to the matvec protocol
        def mv(self):                          # pragma: no cover
            return "not a matvec"

    arr = np.asarray(A).view(_ArrayWithStrayMv)
    loose = SVDSpec(method="auto", tol=1e-2)
    # normalized through as_operator -> DenseOp -> dense heuristic
    assert resolve_method(loose, arr) == "rsvd"
    assert resolve_method(SVDSpec(method="auto"), arr) == "fsvd"


def test_auto_resolution_single_pass_hint(A):
    """Operators flagged single_pass_only route to the one-sweep solver
    before any other operand-aware branch."""
    from repro.api import SinglePassOp
    op = SinglePassOp(DenseOp(A))
    assert resolve_method(SVDSpec(method="auto"), op) == "gnystrom"
    # the hint outranks the loose-tol dense heuristic too
    assert resolve_method(SVDSpec(method="auto", tol=1e-2),
                          op) == "gnystrom"
    out = factorize(op, SVDSpec(method="auto", rank=4), key=KEY)
    assert out.method == "gnystrom"
    s_true = jnp.linalg.svd(A, compute_uv=False)[:4]
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=1e-2)


def test_compile_once_sketch_solvers(A, compile_counter):
    """rbk and gnystrom stage through the plan cache with the same
    compile-once contract as fsvd/rsvd: two solves, one trace each."""
    rbk_spec = SVDSpec(method="rbk", rank=6, passes=3)
    gny_spec = SVDSpec(method="gnystrom", rank=6)
    k1, k2 = jax.random.split(KEY)
    f1 = plan(rbk_spec, like=A).solve(A, key=k1)
    f2 = plan(rbk_spec, like=A).solve(A, key=k2)
    assert compile_counter() == 1
    g1 = plan(gny_spec, like=A).solve(A, key=k1)
    g2 = plan(gny_spec, like=A).solve(A, key=k2)
    assert compile_counter() == 2
    s_true = jnp.linalg.svd(A, compute_uv=False)[:6]
    for f in (f1, f2, g1, g2):
        np.testing.assert_allclose(np.asarray(f.s), np.asarray(s_true),
                                   rtol=1e-2)


@pytest.mark.distributed
def test_auto_resolves_sharded_and_mesh_keys_cache(A, mesh8):
    import repro.distributed.gk_dist  # noqa: F401  (registers solver)
    from repro.distributed.matvec import sharded_operator
    from repro.launch.mesh import make_mesh
    op8 = sharded_operator(A, mesh8)
    assert resolve_method(SVDSpec(method="auto", tol=1e-2),
                          op8) == "fsvd_sharded"
    # the mesh is part of the operand cache key: same payload shapes on a
    # different mesh factorization must NOT share an executable
    mesh24 = make_mesh((2, 4), ("data", "model"))
    op24 = sharded_operator(A, mesh24)
    p = plan(SVDSpec(method="fsvd_sharded", rank=4), like=op8)
    k8, k24 = p.operand_key(op8), p.operand_key(op24)
    assert k8 is not None and k24 is not None and k8 != k24


@pytest.mark.distributed
def test_sharded_compile_once(A, mesh8, compile_counter):
    import repro.distributed.gk_dist  # noqa: F401
    from repro.distributed.matvec import sharded_operator
    op = sharded_operator(A, mesh8)
    spec = SVDSpec(method="fsvd_sharded", rank=4, max_iters=20)
    f1 = plan(spec, like=op).solve(op, key=KEY)
    f2 = plan(spec, like=op).solve(op, key=jax.random.fold_in(KEY, 1))
    assert compile_counter() == 1
    s_true = jnp.linalg.svd(A, compute_uv=False)[:4]
    np.testing.assert_allclose(np.asarray(f1.s), np.asarray(s_true),
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f2.s), np.asarray(s_true),
                               rtol=1e-3)


def test_warm_start_stays_compute_dtype_under_bf16(A):
    """bf16 storage must not leak into the warm-start seam: the blocked
    solver keeps its locked U half-width, and a q1 inheriting that dtype
    would seed the next solve's CGS2 at the bf16 noise floor."""
    out = factorize(A, SVDSpec(method="fsvd_blocked", rank=4,
                               precision="bf16"), key=KEY)
    assert out.U.dtype == jnp.bfloat16       # storage stays narrow
    q1 = out.warm_start()
    assert q1.dtype == jnp.float32           # the blend must not
    # and the warm-started follow-up accepts it
    nxt = factorize(A, SVDSpec(method="fsvd", rank=4, max_iters=16), q1=q1)
    assert nxt.s.shape == (4,)
