"""Hypothesis property battery for the operator-algebra laws.

Adjoint consistency, transpose involution, linearity and pytree round-trips
(through ``jit`` and ``vmap``) hold for *every* operator class — including
the matrix-free ``SparseOp`` / ``KroneckerOp`` / ``GramOp`` — on random
shapes and seeds, not just the fixed cases of ``test_operators.py``.

Skips cleanly when hypothesis is absent (dev/CI requirement, see
requirements-dev.txt).  CI runs it in a dedicated job under the ``ci``
profile registered below (fixed derandomized seed, more examples).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property battery needs hypothesis (dev req)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.operators import (DenseOp, GramOp, KroneckerOp, LowRankOp,  # noqa: E402
                                  ScaledOp, SparseOp, SumOp, TransposedOp,
                                  to_dense)

# the active profile ("ci" / "dev", registered in conftest.py) is picked by
# the HYPOTHESIS_PROFILE environment variable — CI sets "ci"

OP_KINDS = ("dense", "lowrank", "sparse", "kron", "gram", "sum",
            "scaled", "transposed")


def _make_op(kind: str, m: int, n: int, seed: int):
    """Build an operator of ``kind`` with an exact dense oracle."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (m, n))
    if kind == "dense":
        return DenseOp(A), A
    if kind == "lowrank":
        r = max(min(m, n) // 2, 1)
        U = jnp.linalg.qr(jax.random.normal(ks[1], (m, r)))[0]
        s = jnp.abs(jax.random.normal(ks[2], (r,))) + 0.1
        Vt = jnp.linalg.qr(jax.random.normal(ks[3], (n, r)))[0].T
        return LowRankOp(U, s, Vt), (U * s[None, :]) @ Vt
    if kind == "sparse":
        mask = jax.random.bernoulli(ks[1], 0.3, (m, n))
        S = jnp.where(mask, A, 0.0)
        return SparseOp.fromdense(S), S
    if kind == "kron":
        B = jax.random.normal(ks[1], (max(m // 2, 1), max(n // 2, 1)))
        C = jax.random.normal(ks[2], (2, 2))
        return (KroneckerOp(DenseOp(B), DenseOp(C)),
                jnp.kron(B, C))
    if kind == "gram":
        return GramOp(DenseOp(A)), A.T @ A
    if kind == "sum":
        B = jax.random.normal(ks[1], (m, n))
        return SumOp((DenseOp(A), DenseOp(B))), A + B
    if kind == "scaled":
        return ScaledOp(-1.7, DenseOp(A)), -1.7 * A
    if kind == "transposed":
        return TransposedOp(DenseOp(A)), A.T
    raise AssertionError(kind)


dims = st.integers(2, 12)
seeds = st.integers(0, 2**31 - 1)
kinds = st.sampled_from(OP_KINDS)


def _close(x, y, tol=1e-4):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=tol, atol=tol)


@settings(deadline=None)
@given(kinds, dims, dims, seeds)
def test_adjoint_consistency(kind, m, n, seed):
    """⟨Aᵀy, x⟩ == ⟨y, Ax⟩ for every operator kind."""
    op, _ = _make_op(kind, m, n, seed)
    om, on = op.shape
    kx, ky = jax.random.split(jax.random.PRNGKey(seed ^ 0x5EED), 2)
    x = jax.random.normal(kx, (on,))
    y = jax.random.normal(ky, (om,))
    lhs = jnp.vdot(op.T @ y, x)
    rhs = jnp.vdot(y, op @ x)
    scale = float(jnp.abs(rhs)) + float(jnp.linalg.norm(x)
                                        * jnp.linalg.norm(y)) + 1e-6
    assert abs(float(lhs - rhs)) / scale < 1e-4


@settings(deadline=None)
@given(kinds, dims, dims, seeds)
def test_transpose_involution(kind, m, n, seed):
    op, dense = _make_op(kind, m, n, seed)
    _close(to_dense(op.T.T), dense)
    _close(to_dense(op.T), dense.T)


@settings(deadline=None)
@given(kinds, kinds, dims, dims, seeds, st.floats(-3, 3))
def test_linearity(kind_a, kind_b, m, n, seed, alpha):
    """(A + αB) x == A x + α (B x) — SumOp/ScaledOp distribute exactly."""
    op_a, da = _make_op(kind_a, m, n, seed)
    # force matching shapes: rebuild b on a's shape
    am, an = op_a.shape
    op_b, db = _make_op(kind_b if kind_b not in ("kron",) else "dense",
                        am, an, seed + 1)
    if op_b.shape != (am, an):       # gram/transposed reshape their input
        op_b, db = _make_op("dense", am, an, seed + 1)
    x = jax.random.normal(jax.random.PRNGKey(seed ^ 0xA11CE), (an,))
    combo = op_a + alpha * op_b
    _close(combo @ x, (op_a @ x) + alpha * (op_b @ x), tol=1e-3)
    _close(to_dense(combo), da + alpha * db, tol=1e-3)


@settings(deadline=None)
@given(kinds, dims, dims, seeds)
def test_pytree_roundtrip_and_jit(kind, m, n, seed):
    """flatten→unflatten is the identity, and the operator crosses a jit
    boundary as a pytree argument with the same matvec."""
    op, dense = _make_op(kind, m, n, seed)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(op2) is type(op)
    _close(to_dense(op2), dense)

    x = jax.random.normal(jax.random.PRNGKey(seed ^ 0xBEEF), (op.shape[1],))

    @jax.jit
    def apply(o, v):
        return o.mv(v)

    _close(apply(op, x), dense @ x, tol=1e-3)


@settings(deadline=None)
@given(st.sampled_from(("dense", "lowrank", "sparse")), dims, dims, seeds)
def test_vmap_over_stacked_vectors(kind, m, n, seed):
    """vmap of the matvec over a batch of vectors == matmat against the
    stacked matrix (the transform path the facade's batched solve uses)."""
    op, dense = _make_op(kind, m, n, seed)
    X = jax.random.normal(jax.random.PRNGKey(seed ^ 0xF00D),
                          (3, op.shape[1]))
    got = jax.vmap(op.mv)(X)
    _close(got, X @ dense.T, tol=1e-3)


@settings(deadline=None)
@given(dims, dims, dims, dims, seeds)
def test_kron_mixed_factors(ma, na, mb, nb, seed):
    """KroneckerOp over arbitrary (sparse ⊗ dense) factor shapes matches
    jnp.kron exactly."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jnp.where(jax.random.bernoulli(k1, 0.5, (ma, na)),
                  jax.random.normal(k2, (ma, na)), 0.0)
    B = jax.random.normal(k3, (mb, nb))
    op = KroneckerOp(SparseOp.fromdense(A), DenseOp(B))
    _close(to_dense(op), jnp.kron(A, B), tol=1e-3)
    x = jax.random.normal(jax.random.PRNGKey(seed ^ 1), (na * nb,))
    _close(op @ x, jnp.kron(A, B) @ x, tol=1e-3)


@settings(deadline=None)
@given(dims, dims, seeds)
def test_gram_sides_consistent(m, n, seed):
    """GramOp("ata") of A equals GramOp("aat") of Aᵀ, and both are PSD."""
    op, dense = _make_op("dense", m, n, seed)
    g1 = to_dense(GramOp(op, side="ata"))
    g2 = to_dense(GramOp(op.T, side="aat"))
    _close(g1, g2, tol=1e-3)
    w = jnp.linalg.eigvalsh(g1)
    assert float(w.min()) > -1e-3 * max(float(w.max()), 1.0)
