"""Algorithm 4 (RSGD for similarity learning): convergence + variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manifold as mf
from repro.core import rsgd
from repro.data.synthetic import make_rsl_dataset, rsl_batch


def _train(opts, steps=60, d1=24, d2=30, rank=3, n=512, batch=64, seed=0):
    key = jax.random.PRNGKey(seed)
    ds = make_rsl_dataset(key, n, d1, d2, rank, noise=0.0)
    W = mf.random_point(jax.random.fold_in(key, 1), d1, d2, rank)
    losses = []
    for t in range(steps):
        b = rsl_batch(ds, seed, t, batch)
        W, loss = rsgd.rsgd_step(W, b["x"], b["v"], b["y"], opts,
                                 key=jax.random.fold_in(key, t))
        losses.append(float(loss))
    acc = float(rsgd.accuracy(W, ds.X, ds.V, ds.y))
    return losses, acc, W


def test_rsgd_converges_fsvd_retraction():
    # lr tuned for the d^0.25-normalized synthetic domains (see fig2)
    losses, acc, _ = _train(rsgd.RSGDOptions(lr=3.0, fsvd_iters=15),
                            steps=120)
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5])
    assert acc > 0.9


def test_rsgd_qr_and_fsvd_match():
    """Same trajectory under both retractions (they compute the same map)."""
    o1 = rsgd.RSGDOptions(lr=0.05, retraction="qr")
    o2 = rsgd.RSGDOptions(lr=0.05, retraction="fsvd", fsvd_iters=25)
    l1, a1, W1 = _train(o1, steps=20)
    l2, a2, W2 = _train(o2, steps=20)
    np.testing.assert_allclose(l1, l2, rtol=0.05, atol=0.02)
    np.testing.assert_allclose(np.asarray(mf.to_dense(W1)),
                               np.asarray(mf.to_dense(W2)), atol=0.05)


def test_rsgd_paper_literal_projection_variant():
    losses, acc, _ = _train(
        rsgd.RSGDOptions(lr=1.0, fsvd_iters=15, project_at="grad"), steps=80)
    assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:5])


def test_rsgd_logistic_loss():
    losses, acc, _ = _train(
        rsgd.RSGDOptions(lr=1.0, loss="logistic", fsvd_iters=15))
    assert np.mean(losses[-10:]) < np.mean(losses[:5])


def test_rank_preserved():
    _, _, W = _train(rsgd.RSGDOptions(lr=0.1, fsvd_iters=15), steps=10)
    assert W.rank == 3
    assert float(jnp.min(W.s)) > 0


def test_weight_decay_shrinks_spectrum():
    o_plain = rsgd.RSGDOptions(lr=0.05)
    o_decay = rsgd.RSGDOptions(lr=0.05, weight_decay=0.5)
    _, _, W1 = _train(o_plain, steps=30, seed=3)
    _, _, W2 = _train(o_decay, steps=30, seed=3)
    assert float(W2.s.sum()) < float(W1.s.sum())


def test_batch_grad_matches_dense():
    """Implicit batch-gradient operator == explicit dense gradient."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    Xb = jax.random.normal(ks[0], (16, 10))
    Vb = jax.random.normal(ks[1], (16, 12))
    W = mf.random_point(ks[2], 10, 12, 3)
    y = jnp.sign(jax.random.normal(ks[3], (16,)))
    bg = rsgd.batch_euclidean_grad(W, Xb, Vb, y, "hinge", 0.0)

    def dense_loss(Wd):
        yhat = jnp.einsum("bi,ij,bj->b", Xb, Wd, Vb)
        return jnp.maximum(1.0 - y * yhat, 0.0).mean()

    G = jax.grad(dense_loss)(mf.to_dense(W))
    from repro.core.linop import to_dense as linop_dense
    np.testing.assert_allclose(np.asarray(linop_dense(bg.op)),
                               np.asarray(G), atol=1e-5)
