"""Hypothesis property sweep for Algorithm 3 over random (m, n, rank).

Skips cleanly when hypothesis is absent (it is a dev/CI requirement, see
requirements-dev.txt) — the deterministic rank tests live in test_rank.py.
"""
import jax
import pytest

pytest.importorskip(
    "hypothesis", reason="property sweep needs hypothesis (dev requirement)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import make_lowrank  # noqa: E402
from repro.core import numerical_rank  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(st.integers(20, 90), st.integers(20, 90), st.integers(1, 15),
       st.integers(0, 2**31 - 1))
def test_rank_property(m, n, rank, seed):
    """Property: rank(M @ N) == rank for random Gaussian factors (full rank
    factors w.p. 1), detected exactly by Alg 3."""
    rank = min(rank, m, n)
    A = make_lowrank(jax.random.PRNGKey(seed), m, n, rank)
    out = numerical_rank(A)
    assert int(out.rank) == rank
