"""Operator-algebra laws: adjoint/compose/scale identities, pytree
round-trips, and vmap-batched factorization through the facade."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import SVDSpec, factorize
from repro.core.operators import (DenseOp, LowRankOp, ScaledOp, SumOp,
                                  TransposedOp, as_operator, to_dense)


@pytest.fixture()
def ops(rng):
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    A = jax.random.normal(k1, (30, 20))
    B = jax.random.normal(k2, (30, 20))
    U = jnp.linalg.qr(jax.random.normal(k3, (30, 4)))[0]
    V = jnp.linalg.qr(jax.random.normal(k4, (20, 4)))[0]
    s = jnp.sort(jax.random.uniform(k5, (4,)) + 0.5)[::-1]
    return {
        "A": DenseOp(A), "B": DenseOp(B),
        "L": LowRankOp(U, s, V.T),
        "Ad": A, "Bd": B, "Ld": (U * s[None, :]) @ V.T,
    }


def _close(x, y, tol=1e-5):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=tol, atol=tol)


def test_adjoint_law(ops):
    for name in ("A", "L"):
        op = ops[name]
        _close(to_dense(op.T), to_dense(op).T)


def test_double_transpose_identity(ops):
    op = SumOp((ops["A"], ops["L"]))
    _close(to_dense(op.T.T), to_dense(op))
    # generic TransposedOp unwraps to the inner operator
    t = TransposedOp(op)
    assert t.T is op


def test_sum_and_scale_roundtrip(ops):
    _close(to_dense(ops["A"] + ops["B"]), ops["Ad"] + ops["Bd"])
    _close(to_dense(2.5 * ops["A"]), 2.5 * ops["Ad"])
    _close(to_dense((ops["A"] + ops["L"]).T),
           (ops["Ad"] + ops["Ld"]).T)
    _close(to_dense(ops["A"] - ops["B"]), ops["Ad"] - ops["Bd"])
    combo = 2.0 * ops["A"] + (-1.0) * ops["L"]
    _close(to_dense(combo.T), (2.0 * ops["Ad"] - ops["Ld"]).T)


def test_matmul_sugar(ops, rng):
    p = jax.random.normal(rng, (20,))
    P = jax.random.normal(rng, (20, 3))
    _close(ops["A"] @ p, ops["Ad"] @ p)
    _close(ops["A"] @ P, ops["Ad"] @ P)
    _close(ops["L"].T @ jnp.ones(30), ops["Ld"].T @ jnp.ones(30))


def test_fused_forms_match_compose(ops, rng):
    p = jax.random.normal(rng, (20,))
    y = jax.random.normal(jax.random.PRNGKey(7), (30,))
    for op, d in ((ops["A"], ops["Ad"]), (ops["L"], ops["Ld"])):
        _close(op.mv_fused(p, y, 0.7), d @ p - 0.7 * y)
        _close(op.rmv_fused(y, p, 0.3), d.T @ y - 0.3 * p)


def test_pytree_flatten_unflatten_identity(ops):
    op = 0.5 * SumOp((ops["A"], ops["L"])).T
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(op2) is type(op)
    _close(to_dense(op2), to_dense(op))
    # leaves survive a tree_map (e.g. what jit/donation machinery does)
    op3 = jax.tree_util.tree_map(lambda x: x, op)
    _close(to_dense(op3), to_dense(op))


def test_dense_backend_meta_is_static(ops):
    op = DenseOp(ops["Ad"], backend="pallas")
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert len(leaves) == 1          # backend rides in aux, not as a leaf
    assert jax.tree_util.tree_unflatten(treedef, leaves).backend == "pallas"


def test_operator_crosses_jit_boundary(ops, rng):
    p = jax.random.normal(rng, (20,))

    @jax.jit
    def apply(op, x):
        return op.mv(x)

    combo = ops["A"] + 2.0 * ops["L"]
    _close(apply(combo, p), ops["Ad"] @ p + 2.0 * (ops["Ld"] @ p))


def test_as_operator_coercion(ops):
    assert as_operator(ops["A"]) is ops["A"]
    got = as_operator(ops["Ad"], backend="pallas")
    assert isinstance(got, DenseOp) and got.backend == "pallas"
    with pytest.raises(ValueError):
        as_operator(ops["Ad"], backend="mosaic")


def test_scaled_op_traced_alpha(ops, rng):
    p = jax.random.normal(rng, (20,))

    def f(a):
        return ScaledOp(a, ops["A"]).mv(p).sum()

    g = jax.grad(f)(2.0)             # alpha is a leaf -> differentiable
    _close(g, (ops["Ad"] @ p).sum(), tol=1e-4)


def test_vmap_batched_factorize_matches_loop(rng):
    keys = jax.random.split(rng, 3)
    As = jnp.stack([make_lowrank(k, 60, 40, 8) for k in keys])
    spec = SVDSpec(method="fsvd", rank=5, max_iters=32)
    key = jax.random.PRNGKey(42)
    batched = jax.vmap(
        lambda op: factorize(op, spec, key=key))(DenseOp(As))
    assert batched.s.shape == (3, 5)
    for i in range(3):
        single = factorize(DenseOp(As[i]), spec, key=key)
        np.testing.assert_allclose(np.asarray(batched.s[i]),
                                   np.asarray(single.s), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(jnp.abs(jnp.sum(batched.V[i] * single.V, axis=0))),
            np.ones(5), atol=5e-3)
