"""Algorithm 2 (F-SVD) against the dense SVD oracle + the paper's Table-2
error metrics and Figure-1 triplet-quality diagnostic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.core.fsvd import fsvd, truncated_svd_errors
from repro.core.operators import DenseOp, LowRankOp
from repro.core.rsvd import rsvd


@pytest.mark.parametrize("host", [False, True])
@pytest.mark.parametrize("m,n,rank,r", [(200, 150, 30, 10), (120, 160, 20, 20)])
def test_fsvd_matches_dense_svd(rng, host, m, n, rank, r):
    A = make_lowrank(rng, m, n, rank)
    out = fsvd(A, r, 4 * rank, host_loop=host)
    U, s, Vt = jnp.linalg.svd(A, full_matrices=False)
    scale = float(s[0])
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s[:r]),
                               rtol=1e-4, atol=1e-5 * scale)
    # triplet quality (paper Fig 1): |u_i . u_i_svd| * |v_i . v_i_svd| ~ 1
    qual = np.abs(np.sum(np.asarray(out.U) * np.asarray(U[:, :r]), 0)) \
        * np.abs(np.sum(np.asarray(out.V) * np.asarray(Vt[:r].T), 0))
    np.testing.assert_allclose(qual, np.ones(r), atol=5e-3)


def test_table2_error_metrics(rng):
    """Relative error ||A^T U − V Σ||_F/||Σ||_F at machine-precision level
    (paper Table 2 reports ~1e-16/1e-17 in float64; f32 scale here)."""
    A = make_lowrank(rng, 300, 200, 40)
    out = fsvd(A, 20, 160, host_loop=True)
    errs = truncated_svd_errors(A, out)
    assert float(errs["relative"]) < 5e-6
    # rank-r residual == optimal Eckart-Young residual for r >= rank: here
    # r < rank so compare against the dense-SVD truncation residual.
    s = jnp.linalg.svd(A, compute_uv=False)
    opt = float(jnp.sqrt(jnp.sum(s[20:] ** 2)))
    assert float(errs["residual"]) < opt * 1.01 + 1e-3


def test_fsvd_full_rank_recovery(rng):
    """r == rank(A): reconstruction is exact (residual ~ 0)."""
    A = make_lowrank(rng, 150, 100, 12)
    out = fsvd(A, 12, 60, host_loop=True)
    errs = truncated_svd_errors(A, out)
    assert float(errs["residual"]) < 1e-2 * float(jnp.linalg.norm(A))


def test_fsvd_on_implicit_operator(rng):
    """The RSL path: operator given only by factors (never densified)."""
    k1, k2, k3 = jax.random.split(rng, 3)
    U = jnp.linalg.qr(jax.random.normal(k1, (120, 6)))[0]
    Vt = jnp.linalg.qr(jax.random.normal(k2, (80, 6)))[0].T
    s = jnp.sort(jax.random.uniform(k3, (6,)) + 0.5)[::-1]
    op = LowRankOp(U, s, Vt)
    out = fsvd(op, 6, 30)
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s), rtol=1e-4)


def test_fsvd_with_pallas_kernels(rng):
    A = make_lowrank(rng, 256, 192, 15)
    out_k = fsvd(DenseOp(A, backend="pallas"), 8, 60, host_loop=True)
    out_p = fsvd(DenseOp(A, backend="xla"), 8, 60, host_loop=True)
    np.testing.assert_allclose(np.asarray(out_k.s), np.asarray(out_p.s),
                               rtol=1e-4)


def test_fsvd_beats_default_rsvd_in_tail(rng):
    """Paper §6.2 / Fig 1: with slow-ish spectrum decay, default-p R-SVD
    degrades in the tail of the requested triplets while F-SVD stays at
    dense-SVD quality."""
    m, n, rank, r = 300, 300, 100, 60
    A = make_lowrank(rng, m, n, rank)
    s_true = jnp.linalg.svd(A, compute_uv=False)[:r]
    f = fsvd(A, r, 300, host_loop=True)
    rs = rsvd(A, r, p=10)
    err_f = float(jnp.max(jnp.abs(f.s - s_true) / s_true))
    err_r = float(jnp.max(jnp.abs(rs.s - s_true) / s_true))
    assert err_f < 1e-3
    assert err_r > 10 * err_f   # R-SVD default-p visibly worse in the tail


def test_legacy_linop_shims_warn_and_work(rng):
    """The PR-1 shims stay functional but warn with the repo-local
    deprecation category CI escalates to an error (-W error::...), so
    in-repo call sites cannot silently regrow."""
    from repro.compat import ReproDeprecationWarning
    from repro.core.linop import from_dense, from_factors
    A = make_lowrank(rng, 40, 30, 5)
    with pytest.warns(ReproDeprecationWarning):
        op = from_dense(A)
    assert isinstance(op, DenseOp)
    with pytest.warns(ReproDeprecationWarning):
        lo = from_factors(jnp.ones((6, 2)), jnp.ones((2,)),
                          jnp.ones((2, 5)))
    assert isinstance(lo, LowRankOp)
