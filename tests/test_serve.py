"""Serve subsystem: bucket transport bit-identity on the parity zoo, the
continuous batcher's lifecycle contracts (coalescing, backpressure,
cancellation, drain), end-to-end server correctness at equal accuracy,
tenant warm paths (strictly fewer GK iterations than cold), and the stats
endpoint ground-truthed against the plan-cache counters."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (SVDSpec, clear_plan_cache, plan, plan_cache_stats,
                       trace_count)
from repro.serve import (Cancelled, ContinuousBatcher, QueueFull,
                         SolveServer, bucket_shape, embed, unpad_factors)
from repro.serve.traffic import (entry_drift, lowrank_drift,
                                 lowrank_operand, synthetic_stream)
from test_solver_parity import ZOO

KEY = jax.random.PRNGKey(3)
SPEC = SVDSpec(method="fsvd", rank=8, max_iters=48)
SERVE_SPEC = SVDSpec(method="fsvd", rank=4, max_iters=24)


# ---------------------------------------------------------------------------
# bucketing: padding is transport, never arithmetic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_padded_solve_bit_identical_on_zoo(name):
    """The exact-mode contract: embedding into a bucket and extracting
    back feeds the solver the caller's bytes — σ is bit-identical, not
    merely close."""
    A, _ = ZOO[name]
    b = embed(A, 32)
    assert b.bucket == bucket_shape(A.shape, 32)
    assert tuple(b.data.shape) == b.bucket
    back = b.extract()
    np.testing.assert_array_equal(back, np.asarray(A))
    # the padded region is zero, the logical region untouched
    m, n = b.logical_shape
    assert not np.any(np.asarray(b.data)[m:, :])
    assert not np.any(np.asarray(b.data)[:, n:])
    p = plan(SPEC, like=A)
    s_direct = np.asarray(p.solve(A, key=KEY).s)
    s_roundtrip = np.asarray(p.solve(back, key=KEY).s)
    np.testing.assert_array_equal(s_direct, s_roundtrip)


def test_shared_mode_solves_bucket_at_roundoff():
    """mode="shared" solves the zero-padded bucket: zero rows/cols leave
    the singular values mathematically unchanged, so σ agrees with the
    logical solve to f32 roundoff and unpad_factors restores the logical
    factor shapes."""
    A, _ = ZOO["lowrank_noise"]
    b = embed(A, 32)
    padded = np.asarray(b.data)
    fact = plan(SPEC, like=padded).solve(padded, key=KEY)
    fact = unpad_factors(fact, b.logical_shape)
    m, n = b.logical_shape
    assert fact.U.shape[-2] == m and fact.V.shape[-2] == n
    s_direct = np.asarray(plan(SPEC, like=A).solve(A, key=KEY).s)
    err = np.max(np.abs(np.asarray(fact.s) - s_direct)) / s_direct[0]
    assert err < 5e-5


# ---------------------------------------------------------------------------
# the continuous batcher (no solver involved)
# ---------------------------------------------------------------------------

def _recording_batcher(**kw):
    batches = []

    def dispatch(group, tickets):
        batches.append((group, [t.payload for t in tickets]))
        for t in tickets:
            t._resolve(len(tickets))

    return ContinuousBatcher(dispatch, **kw), batches


def test_batcher_flushes_at_max_batch():
    b, batches = _recording_batcher(max_batch=4, window_ms=500.0,
                                    max_queue=64)
    try:
        tickets = [b.submit("g", i) for i in range(4)]
        # window is 500ms: only the max_batch trigger can flush this fast
        assert [t.result(timeout=5.0) for t in tickets] == [4, 4, 4, 4]
        assert batches == [("g", [0, 1, 2, 3])]
    finally:
        b.stop()


def test_batcher_window_flush_keeps_groups_separate():
    b, batches = _recording_batcher(max_batch=8, window_ms=10.0,
                                    max_queue=64)
    try:
        ta = [b.submit("a", i) for i in range(2)]
        tb = b.submit("b", 9)
        assert [t.result(timeout=5.0) for t in ta] == [2, 2]
        assert tb.result(timeout=5.0) == 1
        assert sorted(g for g, _ in batches) == ["a", "b"]
        assert dict(batches) == {"a": [0, 1], "b": [9]}
    finally:
        b.stop()


@pytest.fixture
def blocked_batcher():
    """A batcher whose worker is parked inside a dispatch until released;
    yields (batcher, started_event, release_event, seen_payloads)."""
    started, release = threading.Event(), threading.Event()
    seen = []

    def dispatch(group, tickets):
        seen.extend(t.payload for t in tickets)
        started.set()
        release.wait(timeout=30)
        for t in tickets:
            t._resolve("ok")

    b = ContinuousBatcher(dispatch, max_batch=1, window_ms=1.0, max_queue=3)
    yield b, started, release, seen
    release.set()
    b.stop()


def test_batcher_backpressure_rejects_not_buffers(blocked_batcher):
    b, started, release, _ = blocked_batcher
    blocker = b.submit("g", "blocker")
    assert started.wait(timeout=5.0)
    queued = [b.submit("g", i) for i in range(3)]     # fills max_queue
    with pytest.raises(QueueFull):
        b.submit("g", "overflow")
    release.set()
    assert blocker.result(timeout=5.0) == "ok"
    assert [t.result(timeout=5.0) for t in queued] == ["ok"] * 3


def test_batcher_cancel_never_reaches_dispatch(blocked_batcher):
    b, started, release, seen = blocked_batcher
    b.submit("g", "blocker")
    assert started.wait(timeout=5.0)
    victim = b.submit("g", "victim")
    assert victim.cancel() is True
    assert victim.cancel() is False                   # already done
    with pytest.raises(Cancelled):
        victim.result(timeout=5.0)
    release.set()
    b.stop()
    assert "victim" not in seen


def test_batcher_result_timeout(blocked_batcher):
    b, started, _, _ = blocked_batcher
    b.submit("g", "blocker")
    assert started.wait(timeout=5.0)
    waiting = b.submit("g", "later")
    with pytest.raises(TimeoutError):
        waiting.result(timeout=0.05)
    assert not waiting.done                           # timeout != cancel


def test_batcher_stop_drains_queued_work():
    b, batches = _recording_batcher(max_batch=8, window_ms=200.0,
                                    max_queue=64)
    tickets = [b.submit("g", i) for i in range(5)]
    b.stop(timeout=10.0)                # drain flushes before the window
    # every queued request is served (batch composition during a drain is
    # timing-dependent — the contract is completeness, not coalescing)
    for t in tickets:
        assert isinstance(t.result(timeout=0.1), int)
    assert sorted(p for _, ps in batches for p in ps) == [0, 1, 2, 3, 4]
    with pytest.raises(RuntimeError):
        b.submit("g", 99)


def test_batcher_resolve_cancel_race_exactly_one_wins(blocked_batcher):
    """Regression: a client cancel racing the worker's resolve must pick
    exactly one winner — never a resolved ticket that also reports
    ``cancelled``, never a lost slot."""
    b, started, release, _ = blocked_batcher
    b.submit("g", "blocker")
    assert started.wait(timeout=5.0)
    for trial in range(50):
        t = b.submit("g", trial)
        outcome = {}
        barrier = threading.Barrier(2)

        def do_cancel():
            barrier.wait()
            outcome["cancel"] = t.cancel()

        def do_resolve():
            barrier.wait()
            t._resolve("solved")

        th = [threading.Thread(target=do_cancel),
              threading.Thread(target=do_resolve)]
        for x in th:
            x.start()
        for x in th:
            x.join()
        assert t.done
        if outcome["cancel"]:
            # cancel won: the result path must raise Cancelled forever
            with pytest.raises(Cancelled):
                t.result(timeout=0.0)
            assert t.cancelled
        else:
            # resolve won: the cancel was truthful about losing
            assert t.result(timeout=0.0) == "solved"
            assert not t.cancelled
        t._release_slot()        # the worker never flushes these tickets
    # every trial slot came back exactly once (the parked blocker's slot
    # was already released at flush time) — no leak, no double-decrement
    assert b.pending == 0
    release.set()


def test_batcher_cancel_frees_backpressure_slot(blocked_batcher):
    """Regression: cancelled tickets must give their queue slot back at
    cancel time, not at the next flush — otherwise a burst of cancels
    wedges the intake at max_queue."""
    b, started, release, _ = blocked_batcher
    b.submit("g", "blocker")
    assert started.wait(timeout=5.0)
    victims = [b.submit("g", i) for i in range(3)]   # max_queue reached
    with pytest.raises(QueueFull):
        b.submit("g", "overflow")
    for v in victims:
        assert v.cancel() is True
        assert v.cancel() is False                   # idempotent
    assert b.pending == 0                            # all slots returned
    # the freed slots are immediately usable while the worker is parked
    replacements = [b.submit("g", f"r{i}") for i in range(3)]
    release.set()
    for t in replacements:
        assert t.result(timeout=5.0) == "ok"
    b.stop()
    assert b.pending == 0                            # never negative, drained


def test_batcher_dispatch_error_fails_whole_batch():
    def dispatch(group, tickets):
        raise ValueError("solver exploded")

    b = ContinuousBatcher(dispatch, max_batch=2, window_ms=1.0,
                          max_queue=8)
    try:
        t1, t2 = b.submit("g", 1), b.submit("g", 2)
        for t in (t1, t2):
            with pytest.raises(ValueError, match="solver exploded"):
                t.result(timeout=5.0)
    finally:
        b.stop()


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

def test_server_end_to_end_warm_traffic_compiles_nothing():
    """After warmup, anonymous traffic adds ZERO plan-cache traces — the
    deterministic-staging contract — and the stats endpoint's bucket hit
    rate / counters agree with the plan-cache ground truth."""
    shapes = ((48, 32), (40, 24))
    reqs = list(synthetic_stream(24, shapes=shapes, rank=4, tenants=0,
                                 seed=3))
    with SolveServer(SERVE_SPEC, max_batch=2, window_ms=2.0,
                     key=jax.random.key(1)) as server:
        server.warmup(shapes)
        before, t_before = plan_cache_stats(), trace_count()
        tickets = [server.submit(r.A) for r in reqs]
        results = [t.result(timeout=120.0) for t in tickets]
        server.batcher.stop()           # settle worker-side accounting
        after, stats = plan_cache_stats(), server.stats()
    assert trace_count() == t_before
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]
    assert stats["bucket_hit_rate"] == 1.0
    assert stats["submitted"] == stats["completed"] == len(reqs)
    assert stats["errors"] == 0
    assert sum(int(k) * v for k, v in stats["batch_histogram"].items()) \
        == len(reqs)
    # equal accuracy: σ tracks dense SVD on every served request
    for r, res in zip(reqs, results):
        s_true = np.linalg.svd(np.asarray(r.A), compute_uv=False)[:4]
        err = np.max(np.abs(np.asarray(res.value.s) - s_true)) / s_true[0]
        assert err < 1e-2
        assert res.value.U.shape == (r.shape[0], 4)
        assert res.value.V.shape == (r.shape[1], 4)


def test_tenant_repeat_requests_strictly_fewer_iterations():
    rng = np.random.default_rng(0)
    base = lowrank_operand(rng, (48, 32), 4)
    with SolveServer(SERVE_SPEC, max_batch=2, window_ms=2.0,
                     key=jax.random.key(2)) as server:
        metas = []
        for _ in range(3):
            A = base + 1e-4 * rng.standard_normal(
                base.shape).astype(np.float32)
            res = server.solve(A, tenant="acme", timeout=120.0)
            assert res.kind == "tenant"
            metas.append(res.meta)
        stats = server.stats()
    assert [m["kind"] for m in metas] == ["cold", "refine", "refine"]
    cold = metas[0]["iterations"]
    assert all(m["iterations"] < cold for m in metas[1:])
    assert stats["tenant_requests"] == 3
    assert stats["tenants"]["creates"] == 1
    assert stats["tenants"]["reuses"] == 2


def test_server_delta_requests_hit_update_path():
    """Structured tenant drift shipped as kind="delta" takes the Session
    update branch: zero GK iterations per drift, accuracy tracking the
    dense SVD of the drifted operand."""
    rng = np.random.default_rng(7)
    A = lowrank_operand(rng, (48, 32), 4, noise=0.0)   # exact rank
    with SolveServer(SERVE_SPEC, max_batch=2, window_ms=2.0,
                     key=jax.random.key(8)) as server:
        res0 = server.solve(A, tenant="acme", timeout=120.0)
        assert res0.meta["kind"] == "cold"
        for _ in range(3):
            U, s, Vt = lowrank_drift(rng, A, drift=1e-3, drift_rank=2)
            res = server.solve((U, s, Vt), kind="delta", tenant="acme",
                               timeout=120.0)
            A = A + (U * s) @ Vt
            assert res.kind == "tenant"
            assert res.meta["kind"] == "update"
            assert res.meta["iterations"] == 0
        stats = server.stats()
    s_true = np.linalg.svd(A, compute_uv=False)[:4]
    err = np.max(np.abs(np.asarray(res.value.s) - s_true)) / s_true[0]
    assert err < 1e-4
    assert stats["tenant_requests"] == 4
    assert stats["tenants"]["creates"] == 1


def test_server_delta_requires_tracked_state():
    rng = np.random.default_rng(8)
    A = lowrank_operand(rng, (48, 32), 4)
    U, s, Vt = lowrank_drift(rng, A, drift=1e-3, drift_rank=2)
    with SolveServer(SERVE_SPEC, key=jax.random.key(9)) as server:
        # anonymous deltas are meaningless — rejected at submit
        with pytest.raises(ValueError):
            server.submit((U, s, Vt), kind="delta")
        # a tenant with no prior factorize has no state to update
        with pytest.raises(RuntimeError, match="delta before any"):
            server.solve((U, s, Vt), kind="delta", tenant="ghost",
                         timeout=120.0)


def test_server_entries_requests_hit_sketch_path():
    """Unstructured tenant drift shipped as kind="entries" COO triplets
    (no operand transport) engages the Session sketch-reconstruct branch:
    zero GK iterations once the probe reference is anchored, accuracy
    tracking the dense SVD of the drifted operand."""
    rng = np.random.default_rng(11)
    A = lowrank_operand(rng, (48, 32), 4, noise=0.0)   # exact rank
    with SolveServer(SERVE_SPEC, max_batch=2, window_ms=2.0,
                     key=jax.random.key(12)) as server:
        res0 = server.solve(A, tenant="acme", timeout=120.0)
        assert res0.meta["kind"] == "cold"
        metas = []
        for _ in range(4):
            rows, cols, vals = entry_drift(rng, A, drift=5e-4, nnz=64)
            A = A.copy()
            np.add.at(A, (rows, cols), vals)
            res = server.solve((rows, cols, vals), kind="entries",
                               tenant="acme", timeout=120.0)
            assert res.kind == "tenant"
            metas.append(res.meta)
        stats = server.stats()
    sketched = [m for m in metas if m["kind"] == "sketch"]
    assert len(sketched) >= 2
    for m in sketched:
        assert m["iterations"] == 0
        assert m["probe"] <= m["gate"]          # probe-verified, always
        assert 0.0 < m["staleness"] < 1.0
    s_true = np.linalg.svd(A, compute_uv=False)[:4]
    err = np.max(np.abs(np.asarray(res.value.s) - s_true)) / s_true[0]
    assert err < 5e-3
    assert stats["tenant_requests"] == 5
    assert stats["tenants"]["creates"] == 1


def test_server_entries_requires_tenant_and_tracked_state():
    rng = np.random.default_rng(12)
    A = lowrank_operand(rng, (48, 32), 4)
    rows, cols, vals = entry_drift(rng, A, drift=1e-3, nnz=16)
    with SolveServer(SERVE_SPEC, key=jax.random.key(13)) as server:
        with pytest.raises(ValueError, match="tenant"):
            server.submit((rows, cols, vals), kind="entries")
        with pytest.raises(ValueError, match="COO triplet"):
            server.submit(A, kind="entries", tenant="acme")
        with pytest.raises(RuntimeError, match="entries before any"):
            server.solve((rows, cols, vals), kind="entries",
                         tenant="ghost", timeout=120.0)
        # NaN values quarantine at submit, like any operand
        bad = vals.copy()
        bad[0] = np.nan
        with pytest.raises(Exception, match="quarantined"):
            server.submit((rows, cols, bad), kind="entries",
                          tenant="acme")


def test_estimate_requests_are_stateless():
    A = np.asarray(make_lowrank(jax.random.PRNGKey(5), 48, 32, 4))
    spec = SVDSpec(method="fsvd", rank=4, max_iters=32)
    with SolveServer(spec, key=jax.random.key(3)) as server:
        res = server.solve(A, kind="estimate", timeout=120.0)
        assert res.kind == "estimate"
        assert int(res.value.rank) == 4
        with pytest.raises(ValueError):
            server.submit(A, kind="estimate", tenant="acme")


def test_server_counts_rejections(monkeypatch):
    server = SolveServer(SERVE_SPEC, key=jax.random.key(4))
    try:
        def full(group, payload, **kw):
            raise QueueFull("full")
        monkeypatch.setattr(server.batcher, "submit", full)
        with pytest.raises(QueueFull):
            server.submit(np.zeros((8, 8), np.float32))
        assert server.stats()["rejected"] == 1
        assert server.stats()["submitted"] == 0
    finally:
        server.close()


def test_server_timeout_cancels_and_counts(monkeypatch):
    started, release = threading.Event(), threading.Event()
    server = SolveServer(SERVE_SPEC, max_batch=1, window_ms=1.0,
                         key=jax.random.key(5))
    try:
        def slow(group, tickets):
            started.set()
            release.wait(timeout=30)
            for t in tickets:
                t._resolve("late")
        monkeypatch.setattr(server.batcher, "_dispatch", slow)
        A = np.zeros((8, 8), np.float32)
        server.submit(A)                       # parks the worker
        assert started.wait(timeout=5.0)
        with pytest.raises(TimeoutError):
            server.solve(A, timeout=0.05)
        stats = server.stats()
        assert stats["timeouts"] == 1 and stats["cancelled"] == 1
    finally:
        release.set()
        server.close()


def test_closed_server_refuses_submissions():
    server = SolveServer(SERVE_SPEC, key=jax.random.key(6))
    server.close()
    server.close()                             # idempotent
    with pytest.raises(RuntimeError):
        server.submit(np.zeros((8, 8), np.float32))
