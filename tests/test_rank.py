"""Algorithm 3: numerical rank determination — exactness over fixed
(m, n, rank) cases.  The hypothesis property sweep lives in
``test_rank_property.py`` so this module stays runnable when hypothesis
is not installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.core.rank import numerical_rank


@pytest.mark.parametrize("m,n,rank", [(100, 80, 10), (60, 120, 25),
                                      (200, 200, 1)])
def test_rank_exact(rng, m, n, rank):
    A = make_lowrank(rng, m, n, rank)
    out = numerical_rank(A)
    assert int(out.rank) == rank
    # Alg-1 termination gives the first (slightly loose) estimate: Table 1a
    # reports 102-105 iterations for rank-100 inputs
    assert rank <= int(out.gk_iterations) <= rank + 3


def test_rank_in_graph_variant(rng):
    """The jit-able (fori_loop, masked) path detects rank too."""
    A = make_lowrank(rng, 80, 60, 8)
    out = numerical_rank(A, host_loop=False, max_iters=40)
    assert int(out.rank) == 8


def test_full_rank_matrix(rng):
    A = jax.random.normal(rng, (50, 30))
    out = numerical_rank(A)
    assert int(out.rank) == 30


def test_noisy_lowrank(rng):
    """Rank-10 + tiny noise: numerical rank at a loose tolerance is 10."""
    A = make_lowrank(rng, 100, 80, 10)
    A = A + 1e-6 * jax.random.normal(jax.random.PRNGKey(1), A.shape)
    out = numerical_rank(A, sigma_tol=1e-4 * float(jnp.linalg.norm(A)) ** 2)
    assert int(out.rank) == 10
