"""Distributed-layer tests on 8 fake devices.

Each test runs in a SUBPROCESS with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps its single real device (the dry-run rule).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> dict:
    """Run ``body`` in a fresh 8-device python; it must print a JSON dict."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_matvec_matches_dense():
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed.matvec import place_operator, sharded_operator
        mesh = make_mesh((4, 2), ("data", "model"))
        A = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        Ad = place_operator(A, mesh)
        op = sharded_operator(Ad, mesh)
        p = jax.random.normal(jax.random.PRNGKey(1), (32,))
        q = jax.random.normal(jax.random.PRNGKey(2), (64,))
        e1 = float(jnp.max(jnp.abs(op.mv(p) - A @ p)))
        e2 = float(jnp.max(jnp.abs(op.rmv(q) - A.T @ q)))
        e3 = float(jnp.max(jnp.abs(op.mv_fused(p, q, 0.5) - (A @ p - 0.5*q))))
        print(json.dumps({"e1": e1, "e2": e2, "e3": e3}))
    """)
    assert res["e1"] < 1e-4 and res["e2"] < 1e-4 and res["e3"] < 1e-4


def test_distributed_fsvd_matches_dense():
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed.gk_dist import fsvd_sharded, rank_sharded
        from repro.core import fsvd
        mesh = make_mesh((4, 2), ("data", "model"))
        M = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
        N = jax.random.normal(jax.random.PRNGKey(1), (64, 128))
        A = M @ N
        out = fsvd_sharded(A, mesh, 8, 40)
        s_true = jnp.linalg.svd(A, compute_uv=False)[:8]
        err = float(jnp.max(jnp.abs(out.s - s_true) / s_true))
        rk = rank_sharded(A, mesh, max_iters=100)
        print(json.dumps({"err": err, "rank": int(rk.rank)}))
    """)
    assert res["err"] < 1e-3
    assert res["rank"] == 64


def test_multipod_mesh_axes():
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed.matvec import place_operator, sharded_operator
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        A = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        op = sharded_operator(place_operator(A, mesh), mesh)
        p = jax.random.normal(jax.random.PRNGKey(1), (32,))
        err = float(jnp.max(jnp.abs(op.mv(p) - A @ p)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-4


def test_compressed_mean_grads():
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed import compression as C
        from repro.configs.base import FsvdConfig
        cfg = FsvdConfig(compression_rank=8, compression_min_dim=32,
                         max_iters=24)
        mesh = make_mesh((8,), ("data",))
        lowU = jax.random.normal(jax.random.PRNGKey(3), (128, 8))
        lowV = jax.random.normal(jax.random.PRNGKey(4), (8, 96))
        G = 0.01 * jax.random.normal(jax.random.PRNGKey(2), (8, 128, 96)) \\
            + (lowU @ lowV)[None]
        small = jnp.broadcast_to(jnp.arange(8.0)[:, None], (8, 8))

        def body(g, sm, e):
            grads = {"w": g[0], "tiny": sm[0]}
            ef = {"w": e[0], "tiny": jnp.zeros(())}
            mean, new_ef, stats = C.compressed_mean_grads(grads, ef, "data",
                                                          cfg)
            return (mean["w"][None], mean["tiny"][None],
                    new_ef["w"][None],
                    jnp.stack([stats.dense_bytes, stats.compressed_bytes])[None])

        out = jax.jit(jax.shard_map(body, mesh=mesh,
            in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data"), P("data")),
            check_vma=False))(G, small, jnp.zeros((8, 128, 96)))
        mean_true = G.mean(0)
        rel = float(jnp.linalg.norm(out[0][0] - mean_true)
                    / jnp.linalg.norm(mean_true))
        tiny_err = float(jnp.max(jnp.abs(out[1][0] - 3.5)))
        ef_norm = float(jnp.linalg.norm(out[2][0]))
        print(json.dumps({"rel": rel, "tiny": tiny_err, "ef": ef_norm}))
    """)
    assert res["rel"] < 5e-3          # low-rank-dominated mean well captured
    assert res["tiny"] < 1e-6         # small leaves use plain psum-mean
    assert res["ef"] > 0              # residual captured for error feedback


def test_ef_accumulates_what_compression_drops():
    """DP-SGD with EF compression tracks uncompressed SGD on a quadratic.

    The entire optimization runs inside ONE jitted shard_map + fori_loop —
    a single executable keeps the CPU-collective rendezvous count low (the
    many-small-executions pattern is flaky on the host backend)."""
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed import compression as C
        from repro.configs.base import FsvdConfig
        cfg = FsvdConfig(compression_rank=2, compression_min_dim=8,
                         max_iters=6)
        mesh = make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        Wstar = jax.random.normal(key, (32, 24))
        X = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 32))
        lr, steps = 0.1, 150

        def run(x):
            x = x[0]                       # (16, 32) local shard

            def one(i, carry):
                W, e = carry
                r = x @ (W - Wstar)
                g = x.T @ r / x.shape[0]
                mean, new_e, _ = C.compressed_mean_grads(
                    {"w": g}, {"w": e}, "data", cfg)
                return W - lr * mean["w"], new_e["w"]

            W, _ = jax.lax.fori_loop(
                0, steps, one, (jnp.zeros((32, 24)), jnp.zeros((32, 24))))
            return W[None]

        W = jax.jit(jax.shard_map(run, mesh=mesh, in_specs=(P("data"),),
                                  out_specs=P("data"),
                                  check_vma=False))(X)[0]
        err = float(jnp.linalg.norm(W - Wstar) / jnp.linalg.norm(Wstar))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 0.15   # converges to the optimum despite rank-2 comm


def test_partition_rules_divisibility_fallback():
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed.partition import logical_to_spec
        mesh = make_mesh((2, 4), ("data", "model"))
        s1 = logical_to_spec(("embed", "heads", "head_dim"), (64, 8, 32), mesh)
        s2 = logical_to_spec(("embed", "kv_heads", "head_dim"), (64, 3, 32),
                             mesh)   # 3 % 4 != 0 -> replicated
        s3 = logical_to_spec(("experts", "embed", "mlp"), (8, 64, 128), mesh)
        print(json.dumps({"s1": str(s1), "s2": str(s2), "s3": str(s3)}))
    """)
    assert "'model'" in res["s1"]
    assert "'model'" not in res["s2"]
    # conflict rule: experts claim model; mlp must NOT re-claim it
    assert res["s3"].count("'model'") == 1 and "'data'" in res["s3"]


def test_fused_step_is_one_collective_per_half_step():
    """Acceptance: on a row-sharded mesh each fused GK half-step issues
    exactly ONE psum (asserted on the jaxpr) lowering to exactly ONE
    all-reduce (asserted on the compiled HLO).  A "model" axis adds the
    matvec-reduce collective — exactly one more, never one per dot."""
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.distributed.matvec import sharded_operator
        from repro.launch import hlo_analysis

        def iter_eqns(jaxpr):
            for eqn in jaxpr.eqns:
                yield eqn
                for v in eqn.params.values():
                    vs = v if isinstance(v, (tuple, list)) else [v]
                    for x in vs:
                        if hasattr(x, "eqns"):
                            yield from iter_eqns(x)
                        elif hasattr(x, "jaxpr"):
                            yield from iter_eqns(x.jaxpr)

        def psums(fn, *args):
            jx = jax.make_jaxpr(fn)(*args)
            return sum(1 for e in iter_eqns(jx.jaxpr)
                       if e.primitive.name == "psum")

        A = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        p = jax.random.normal(jax.random.PRNGKey(1), (64,))
        q = jax.random.normal(jax.random.PRNGKey(2), (128,))
        Q = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(3),
                                            (128, 9)))[0]
        Pb = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(4),
                                             (64, 9)))[0]
        out = {}
        for tag, shape, axes, backend in [
                ("rows", (8,), ("data",), "xla"),
                ("rows_pallas", (8,), ("data",), "pallas"),
                ("pods", (2, 4), ("pod", "data"), "xla"),
                ("model", (4, 2), ("data", "model"), "xla")]:
            op = sharded_operator(A, make_mesh(shape, axes), backend=backend)
            out[tag] = [
                psums(lambda p, q, Q: op.lanczos_step(p, q, 0.4, Q)[0],
                      p, q, Q),
                psums(lambda q, p, Pb: op.lanczos_rstep(q, p, 0.2, Pb)[0],
                      q, p, Pb)]
        op = sharded_operator(A, make_mesh((8,), ("data",)))
        hlo = jax.jit(lambda p, q, Q: op.lanczos_step(p, q, 0.4, Q)) \\
            .lower(p, q, Q).compile().as_text()
        counts = hlo_analysis.analyze(hlo).collective_counts
        out["hlo"] = {k: v for k, v in counts.items() if v}
        print(json.dumps(out))
    """)
    assert res["rows"] == [1, 1], res
    assert res["rows_pallas"] == [1, 1], res
    assert res["pods"] == [1, 1], res
    assert res["model"] == [2, 2], res          # +1 matvec-reduce, not +per-dot
    assert res["hlo"] == {"all-reduce": 1}, res


def test_sharded_solvers_match_dense_on_8_devices():
    """Acceptance: sharded fsvd / fsvd_blocked / rsvd match their
    single-device factorizations to 1e-5 (f32) on a non-divisible shape."""
    res = run_sub("""
        from repro.api import SVDSpec, factorize
        from repro.launch.mesh import make_mesh
        from repro.distributed.matvec import sharded_operator
        import repro.distributed.gk_dist  # registers fsvd_sharded
        mesh = make_mesh((8,), ("data",))
        # the 1e3 scale makes sigma_max(A) ~ 3e4: regression cover for the
        # distributed orthonormalization's drop threshold, which must be
        # scale-relative (a fixed scale silently dropped all expansion
        # columns for sigma_max > ~2.5e3 and degraded fsvd_blocked)
        M = jax.random.normal(jax.random.PRNGKey(0), (100, 12))
        A = 1e3 * (M @ jax.random.normal(jax.random.PRNGKey(1), (12, 70))
                   + 1e-4 * jax.random.normal(jax.random.PRNGKey(2),
                                              (100, 70)))
        smax = float(jnp.linalg.svd(A, compute_uv=False)[0])
        key = jax.random.PRNGKey(7)
        out = {}
        for method, kw in [("fsvd_sharded", dict(max_iters=48)),
                           ("fsvd_blocked", dict()),
                           ("rsvd", dict(power_iters=3, oversample=10))]:
            spec = SVDSpec(method=method, rank=8, **kw)
            sharded = factorize(sharded_operator(A, mesh), spec, key=key)
            if method == "fsvd_sharded":
                ref = factorize(sharded_operator(A, make_mesh((1,),
                                                              ("data",))),
                                spec, key=key)
            else:
                ref = factorize(A, spec, key=key)
            out[method] = float(np.max(np.abs(np.asarray(sharded.s)
                                              - np.asarray(ref.s))) / smax)
        print(json.dumps(out))
    """)
    for method, err in res.items():
        assert err < 1e-5, f"{method}: sharded vs single σ error {err:.2e}"


def test_sharded_sparse_and_gram_operands():
    """ShardedOp wraps SparseOp (row-partitioned ELL packs) and GramOp;
    estimate_rank + fsvd_blocked accept both without densifying."""
    res = run_sub("""
        from repro.api import SVDSpec, estimate_rank, factorize
        from repro.core.operators import DenseOp, GramOp, SparseOp
        from repro.launch.mesh import make_mesh
        from repro.distributed.matvec import sharded_operator
        mesh = make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(3)
        mask = jax.random.uniform(jax.random.PRNGKey(4), (90, 60)) < 0.08
        dense = jnp.where(mask, jax.random.normal(key, (90, 60)), 0.0)
        sop = sharded_operator(SparseOp.fromdense(dense), mesh)
        p = jax.random.normal(jax.random.PRNGKey(5), (60,))
        q = jax.random.normal(jax.random.PRNGKey(6), (90,))
        e_mv = float(jnp.max(jnp.abs(sop.mv(p) - dense @ p)))
        e_rmv = float(jnp.max(jnp.abs(sop.rmv(q) - dense.T @ q)))
        s_true = jnp.linalg.svd(dense, compute_uv=False)[:6]
        out = factorize(sop, SVDSpec(method="fsvd_blocked", rank=6),
                        key=jax.random.PRNGKey(8))
        e_s = float(np.max(np.abs(np.asarray(out.s) - np.asarray(s_true)))
                    / float(s_true[0]))
        lr = jax.random.normal(jax.random.PRNGKey(9), (64, 7)) \\
            @ jax.random.normal(jax.random.PRNGKey(10), (7, 48))
        gop = sharded_operator(GramOp(DenseOp(lr)), mesh)
        rk = int(estimate_rank(gop, key=jax.random.PRNGKey(11)).rank)
        print(json.dumps({"mv": e_mv, "rmv": e_rmv, "sigma": e_s,
                          "rank": rk}))
    """)
    assert res["mv"] < 1e-4 and res["rmv"] < 1e-4
    assert res["sigma"] < 1e-5
    assert res["rank"] == 7


def test_fsvd_sharded_rejects_host_loop():
    """Regression (this PR): spec.host_loop=True used to be silently
    honored — a host loop on a sharded operand gathers device scalars
    every iteration, stalling the mesh.  It must be a loud error now."""
    import jax
    import pytest
    from repro.api import SVDSpec, factorize
    from repro.distributed.matvec import sharded_operator
    from repro.launch.mesh import make_mesh
    import repro.distributed.gk_dist  # noqa: F401  (registers fsvd_sharded)
    mesh = make_mesh((jax.device_count(),), ("data",))
    op = sharded_operator(
        jax.random.normal(jax.random.PRNGKey(0), (32, 16)), mesh)
    with pytest.raises(ValueError, match="host_loop"):
        factorize(op, SVDSpec(method="fsvd_sharded", rank=4,
                              host_loop=True),
                  key=jax.random.PRNGKey(1))
    # host_loop=None / False keep working
    out = factorize(op, SVDSpec(method="fsvd_sharded", rank=4),
                    key=jax.random.PRNGKey(1))
    assert out.s.shape == (4,)


def test_estimate_rank_sharded_defaults_to_in_graph(monkeypatch):
    """Regression (this PR): estimate_rank's host-loop default must flip
    to the in-graph loop on sharded operands — the per-iteration host
    gather is the same mesh-wide stall fsvd_sharded rejects."""
    import jax
    import pytest
    import repro.core.gk as gk_mod
    from repro.api import SVDSpec, estimate_rank
    from repro.distributed.matvec import sharded_operator
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((jax.device_count(),), ("data",))
    A = jax.random.normal(jax.random.PRNGKey(0), (40, 9)) \
        @ jax.random.normal(jax.random.PRNGKey(1), (9, 24))
    op = sharded_operator(A, mesh)

    def _no_host_loop(*a, **kw):
        raise AssertionError("sharded estimate_rank took the host loop")

    monkeypatch.setattr(gk_mod, "gk_bidiag_host", _no_host_loop)
    est = estimate_rank(op, key=jax.random.PRNGKey(2))
    assert int(est.rank) == 9
    # an explicit host_loop=True is still the caller's to choose
    with pytest.raises(AssertionError, match="host loop"):
        estimate_rank(op, SVDSpec(host_loop=True),
                      key=jax.random.PRNGKey(2))
    # ... and dense operands keep the paper's early-exit host default
    with pytest.raises(AssertionError, match="host loop"):
        estimate_rank(A, key=jax.random.PRNGKey(2))


def test_sharded_train_step_runs():
    """End-to-end: reduced arch, (2,2,2) pod mesh, one real sharded step."""
    res = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.launch import input_specs as ispec
        from repro.configs import get_arch
        from repro.configs.base import OptimConfig
        from repro.runtime.steps import build_train_step, init_state
        from repro.data.synthetic import lm_batch, LMBatchSpec

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_arch("olmoe-1b-7b").reduced()
        opt = OptimConfig(lr=1e-3)
        state = init_state(cfg, opt, jax.random.PRNGKey(0))
        _, state_shard = ispec.state_struct_and_shardings(cfg, opt, mesh)
        state = jax.device_put(state, state_shard)
        step = jax.jit(build_train_step(cfg, opt, mesh),
                       in_shardings=(state_shard, None),
                       donate_argnums=(0,))
        spec = LMBatchSpec(8, 32, cfg.vocab_size)
        with mesh:
            state, metrics = step(state, lm_batch(spec, 0, 0))
            state, metrics = step(state, lm_batch(spec, 0, 1))
        print(json.dumps({"loss": float(metrics["loss"]),
                          "finite": bool(jnp.isfinite(metrics["loss"]))}))
    """)
    assert res["finite"]
