"""Checkpoint store: atomicity, validity checks, keep-N GC, async writes,
resume, reshard-on-restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)


@pytest.fixture
def tree(rng):
    k1, k2 = jax.random.split(rng)
    return {"params": {"w": jax.random.normal(k1, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": [jax.random.normal(k2, (8, 4)),
                    jnp.asarray(3, jnp.int32)]}


def test_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out, extra = load_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_checkpoint_ignored(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # corrupt step 2: truncate a leaf file
    p2 = tmp_path / "step_2"
    leaf = next(f for f in os.listdir(p2) if f.endswith(".npy"))
    with open(p2 / leaf, "wb") as f:
        f.write(b"xx")
    assert latest_step(str(tmp_path)) == 1


def test_missing_manifest_ignored(tmp_path, tree):
    save_checkpoint(str(tmp_path), 3, tree)
    os.remove(tmp_path / "step_3" / "manifest.json")
    assert latest_step(str(tmp_path)) is None


def test_keep_n_gc(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    assert "step_3" in names and "step_4" in names
    assert "step_1" not in names and "step_2" not in names


def test_async_writer(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    mgr.save(5, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 5


def test_restore_latest_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(9, tree, extra={"note": "hi"})
    got = mgr.restore_latest(tree)
    assert got is not None
    step, out, extra = got
    assert step == 9 and extra["note"] == "hi"


def test_shape_mismatch_raises(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    bad = jax.tree.map(lambda x: jnp.zeros((2,) + x.shape, x.dtype), tree)
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), 1, bad)


def test_extra_metadata_survives(tmp_path, tree):
    save_checkpoint(str(tmp_path), 4, tree, extra={"mesh": [16, 16]})
    _, extra = load_checkpoint(str(tmp_path), 4, tree)
    assert extra["mesh"] == [16, 16]


# ---------------------------------------------------------------------------
# solver-result pytrees (repro.api) through the leaf protocol
# ---------------------------------------------------------------------------

def _fact(method="fsvd"):
    from repro.api import SVDSpec, factorize
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (24, 5)) @ jax.random.normal(k2, (5, 18))
    return A, factorize(A, SVDSpec(method=method, rank=4, max_iters=16),
                        key=key)


def test_factorization_roundtrip_bit_equal(tmp_path):
    """A Factorization checkpoints like any state pytree: bit-equal leaves
    and the static ``method`` aux intact (it rides the template, never the
    disk)."""
    from repro.api import Factorization
    _, fact = _fact()
    save_checkpoint(str(tmp_path), 1, {"fact": fact})
    out, _ = load_checkpoint(str(tmp_path), 1, {"fact": fact})
    back = out["fact"]
    assert isinstance(back, Factorization) and back.method == fact.method
    for a, b in zip(jax.tree.leaves(fact), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_rank_estimate_roundtrip_bit_equal(tmp_path):
    from repro.api import RankEstimate, estimate_rank
    key = jax.random.PRNGKey(6)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (30, 7)) @ jax.random.normal(k2, (7, 22))
    est = estimate_rank(A, key=key)
    save_checkpoint(str(tmp_path), 2, {"rank": est})
    out, _ = load_checkpoint(str(tmp_path), 2, {"rank": est})
    back = out["rank"]
    assert isinstance(back, RankEstimate) and back.method == est.method
    assert int(back.rank) == int(est.rank) == 7
    for a, b in zip(jax.tree.leaves(est), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_state_roundtrip(tmp_path):
    """save_session_state/load_session_state: factorization template is
    rebuilt from the manifest (no geometry supplied) and the plan-spec
    metadata survives."""
    from repro.api import SVDSpec, session
    from repro.checkpoint import load_session_state, save_session_state
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (20, 4)) @ jax.random.normal(k2, (4, 16))
    sess = session(A, SVDSpec(method="fsvd", rank=3, max_iters=12), key=key)
    sess.solve()
    save_session_state(str(tmp_path), 1, sess)
    fact, meta = load_session_state(str(tmp_path), 1)
    assert meta["spec"]["rank"] == 3 and meta["spec"]["method"] == "fsvd"
    assert fact.method == sess.fact.method
    for a, b in zip(jax.tree.leaves(fact), jax.tree.leaves(sess.fact)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_state_before_first_solve(tmp_path):
    from repro.api import SVDSpec, session
    from repro.checkpoint import load_session_state, save_session_state
    A = jnp.eye(8)
    sess = session(A, SVDSpec(rank=2), key=jax.random.PRNGKey(0))
    save_session_state(str(tmp_path), 0, sess)
    fact, meta = load_session_state(str(tmp_path), 0)
    assert fact is None and meta["step"] == 0


# ---------------------------------------------------------------------------
# PR 8: per-leaf CRC32 verification + write-path fault injection
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _clean_failpoints():
    from repro.runtime import faults
    faults.disarm_all()
    yield
    faults.disarm_all()


def _flip_leaf_byte(step_dir):
    """Same-size bit-rot: flip one byte in a leaf's data region (the
    size check alone cannot see this — only the CRC can)."""
    leaf = next(f for f in sorted(os.listdir(step_dir))
                if f.endswith(".npy"))
    path = os.path.join(str(step_dir), leaf)
    with open(path, "r+b") as f:
        f.seek(-4, os.SEEK_END)
        old = f.read(1)
        f.seek(-4, os.SEEK_END)
        f.write(bytes([old[0] ^ 0xFF]))


def test_crc_rejects_same_size_bitrot(tmp_path, tree):
    """A flipped byte keeps the file size: pre-CRC validity would accept
    it and restore garbage.  valid_steps/latest_step must skip the rotten
    step, and a direct load of it must raise, never return wrong data."""
    from repro.checkpoint import valid_steps
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    _flip_leaf_byte(tmp_path / "step_2")
    assert valid_steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1
    with pytest.raises(ValueError, match="CRC32"):
        load_checkpoint(str(tmp_path), 2, tree)
    out, _ = load_checkpoint(str(tmp_path), 1, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_write_crash_failpoint_leaves_no_partial_state(tmp_path, tree):
    """A crash at checkpoint.write (before the atomic rename) must leave
    the directory exactly as it was: older steps intact, no half-written
    step visible to the scan."""
    from repro.checkpoint import valid_steps
    from repro.runtime import faults
    from repro.runtime.faults import FaultInjected
    save_checkpoint(str(tmp_path), 1, tree)
    faults.arm(faults.CHECKPOINT_WRITE, mode="raise", p=1.0)
    with pytest.raises(FaultInjected):
        save_checkpoint(str(tmp_path), 2, tree)
    faults.disarm_all()
    assert valid_steps(str(tmp_path)) == [1]
    assert not (tmp_path / "step_2").exists()


def test_corrupt_failpoint_bitrot_is_detected(tmp_path, tree):
    """corrupt-mode injection mangles leaf bytes AFTER their CRC is
    recorded — exactly a torn write / bit-rot in flight.  The checkpoint
    lands on disk but verification rejects it and recovery falls back."""
    from repro.checkpoint import valid_steps
    from repro.runtime import faults
    save_checkpoint(str(tmp_path), 1, tree)
    faults.arm(faults.CHECKPOINT_WRITE, mode="corrupt", p=1.0)
    save_checkpoint(str(tmp_path), 2, tree)
    faults.disarm_all()
    assert (tmp_path / "step_2").exists()     # written...
    assert valid_steps(str(tmp_path)) == [1]  # ...but never trusted
    assert latest_step(str(tmp_path)) == 1


def test_session_restore_falls_back_to_newest_verified(tmp_path):
    """Session.restore walks verified steps newest-first: with the newest
    checkpoint rotten it restores the older one instead of failing (the
    serving tenant restore-on-evict path rides exactly this)."""
    from repro.api import SVDSpec, session
    from repro.api.session import Session
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (20, 4)) @ jax.random.normal(k2, (4, 16))
    sess = session(A, SVDSpec(method="fsvd", rank=3, max_iters=12), key=key)
    sess.solve()
    sess.save(str(tmp_path), step=1)
    sess.update(A + 1e-4 * jax.random.normal(k2, A.shape))
    sess.save(str(tmp_path), step=2)
    _flip_leaf_byte(tmp_path / "step_2")
    restored = Session.restore(str(tmp_path), A, key=key)
    assert restored._step == 1                 # newer step was rotten
    for a, b in zip(jax.tree.leaves(restored.fact),
                    jax.tree.leaves(sess.fact)):
        assert np.asarray(a).shape == np.asarray(b).shape


def test_restore_failpoint_raises_and_tenant_registry_survives(tmp_path):
    """The session.restore failpoint makes restore blow up; the tenant
    registry must absorb that into a fresh (cold) session and count it —
    a tenant is never unservable because its checkpoint path is."""
    from repro.api import SVDSpec, session
    from repro.runtime import faults
    from repro.serve.tenant import TenantRegistry
    key = jax.random.PRNGKey(13)
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (20, 4)) @ jax.random.normal(k2, (4, 16))
    spec = SVDSpec(method="fsvd", rank=3, max_iters=12)
    sess = session(A, spec, key=key)
    sess.solve()
    sess.save(str(tmp_path / "t0"), step=1)
    reg = TenantRegistry(spec, checkpoint_dir=str(tmp_path), key=key)
    faults.arm(faults.SESSION_RESTORE, mode="raise", p=1.0)
    got = reg.get("t0", A)                    # restore fails -> fresh
    faults.disarm_all()
    assert got.fact is None                   # cold, not restored
    assert reg.stats()["restore_failures"] == 1
    assert reg.stats()["creates"] == 1
