"""repro.api facade: spec-driven solves agree with the legacy entry points,
the unified result type behaves, keys are handled uniformly, and the
registry is open for extension."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (Factorization, ImplicitKeyWarning, RankEstimate,
                       SVDSpec, available_solvers, estimate_rank, factorize,
                       register_solver, resolve_method)
from repro.core.fsvd import fsvd as legacy_fsvd
from repro.core.rank import numerical_rank as legacy_rank
from repro.core.rsvd import rsvd as legacy_rsvd

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def A():
    return make_lowrank(jax.random.PRNGKey(0), 120, 80, 15)


def test_same_result_type_across_methods(A):
    f = factorize(A, SVDSpec(method="fsvd", rank=6), key=KEY)
    r = factorize(A, SVDSpec(method="rsvd", rank=6), key=KEY)
    assert type(f) is Factorization and type(r) is Factorization
    assert f.method == "fsvd" and r.method == "rsvd"
    assert f.s.shape == r.s.shape == (6,)
    # both reproduce the dominant triplets of a rank-15 input
    s_true = jnp.linalg.svd(A, compute_uv=False)[:6]
    np.testing.assert_allclose(np.asarray(f.s), np.asarray(s_true),
                               rtol=1e-3)


def test_facade_matches_legacy_fsvd(A):
    new = factorize(A, SVDSpec(method="fsvd", rank=8, max_iters=60,
                               reorth_passes=2), key=KEY)
    old = legacy_fsvd(A, 8, 60, key=KEY, reorth_passes=2)
    np.testing.assert_allclose(np.asarray(new.s), np.asarray(old.s),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new.U), np.asarray(old.U),
                               rtol=1e-5, atol=1e-5)
    assert int(new.iterations) == int(old.kprime)


def test_facade_matches_legacy_rsvd(A):
    new = factorize(A, SVDSpec(method="rsvd", rank=8, oversample=20,
                               power_iters=1), key=KEY)
    old = legacy_rsvd(A, 8, p=20, power_iters=1, key=KEY)
    np.testing.assert_allclose(np.asarray(new.s), np.asarray(old.s),
                               rtol=1e-6)


def test_estimate_rank_matches_legacy(A):
    est = estimate_rank(A, key=KEY)
    old = legacy_rank(A, key=KEY)
    assert int(est.rank) == int(old.rank) == 15
    assert int(est.iterations) == int(old.gk_iterations)
    assert isinstance(est, RankEstimate)


def test_spec_overrides_and_validation(A):
    out = factorize(A, rank=4, method="fsvd", key=KEY)   # kwargs-only form
    assert out.rank == 4
    with pytest.raises(ValueError):
        SVDSpec(rank=0)
    with pytest.raises(ValueError):
        SVDSpec(backend="cuda")
    s = SVDSpec(rank=3)
    assert s.replace(rank=9).rank == 9 and s.rank == 3


def test_auto_method_resolution():
    assert resolve_method(SVDSpec(method="auto")) == "fsvd"
    assert resolve_method(SVDSpec(method="auto", tol=1e-2)) == "rsvd"
    assert resolve_method(SVDSpec(method="auto", power_iters=2)) == "rsvd"
    assert resolve_method(SVDSpec(method="fsvd", tol=1e-2)) == "fsvd"


def test_implicit_key_warns_explicit_does_not(A):
    spec = SVDSpec(method="rsvd", rank=4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        factorize(A, spec)
    assert any(issubclass(w.category, ImplicitKeyWarning) for w in rec)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        factorize(A, spec, key=KEY)
    assert not any(issubclass(w.category, ImplicitKeyWarning) for w in rec)


def test_factorization_reconstruct_and_errors(A):
    out = factorize(A, SVDSpec(method="fsvd", rank=15, max_iters=80),
                    key=KEY)
    R = out.reconstruct()
    assert float(jnp.linalg.norm(A - R)) < 1e-2 * float(jnp.linalg.norm(A))
    errs = out.errors(A)
    assert float(errs["relative"]) < 5e-5
    assert errs["residual"] is not None


def test_warm_start_round_trip(A):
    first = factorize(A, SVDSpec(method="fsvd", rank=6), key=KEY)
    again = factorize(A, SVDSpec(method="fsvd", rank=6),
                      q1=first.warm_start())
    np.testing.assert_allclose(np.asarray(again.s), np.asarray(first.s),
                               rtol=1e-4)


def test_factorization_is_pytree(A):
    out = factorize(A, SVDSpec(method="fsvd", rank=4), key=KEY)
    leaves, treedef = jax.tree_util.tree_flatten(out)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.method == out.method
    np.testing.assert_allclose(np.asarray(back.s), np.asarray(out.s))


def test_registry_extension(A):
    @register_solver("constant")
    def solve_constant(op, spec, *, key=None, q1=None):
        m, n = op.shape
        return Factorization(jnp.zeros((m, spec.rank)),
                             jnp.zeros((spec.rank,)),
                             jnp.zeros((n, spec.rank)),
                             jnp.asarray(0, jnp.int32),
                             jnp.asarray(False), method="constant")

    assert "constant" in available_solvers()
    out = factorize(A, SVDSpec(method="constant", rank=2))
    assert out.method == "constant" and float(out.s.sum()) == 0.0


def test_legacy_entry_points_warn_deprecation(A):
    import repro.core as core
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        core.fsvd(A, 3, 20, key=KEY)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)


def test_factorize_jit_matches_eager(A):
    from repro.api import factorize_jit
    spec = SVDSpec(method="fsvd", rank=5, max_iters=30)
    fn = factorize_jit(spec)
    q1 = jnp.ones((A.shape[0],), jnp.float32)
    out_j = fn(A, KEY, q1)
    out_e = factorize(A, spec, key=KEY, q1=q1)
    np.testing.assert_allclose(np.asarray(out_j.s), np.asarray(out_e.s),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_j.V), np.asarray(out_e.V),
                               rtol=1e-4, atol=1e-5)


def test_factorize_jit_rejects_host_loops():
    from repro.api import factorize_jit
    with pytest.raises(ValueError, match="host"):
        factorize_jit(SVDSpec(method="fsvd", host_loop=True))
    with pytest.raises(ValueError, match="host"):
        factorize_jit(SVDSpec(method="fsvd_blocked"))


def test_spec_precision_validation():
    with pytest.raises(ValueError, match="precision"):
        SVDSpec(precision="fp8")
    assert SVDSpec(precision="bf16").precision == "bf16"


def test_estimate_rank_rejects_narrow_precision(A):
    with pytest.raises(ValueError, match="full-precision"):
        estimate_rank(A, SVDSpec(precision="bf16"), key=KEY)
