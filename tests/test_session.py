"""Session layer: tracked solves on drifting operators.

The acceptance battery: on the parity zoo, ``Session.update`` after a
small drift must converge in strictly fewer GK iterations than a cold
``factorize`` of the drifted matrix — at the same accuracy gate the
cold solve is held to (max |ŝ − s| / σ_max vs dense SVD).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (Session, SVDSpec, clear_plan_cache, factorize,
                       session, trace_count)
from repro.core.operators import LowRankOp
from test_solver_parity import R, ZOO

KEY = jax.random.PRNGKey(11)

SPEC = SVDSpec(method="fsvd", rank=R, max_iters=48)
STOL = 5e-4          # the parity battery's GK gate (vs sigma_max)


def _drifted(A, key, rel=1e-3):
    G = jax.random.normal(key, A.shape)
    return A + rel * jnp.linalg.norm(A) * G / jnp.linalg.norm(G)


def _accuracy(fact, A) -> float:
    s_true = jnp.linalg.svd(A, compute_uv=False)[: fact.rank]
    return float(jnp.max(jnp.abs(fact.s - s_true)) / s_true[0])


@pytest.mark.parametrize("name", sorted(ZOO))
def test_update_beats_cold_on_zoo(name):
    """Acceptance: tracked refine converges in fewer GK iterations than a
    cold solve of the drifted matrix, at the same accuracy gate."""
    A, _ = ZOO[name]
    spec = SPEC.replace(max_iters=min(48, min(A.shape)))
    A2 = _drifted(A, jax.random.fold_in(KEY, 1))
    cold = factorize(A2, spec, key=jax.random.fold_in(KEY, 2))

    sess = session(A, spec, key=KEY)
    sess.solve()
    tracked = sess.update(A2)

    assert sess.history[-1]["kind"] == "refine"
    assert int(tracked.iterations) < int(cold.iterations)
    acc_cold = _accuracy(cold, A2)
    acc_tracked = _accuracy(tracked, A2)
    assert acc_tracked <= max(STOL, 2.0 * acc_cold), (
        f"{name}: tracked {acc_tracked:.2e} vs cold {acc_cold:.2e}")


def test_refine_vs_restart_decision():
    A, _ = ZOO["lowrank_noise"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    # tiny drift -> refine
    sess.update(_drifted(A, jax.random.fold_in(KEY, 3), rel=1e-4))
    assert sess.history[-1]["kind"] == "refine"
    assert sess.history[-1]["drift"] < sess.restart_angle
    # unrelated operator -> subspace angle blows past the threshold
    B = make_lowrank(jax.random.PRNGKey(99), *A.shape, R)
    sess.update(B)
    assert sess.history[-1]["kind"] == "restart"
    assert sess.history[-1]["drift"] > sess.restart_angle
    assert sess.counts() == {"cold": 1, "refine": 1, "restart": 1}


def test_drift_is_zero_for_unchanged_operator():
    A, _ = ZOO["graded"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    assert sess.drift() < 1e-4
    again = sess.solve()                      # re-solve same operand
    assert sess.history[-1]["kind"] == "refine"
    assert _accuracy(again, A) <= STOL


def test_delta_lowrank_update():
    """A structured low-rank drift takes the zero-iteration update branch
    (PR 7 three-way policy) at the same accuracy gate as a GK solve."""
    A, _ = ZOO["lowrank_noise"]
    m, n = A.shape
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (m, 1))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, n))
    scale = 1e-3 * float(jnp.linalg.norm(A)) / float(
        jnp.linalg.norm(u) * jnp.linalg.norm(v))
    fact = sess.delta(LowRankOp(u, jnp.asarray([scale]), v))
    assert sess.history[-1]["kind"] == "update"
    assert sess.history[-1]["iterations"] == 0
    assert sess.counts()["update"] == 1
    A2 = A + scale * (u @ v)
    assert _accuracy(fact, A2) <= STOL


def test_delta_update_disabled_falls_back_to_refine():
    """update_tol=0.0 disables the update path: the pre-PR-7 behavior
    (fold + tracked GK solve) for every delta."""
    A, _ = ZOO["lowrank_noise"]
    m, n = A.shape
    sess = session(A, SPEC, key=KEY, update_tol=0.0)
    sess.solve()
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (m, 1))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, n))
    scale = 1e-3 * float(jnp.linalg.norm(A)) / float(
        jnp.linalg.norm(u) * jnp.linalg.norm(v))
    fact = sess.delta(LowRankOp(u, jnp.asarray([scale]), v))
    assert sess.history[-1]["kind"] == "refine"
    assert "update" not in sess.counts()
    A2 = A + scale * (u @ v)
    assert _accuracy(fact, A2) <= STOL


def test_session_residual_history():
    A, _ = ZOO["tall"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    sess.update(_drifted(A, jax.random.fold_in(KEY, 7)))
    assert all("residual" in rec for rec in sess.history)
    assert all(rec["residual"] < 1e-4 for rec in sess.history)
    quiet = session(A, SPEC, key=KEY, track_residuals=False)
    quiet.solve()
    assert "residual" not in quiet.history[-1]


def test_session_compiles_twice_for_many_solves():
    """One cold-budget trace + one refine-budget trace cover an arbitrary
    stream of same-shaped updates."""
    A, _ = ZOO["wide"]
    clear_plan_cache()
    base = trace_count()
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    for t in range(4):
        sess.update(_drifted(A, jax.random.fold_in(KEY, 20 + t)))
    assert trace_count() - base == 2
    assert sess.counts()["refine"] == 4


def test_session_save_restore_roundtrip(tmp_path):
    A, _ = ZOO["lowrank_noise"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    A2 = _drifted(A, jax.random.fold_in(KEY, 8))
    sess.update(A2)
    sess.save(str(tmp_path))

    back = Session.restore(str(tmp_path), A2, key=KEY)
    assert back.solves == sess.solves
    assert back.history == sess.history
    assert back.spec == sess.spec
    for a, b in zip(jax.tree.leaves(back.fact), jax.tree.leaves(sess.fact)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert back.fact.method == sess.fact.method
    # the restored session keeps tracking warm
    back.update(_drifted(A2, jax.random.fold_in(KEY, 9)))
    assert back.history[-1]["kind"] == "refine"


def test_load_latest_into_live_session(tmp_path):
    A, _ = ZOO["graded"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    sess.save(str(tmp_path))
    fresh = session(A, SPEC, key=KEY)
    assert fresh.fact is None
    assert fresh.load_latest(str(tmp_path))
    assert fresh.solves == 1 and fresh.fact is not None
    assert not session(A, SPEC, key=KEY).load_latest(str(tmp_path / "no"))


def test_update_with_new_shape_restarts_not_crashes():
    """A geometry change under the session is maximal drift: route to the
    cold/restart branch instead of a shape-mismatched drift matmat."""
    A, _ = ZOO["lowrank_noise"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    B = make_lowrank(jax.random.PRNGKey(5), 40, 24, R)
    fact = sess.update(B)
    assert sess.history[-1]["kind"] == "restart"
    assert fact.shape == (40, 24)
    assert _accuracy(fact, B) <= STOL


def test_refine_uses_session_key_stream_for_sketch(recwarn):
    """rsvd has no warm-start seam — refines must draw from the session's
    key stream, not warn and fall back to PRNGKey(0)."""
    import warnings
    from repro.api import ImplicitKeyWarning
    A, _ = ZOO["lowrank_noise"]
    sess = session(A, SVDSpec(method="rsvd", rank=4, power_iters=2),
                   key=KEY)
    with warnings.catch_warnings():
        warnings.simplefilter("error", ImplicitKeyWarning)
        sess.solve()
        sess.update(_drifted(A, jax.random.fold_in(KEY, 30)))


def test_session_save_keep_n(tmp_path):
    A, _ = ZOO["graded"]
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    import os
    for s in (1, 2, 3, 4):
        sess.save(str(tmp_path), s, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert "step_3" in names and "step_4" in names
    assert "step_1" not in names and "step_2" not in names
