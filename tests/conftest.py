"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see the
1 real CPU device; only the dry-run forces 512 placeholder devices (and the
distributed tests spawn subprocesses with their own flags)."""
import jax
import jax.numpy as jnp
import pytest

try:                                  # hypothesis is a dev/CI requirement
    import os

    import hypothesis

    # CI runs the property suites under HYPOTHESIS_PROFILE=ci: fixed,
    # derandomized examples so per-PR runs are reproducible.  Hypothesis
    # does not read the env var on its own — load_profile is required.
    hypothesis.settings.register_profile(
        "ci", max_examples=40, deadline=None, derandomize=True)
    hypothesis.settings.register_profile(
        "dev", max_examples=10, deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture(scope="module", autouse=True)
def _clear_jit_caches():
    """Drop compiled-executable caches between test modules.

    The suite compiles hundreds of distinct programs (kernel sweeps, ten
    architectures, trainer graphs); without this the CPU JIT's resident
    code pushes the host OOM near the end of a full run ("LLVM compilation
    error: Cannot allocate memory")."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_lowrank(key, m: int, n: int, rank: int, dtype=jnp.float32):
    """Synthetic fixed-rank matrix, the paper's test input (§6.1)."""
    k1, k2 = jax.random.split(key)
    M = jax.random.normal(k1, (m, rank), dtype)
    N = jax.random.normal(k2, (rank, n), dtype)
    return M @ N
