"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see the
1 real CPU device; only the dry-run forces 512 placeholder devices (and the
distributed tests spawn subprocesses with their own flags).

Tests that need a real in-process mesh carry the ``distributed`` marker and
auto-skip below 8 devices; the dedicated CI job (and local runs of the
battery) provide them via

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m pytest -m distributed
"""
import jax
import jax.numpy as jnp
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "distributed: needs >= 8 jax devices; run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 (auto-skipped "
        "otherwise)")


def pytest_collection_modifyitems(config, items):
    if jax.device_count() >= 8:
        return
    skip = pytest.mark.skip(
        reason="needs 8 devices; set "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    for item in items:
        if "distributed" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def mesh8():
    """Row-sharded 8-way mesh (the canonical distributed-test layout)."""
    from repro.launch.mesh import make_mesh
    return make_mesh((8,), ("data",))

try:                                  # hypothesis is a dev/CI requirement
    import os

    import hypothesis

    # CI runs the property suites under HYPOTHESIS_PROFILE=ci: fixed,
    # derandomized examples so per-PR runs are reproducible.  Hypothesis
    # does not read the env var on its own — load_profile is required.
    hypothesis.settings.register_profile(
        "ci", max_examples=40, deadline=None, derandomize=True)
    hypothesis.settings.register_profile(
        "dev", max_examples=10, deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture(scope="module", autouse=True)
def _clear_jit_caches():
    """Drop compiled-executable caches between test modules.

    The suite compiles hundreds of distinct programs (kernel sweeps, ten
    architectures, trainer graphs); without this the CPU JIT's resident
    code pushes the host OOM near the end of a full run ("LLVM compilation
    error: Cannot allocate memory")."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_lowrank(key, m: int, n: int, rank: int, dtype=jnp.float32):
    """Synthetic fixed-rank matrix, the paper's test input (§6.1)."""
    k1, k2 = jax.random.split(key)
    M = jax.random.normal(k1, (m, rank), dtype)
    N = jax.random.normal(k2, (rank, n), dtype)
    return M @ N
