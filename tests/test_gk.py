"""Algorithm 1 invariants: orthonormal bases, the bidiagonal identity,
breakdown-based rank detection, host/in-graph agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.core import gk_bidiag, gk_bidiag_host
from repro.core.operators import DenseOp
from repro.core.tridiag import btb_tridiagonal


def bidiag_matrix(res, k):
    """Assemble B_{k+1,k} from the stored scalars."""
    B = np.zeros((k + 1, k))
    al = np.asarray(res.alphas)
    be = np.asarray(res.betas)
    for i in range(k):
        B[i, i] = al[i]
        B[i + 1, i] = be[i]
    return B


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
@pytest.mark.parametrize("m,n", [(120, 80), (64, 150)])
def test_orthonormal_bases(rng, runner, m, n):
    A = jax.random.normal(rng, (m, n))
    k = 40
    res = runner(A, k)
    kp = int(res.kprime)
    P = np.asarray(res.P[:, :kp])
    Q = np.asarray(res.Q[:, :kp + 1])
    np.testing.assert_allclose(P.T @ P, np.eye(kp), atol=5e-5)
    np.testing.assert_allclose(Q[:, :kp].T @ Q[:, :kp], np.eye(kp),
                               atol=5e-5)


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
def test_bidiag_identity(rng, runner):
    """A P_k = Q_{k+1} B_{k+1,k} (paper eq. 10)."""
    m, n, k = 90, 70, 25
    A = jax.random.normal(rng, (m, n))
    res = runner(A, k)
    kp = int(res.kprime)
    B = bidiag_matrix(res, kp)
    lhs = np.asarray(A) @ np.asarray(res.P[:, :kp])
    rhs = np.asarray(res.Q[:, :kp + 1]) @ B[:kp + 1, :kp]
    np.testing.assert_allclose(lhs, rhs, atol=2e-3)


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
@pytest.mark.parametrize("rank", [5, 17])
def test_breakdown_detects_rank(rng, runner, rank):
    """Krylov breakdown fires within a couple of iterations of the numerical
    rank (paper Table 1a: 102-105 iterations for rank-100 inputs)."""
    A = make_lowrank(rng, 100, 80, rank)
    res = runner(A, 60)
    assert bool(res.breakdown)
    assert rank <= int(res.kprime) <= rank + 3


def test_host_and_graph_agree(rng):
    rank = 10
    A = make_lowrank(rng, 80, 60, rank)
    r1 = gk_bidiag(A, 30, key=jax.random.PRNGKey(7))
    r2 = gk_bidiag_host(A, 30, key=jax.random.PRNGKey(7))
    assert int(r1.kprime) == int(r2.kprime)
    kp = int(r1.kprime)
    # the final direction at breakdown is roundoff-dominated (it spans the
    # exhausted complement); compare only the converged entries + top Ritz
    np.testing.assert_allclose(np.asarray(r1.alphas[:kp - 1]),
                               np.asarray(r2.alphas[:kp - 1]), rtol=2e-3)
    t1 = np.linalg.eigvalsh(np.asarray(btb_tridiagonal(r1.alphas, r1.betas)))
    t2 = np.linalg.eigvalsh(np.asarray(btb_tridiagonal(r2.alphas, r2.betas)))
    np.testing.assert_allclose(t1[-rank:], t2[-rank:], rtol=1e-2)


def test_start_vector_convention(rng):
    """Paper line 1: q1 ~ N(2, 1) — mean ~2 (sanity on the odd convention)."""
    from repro.core.gk import start_vector
    v = start_vector(rng, 10000)
    assert 1.9 < float(v.mean()) < 2.1


def _old_carry_gk_bidiag(A, k, *, key, reorth_passes=2):
    """The seed's fori_loop with whole-buffer ``jnp.where`` carries.

    Step math is shared with the production implementation (``gk._step`` /
    ``gk._rstep``), so comparing against ``gk_bidiag`` isolates exactly the
    carry rewrite (masked per-column ``dynamic_update_slice``) — which must
    be a pure traffic optimization, bit-for-bit.
    """
    from repro.core import gk as G
    from repro.core.operators import as_operator
    op = as_operator(A)
    m, n = op.shape
    dtype = jnp.float32
    q1 = G.start_vector(key, m, dtype)
    beta1 = jnp.linalg.norm(q1)
    q = q1 / beta1
    p = op.rmv(q).astype(dtype)
    alpha1 = jnp.linalg.norm(p)
    p = p / jnp.where(alpha1 > 0, alpha1, 1.0)
    Q = jnp.zeros((m, k + 1), dtype).at[:, 0].set(q)
    P = jnp.zeros((n, k), dtype).at[:, 0].set(p)
    alphas = jnp.zeros((k,), dtype).at[0].set(alpha1)
    betas = jnp.zeros((k,), dtype)
    eff = max(1e-8, 40.0 * float(jnp.finfo(dtype).eps))
    thresh = eff * jnp.maximum(alpha1, 1.0)

    def body(i, c):
        Qb, Pb, al, be, qv, pv, kp, done = c
        u, beta = G._step(op, pv, qv, al[i - 1], Qb, reorth_passes)
        hit = beta < thresh
        done1 = jnp.logical_or(done, hit)
        qn = u / jnp.where(beta > 0, beta, 1.0)
        v, alpha = G._rstep(op, qn, pv, beta, Pb, reorth_passes)
        done2 = jnp.logical_or(done1, alpha < thresh)
        pn = v / jnp.where(alpha > 0, alpha, 1.0)
        keep = jnp.logical_not(done1)
        keep2 = jnp.logical_not(done2)
        Qn = jnp.where(keep, Qb.at[:, i].set(qn).astype(dtype), Qb)
        Pn = jnp.where(keep2, Pb.at[:, i].set(pn), Pb)
        al_n = jnp.where(keep2, al.at[i].set(alpha), al)
        be_n = jnp.where(keep, be.at[i - 1].set(beta), be)
        kp_n = jnp.where(done2, kp, kp + 1)
        return (Qn, Pn, al_n, be_n, jnp.where(keep, qn, qv),
                jnp.where(keep2, pn, pv), kp_n, done2)

    c = jax.lax.fori_loop(1, k, body,
                          (Q, P, alphas, betas, q, p,
                           jnp.asarray(1, jnp.int32), jnp.asarray(False)))
    Qb, Pb, al, be, qv, pv, kp, done = c
    u, beta = G._step(op, pv, qv, al[kp - 1], Qb, reorth_passes)
    valid = jnp.logical_not(done) & (beta >= thresh)
    qn = u / jnp.where(beta > 0, beta, 1.0)
    Qf = jnp.where(valid, Qb.at[:, kp].set(qn.astype(dtype)), Qb)
    be_f = jnp.where(valid, be.at[kp - 1].set(beta), be)
    return G.GKResult(al, be_f, beta1, Pb, Qf, kp, done)


@pytest.mark.parametrize("case", ["fullrank", "breakdown"])
def test_column_carry_bit_equal_old_carry(case):
    """The masked per-column dynamic_update_slice carry is bit-identical to
    the seed's whole-buffer jnp.where carry — including when breakdown
    masking freezes the buffers mid-loop."""
    key = jax.random.PRNGKey(42)
    if case == "fullrank":
        A = jax.random.normal(key, (100, 70))
        k = 25
    else:
        A = make_lowrank(key, 100, 80, 8)       # breakdown around i=8-11
        k = 30
    new = gk_bidiag(A, k, key=jax.random.PRNGKey(7))
    old = _old_carry_gk_bidiag(A, k, key=jax.random.PRNGKey(7))
    for name in new._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(new, name)), np.asarray(getattr(old, name)),
            err_msg=f"carry rewrite changed GKResult.{name}")


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
def test_pallas_fused_step_matches_xla(rng, runner):
    """DenseOp(backend='pallas') routes the whole half-iteration through
    the fused gk_step kernels; the bases/recurrence must match the xla
    composition to f32 blocking-order accuracy."""
    from repro.core.operators import DenseOp
    A = jax.random.normal(rng, (120, 90))
    k = 20
    r_x = runner(DenseOp(A), k, key=jax.random.PRNGKey(3))
    r_p = runner(DenseOp(A, backend="pallas"), k, key=jax.random.PRNGKey(3))
    assert int(r_x.kprime) == int(r_p.kprime)
    np.testing.assert_allclose(np.asarray(r_x.alphas),
                               np.asarray(r_p.alphas), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(r_x.betas),
                               np.asarray(r_p.betas), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(r_x.Q), np.asarray(r_p.Q),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
def test_bf16_precision_basis(rng, runner):
    """precision='bf16' stores the bases half-width; the recurrence scalars
    stay f32 and track the full-precision run to bf16 accuracy."""
    A = jax.random.normal(rng, (120, 90))
    k = 15
    full = runner(A, k, key=jax.random.PRNGKey(5))
    half = runner(A, k, key=jax.random.PRNGKey(5), precision="bf16")
    assert half.Q.dtype == jnp.bfloat16 and half.P.dtype == jnp.bfloat16
    assert half.alphas.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(half.alphas),
                               np.asarray(full.alphas), rtol=0.05, atol=0.05)
    # bf16-stored basis columns stay orthonormal to storage accuracy
    Q = np.asarray(half.Q, np.float32)
    np.testing.assert_allclose(Q.T @ Q, np.eye(Q.shape[1]), atol=0.05)


def test_fused_matvec_linop_equivalence(rng):
    """LinOp default fused path == explicit composition."""
    A = jax.random.normal(rng, (50, 40))
    op = DenseOp(A)
    p = jax.random.normal(jax.random.PRNGKey(1), (40,))
    y = jax.random.normal(jax.random.PRNGKey(2), (50,))
    np.testing.assert_allclose(np.asarray(op.mv_fused(p, y, 0.5)),
                               np.asarray(A @ p - 0.5 * y), rtol=1e-5)
