"""Algorithm 1 invariants: orthonormal bases, the bidiagonal identity,
breakdown-based rank detection, host/in-graph agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.core import gk_bidiag, gk_bidiag_host
from repro.core.linop import from_dense
from repro.core.tridiag import btb_tridiagonal


def bidiag_matrix(res, k):
    """Assemble B_{k+1,k} from the stored scalars."""
    B = np.zeros((k + 1, k))
    al = np.asarray(res.alphas)
    be = np.asarray(res.betas)
    for i in range(k):
        B[i, i] = al[i]
        B[i + 1, i] = be[i]
    return B


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
@pytest.mark.parametrize("m,n", [(120, 80), (64, 150)])
def test_orthonormal_bases(rng, runner, m, n):
    A = jax.random.normal(rng, (m, n))
    k = 40
    res = runner(A, k)
    kp = int(res.kprime)
    P = np.asarray(res.P[:, :kp])
    Q = np.asarray(res.Q[:, :kp + 1])
    np.testing.assert_allclose(P.T @ P, np.eye(kp), atol=5e-5)
    np.testing.assert_allclose(Q[:, :kp].T @ Q[:, :kp], np.eye(kp),
                               atol=5e-5)


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
def test_bidiag_identity(rng, runner):
    """A P_k = Q_{k+1} B_{k+1,k} (paper eq. 10)."""
    m, n, k = 90, 70, 25
    A = jax.random.normal(rng, (m, n))
    res = runner(A, k)
    kp = int(res.kprime)
    B = bidiag_matrix(res, kp)
    lhs = np.asarray(A) @ np.asarray(res.P[:, :kp])
    rhs = np.asarray(res.Q[:, :kp + 1]) @ B[:kp + 1, :kp]
    np.testing.assert_allclose(lhs, rhs, atol=2e-3)


@pytest.mark.parametrize("runner", [gk_bidiag, gk_bidiag_host])
@pytest.mark.parametrize("rank", [5, 17])
def test_breakdown_detects_rank(rng, runner, rank):
    """Krylov breakdown fires within a couple of iterations of the numerical
    rank (paper Table 1a: 102-105 iterations for rank-100 inputs)."""
    A = make_lowrank(rng, 100, 80, rank)
    res = runner(A, 60)
    assert bool(res.breakdown)
    assert rank <= int(res.kprime) <= rank + 3


def test_host_and_graph_agree(rng):
    rank = 10
    A = make_lowrank(rng, 80, 60, rank)
    r1 = gk_bidiag(A, 30, key=jax.random.PRNGKey(7))
    r2 = gk_bidiag_host(A, 30, key=jax.random.PRNGKey(7))
    assert int(r1.kprime) == int(r2.kprime)
    kp = int(r1.kprime)
    # the final direction at breakdown is roundoff-dominated (it spans the
    # exhausted complement); compare only the converged entries + top Ritz
    np.testing.assert_allclose(np.asarray(r1.alphas[:kp - 1]),
                               np.asarray(r2.alphas[:kp - 1]), rtol=2e-3)
    t1 = np.linalg.eigvalsh(np.asarray(btb_tridiagonal(r1.alphas, r1.betas)))
    t2 = np.linalg.eigvalsh(np.asarray(btb_tridiagonal(r2.alphas, r2.betas)))
    np.testing.assert_allclose(t1[-rank:], t2[-rank:], rtol=1e-2)


def test_start_vector_convention(rng):
    """Paper line 1: q1 ~ N(2, 1) — mean ~2 (sanity on the odd convention)."""
    from repro.core.gk import start_vector
    v = start_vector(rng, 10000)
    assert 1.9 < float(v.mean()) < 2.1


def test_fused_matvec_linop_equivalence(rng):
    """LinOp default fused path == explicit composition."""
    A = jax.random.normal(rng, (50, 40))
    op = from_dense(A)
    p = jax.random.normal(jax.random.PRNGKey(1), (40,))
    y = jax.random.normal(jax.random.PRNGKey(2), (50,))
    np.testing.assert_allclose(np.asarray(op.mv_fused(p, y, 0.5)),
                               np.asarray(A @ p - 0.5 * y), rtol=1e-5)
