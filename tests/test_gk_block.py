"""Block GK bidiagonalization (beyond-paper MXU adaptation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.core.gk_block import fsvd_block, gk_block_host
from repro.core.fsvd import fsvd


def test_block_bases_orthonormal(rng):
    A = jax.random.normal(rng, (200, 150))
    res = gk_block_host(A, block=16, steps=4)
    Q, P = np.asarray(res.Q), np.asarray(res.P)
    np.testing.assert_allclose(Q.T @ Q, np.eye(Q.shape[1]), atol=1e-4)
    np.testing.assert_allclose(P.T @ P, np.eye(P.shape[1]), atol=1e-4)


def test_projection_identity(rng):
    """K == Qᵀ A P (the block-bidiagonal assembly is consistent)."""
    A = jax.random.normal(rng, (120, 90))
    res = gk_block_host(A, block=8, steps=5)
    K_direct = np.asarray(res.Q).T @ np.asarray(A) @ np.asarray(res.P)
    np.testing.assert_allclose(np.asarray(res.K), K_direct, atol=2e-3)


@pytest.mark.parametrize("m,n,rank,r", [(300, 200, 40, 10), (150, 220, 25, 25)])
def test_fsvd_block_matches_dense(rng, m, n, rank, r):
    A = make_lowrank(rng, m, n, rank)
    out = fsvd_block(A, r, block=max(16, r), steps=6)
    s_true = jnp.linalg.svd(A, compute_uv=False)[:r]
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=2e-3)
    # triplet quality against dense SVD
    U, s, Vt = jnp.linalg.svd(A, full_matrices=False)
    qual = np.abs(np.sum(np.asarray(out.U) * np.asarray(U[:, :r]), 0)) \
        * np.abs(np.sum(np.asarray(out.V) * np.asarray(Vt[:r].T), 0))
    assert qual.min() > 0.99


def test_block_and_vector_paths_agree(rng):
    A = make_lowrank(rng, 256, 180, 30)
    out_b = fsvd_block(A, 8, block=32, steps=4)
    out_v = fsvd(A, 8, 120, host_loop=True)
    np.testing.assert_allclose(np.asarray(out_b.s), np.asarray(out_v.s),
                               rtol=1e-3)


def test_block_breakdown_on_lowrank(rng):
    """Rank < block: the second step's slab is rank-deficient -> breakdown
    fires and the captured spectrum is still exact."""
    A = make_lowrank(rng, 150, 100, 12)
    out = fsvd_block(A, 12, block=16, steps=6)
    s_true = jnp.linalg.svd(A, compute_uv=False)[:12]
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=1e-3)


def test_fewer_passes_than_vector_lanczos(rng):
    """The block method reaches top-r convergence in ~3r/b + 2 passes over A
    vs ~4r passes for vector Lanczos — the A-traffic win."""
    A = make_lowrank(rng, 400, 300, 60)
    r, b = 16, 64
    out = fsvd_block(A, r, block=b, steps=3)   # 3 passes over A
    s_true = jnp.linalg.svd(A, compute_uv=False)[:r]
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(s_true),
                               rtol=1e-3)


# --------------------------------------------------------------------------
# streaming blocked solver (fsvd_blocked)
# --------------------------------------------------------------------------

def test_fsvd_blocked_rank_deficient_stays_orthonormal(rng):
    """Rank-deficient operand, more triplets requested than exist: the
    rank-revealing MGS expansion must not fabricate basis directions
    (Householder QR of a rank-deficient block would), so Ritz values stay
    bounded by sigma_max and the zero triplets come back as exact zeros."""
    from repro.core.gk_block import fsvd_blocked
    A = make_lowrank(rng, 40, 30, 4)
    s_true = jnp.linalg.svd(A, compute_uv=False)
    res = fsvd_blocked(A, 8, key=jax.random.PRNGKey(3))
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.s), np.asarray(s_true[:8]),
                               atol=1e-4 * float(s_true[0]))
    # returned bases are orthonormal despite the deficient expansion
    for M in (res.U[:, :4], res.V[:, :4]):
        Mn = np.asarray(M)
        np.testing.assert_allclose(Mn.T @ Mn, np.eye(4), atol=1e-3)


def test_fsvd_blocked_locks_across_restarts(rng):
    """A basis budget far below what one cycle needs forces many restart
    cycles; locking must still assemble all requested triplets."""
    from repro.core.gk_block import fsvd_blocked
    A = make_lowrank(rng, 120, 100, 20) \
        + 1e-4 * jax.random.normal(jax.random.PRNGKey(1), (120, 100))
    s_true = jnp.linalg.svd(A, compute_uv=False)
    res = fsvd_blocked(A, 12, block=4, max_basis=14,
                       key=jax.random.PRNGKey(5))
    assert res.converged and res.restarts > 1
    np.testing.assert_allclose(np.asarray(res.s), np.asarray(s_true[:12]),
                               atol=5e-4 * float(s_true[0]))


def test_mgs_block_gram_keeps_large_scale_blocks():
    """Regression: the eigQR drop threshold must be relative to each
    pass's own input scale.  A stale first-pass scale made the second
    pass (unit columns vs a huge raw-block scale) drop EVERY column once
    ``max‖w‖ > 1/drop`` — e.g. any distributed fsvd_blocked expansion
    ``Aᵀ(A V)``, which scales as σ_max(A)²."""
    from repro.core.gk_block import _mgs_block, _mgs_block_gram
    key = jax.random.PRNGKey(0)
    W = 1e4 * jax.random.normal(key, (64, 8))
    empty = jnp.zeros((64, 0), jnp.float32)
    Q = _mgs_block_gram(W, (empty,))
    assert Q.shape == (64, 8)
    # orthonormal to working precision
    err = jnp.max(jnp.abs(Q.T @ Q - jnp.eye(8)))
    assert float(err) < 1e-5
    # spans the same subspace as the per-column MGS reference
    Qref = _mgs_block(W, (empty,))
    cos = jnp.linalg.svd(Qref.T @ Q, compute_uv=False)
    assert float(jnp.min(cos)) > 1 - 1e-5


def test_mgs_block_gram_drops_spanned_columns():
    """The rank-revealing contract survives the fix: columns already in
    the span of the bases (or duplicated within the block) are dropped,
    never completed arbitrarily."""
    from repro.core.gk_block import _mgs_block_gram
    key = jax.random.PRNGKey(1)
    B = jnp.linalg.qr(jax.random.normal(key, (48, 4)))[0]
    fresh = jax.random.normal(jax.random.PRNGKey(2), (48, 3))
    W = jnp.concatenate([B @ (B.T @ fresh[:, :1]) * 50.0,   # spanned by B
                         fresh,
                         fresh[:, :1] * 2.0], axis=1)       # duplicate
    Q = _mgs_block_gram(W, (B,))
    assert Q.shape[1] == 3
    assert float(jnp.max(jnp.abs(B.T @ Q))) < 1e-5
