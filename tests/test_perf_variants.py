"""Numerical equivalence of the §Perf hillclimb variants:
online-softmax attention, DUS cache update, remat policies, and the
trip-count-aware HLO analyzer itself."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.configs import get_arch
from repro.launch import hlo_analysis as H
from repro.models import model as M
from repro.models.layers import ParamBag


@pytest.fixture(scope="module")
def attn_setup():
    cfg = get_arch("gemma2-9b").reduced(sliding_window=16, num_layers=1)
    bag = ParamBag(jax.random.PRNGKey(0))
    A.init_gqa(bag, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    return cfg, bag.params["attn"], x, pos


@pytest.mark.parametrize("window", [A.GLOBAL_WINDOW, 16])
@pytest.mark.parametrize("cap", [None, 50.0])
@pytest.mark.parametrize("q_chunk", [16, 32])
def test_online_softmax_matches_full(attn_setup, window, cap, q_chunk):
    cfg, p, x, pos = attn_setup
    c_full = dataclasses.replace(cfg, attn_impl="full",
                                 attn_logit_softcap=cap)
    c_onl = dataclasses.replace(cfg, attn_impl="online", q_chunk=q_chunk,
                                attn_logit_softcap=cap)
    o1, _ = A.gqa_attention(p, x, pos, c_full, window=window)
    o2, _ = A.gqa_attention(p, x, pos, c_onl, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_online_softmax_grads_match(attn_setup):
    cfg, p, x, pos = attn_setup
    c_full = dataclasses.replace(cfg, attn_impl="full")
    c_onl = dataclasses.replace(cfg, attn_impl="online", q_chunk=16)

    def loss(impl_cfg, xx):
        out, _ = A.gqa_attention(p, xx, pos, impl_cfg)
        return jnp.sum(out ** 2)

    g1 = jax.grad(lambda xx: loss(c_full, xx))(x)
    g2 = jax.grad(lambda xx: loss(c_onl, xx))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


def test_dus_cache_update_matches_blend(attn_setup):
    cfg, p, x, pos = attn_setup
    cache = A.init_gqa_cache(cfg, 2, 64, jnp.float32)
    tok, tpos = x[:, 10:11], jnp.full((2, 1), 10, jnp.int32)
    _, c1 = A.gqa_attention(p, tok, tpos, cfg, cache=cache)
    _, c2 = A.gqa_attention(
        p, tok, tpos, dataclasses.replace(cfg, cache_update="dus"),
        cache=cache)
    for k in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))


def test_online_impl_full_model_loss():
    cfg = get_arch("gemma-7b").reduced()
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    l_full, _ = M.loss_fn(params, batch,
                          dataclasses.replace(cfg, attn_impl="full"))
    l_onl, _ = M.loss_fn(params, batch,
                         dataclasses.replace(cfg, attn_impl="online",
                                             q_chunk=16))
    assert abs(float(l_full) - float(l_onl)) < 1e-3


@pytest.mark.parametrize("policy", ["none", "dots", "nothing"])
def test_remat_policies_same_loss(policy):
    cfg = get_arch("stablelm-1.6b").reduced(remat_policy=policy)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    loss, _ = M.loss_fn(params, batch, cfg)
    g = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(g))


# ---------------------------------------------------------------------------
# HLO analyzer ground truths
# ---------------------------------------------------------------------------

def test_hlo_analyzer_scan_trip_count():
    m = 256
    A_ = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def scanned(a):
        def body(x, _):
            return x @ x, None
        out, _ = jax.lax.scan(body, a, None, length=7)
        return out

    txt = jax.jit(scanned).lower(A_).compile().as_text()
    cost = H.analyze(txt, vmem_threshold=0)
    expect = 7 * 2 * m ** 3
    assert abs(cost.dot_flops - expect) / expect < 0.01


def test_hlo_analyzer_plain_matmul():
    m = 512
    A_ = jax.ShapeDtypeStruct((m, m), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(A_, A_).compile().as_text()
    cost = H.analyze(txt, vmem_threshold=0)
    assert abs(cost.dot_flops - 2 * m ** 3) / (2 * m ** 3) < 0.01
    # reads 2 x 1MB + writes 1MB
    assert 2.5e6 < cost.hbm_bytes < 4e6


def test_hlo_analyzer_vmem_threshold():
    m = 128   # 64 KiB buffers — below any reasonable VMEM threshold
    A_ = jax.ShapeDtypeStruct((m, m), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(A_, A_).compile().as_text()
    cost = H.analyze(txt, vmem_threshold=2**20)
    assert cost.hbm_bytes == 0.0
    assert cost.dot_flops > 0    # flops still counted


def test_hlo_analyzer_type_bytes():
    from repro.launch.hlo_analysis import _first_type_bytes
    assert _first_type_bytes("bf16[2,3]{1,0}") == 12
    assert _first_type_bytes("(f32[4]{0}, s32[2]{0})") == 24
    assert _first_type_bytes("f32[]") == 4
