"""Fault-tolerant serving: supervisor restart of crashed/hung dispatch
workers, the stop()/submit() shutdown race, cancel-on-timeout slot
release, deadline admission, NaN quarantine (and why it must happen
before batching), transient retry, circuit breaking and probe-gated
degraded answers."""
import threading
import time

import jax
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import SVDSpec
from repro.runtime import faults
from repro.serve import (ContinuousBatcher, DeadlineExceeded,
                         DegradedRejected, PoisonedOperand, SolveServer,
                         WorkerCrashed)

KEY = jax.random.PRNGKey(3)
SERVE_SPEC = SVDSpec(method="fsvd", rank=4, max_iters=24)
SHAPE = (24, 16)


@pytest.fixture(autouse=True)
def _clean_failpoints():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _operand(seed=0, m=SHAPE[0], n=SHAPE[1]):
    return np.array(make_lowrank(jax.random.PRNGKey(seed), m, n, 4),
                    copy=True)


@pytest.fixture(scope="module")
def server():
    """One warmed module-scoped server: resilience counters are asserted
    as before/after deltas so tests stay order-independent."""
    srv = SolveServer(SERVE_SPEC, key=KEY, window_ms=2.0,
                      hang_timeout_s=30.0, max_retries=2,
                      retry_backoff_ms=1.0, breaker_threshold=2,
                      breaker_reset_s=0.3)
    srv.warmup([SHAPE])
    yield srv
    faults.disarm_all()
    srv.close()


# ---------------------------------------------------------------------------
# batcher supervisor (no solver involved)
# ---------------------------------------------------------------------------

def _echo_batcher(**kw):
    def dispatch(group, tickets):
        for t in tickets:
            t._resolve(t.payload)
    return ContinuousBatcher(dispatch, **kw)


def test_worker_crash_fails_inflight_only_and_restarts():
    """serve.dispatch raise-mode kills the worker mid-batch: the
    in-flight tickets fail with WorkerCrashed (typed, retryable), the
    supervisor restarts the worker, and tickets queued behind the crash
    are served by the successor."""
    release = threading.Event()

    def dispatch(group, tickets):
        release.wait(5.0)
        for t in tickets:
            t._resolve(t.payload)

    b = ContinuousBatcher(dispatch, max_batch=1, window_ms=1.0,
                          watchdog_interval_s=0.01)
    try:
        faults.arm(faults.SERVE_DISPATCH, mode="raise", p=1.0, max_fires=1)
        doomed = b.submit("g", "doomed")
        with pytest.raises(WorkerCrashed):
            doomed.result(timeout=5.0)
        release.set()
        survivor = b.submit("g", "survivor")
        assert survivor.result(timeout=5.0) == "survivor"
        deadline = time.perf_counter() + 5.0
        while b.restarts < 1 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert b.restarts == 1 and b.crashes == 1
        assert b.pending == 0
    finally:
        faults.disarm_all()
        b.stop()


def test_hung_dispatch_is_detected_and_worker_restarted():
    """delay-mode injection overruns hang_timeout_s: the watchdog fails
    the in-flight batch and a fresh worker serves what follows."""
    b = _echo_batcher(max_batch=1, window_ms=1.0, hang_timeout_s=0.1,
                      watchdog_interval_s=0.01)
    try:
        faults.arm(faults.SERVE_DISPATCH, mode="delay", p=1.0,
                   delay_s=1.0, max_fires=1)
        hung = b.submit("g", "hung")
        with pytest.raises(WorkerCrashed, match="hang_timeout"):
            hung.result(timeout=5.0)
        assert b.submit("g", "after").result(timeout=5.0) == "after"
        assert b.restarts >= 1
    finally:
        faults.disarm_all()
        b.stop()


def test_stop_submit_race_every_ticket_terminates():
    """Regression: a ticket whose enqueue lands AFTER the stopping
    worker's final drain used to sit in the intake queue forever.  Park
    the submitter exactly on that boundary (its put is delayed until the
    drain completed) and require the ticket to terminate with a typed
    RuntimeError — and the backpressure slot to be released."""
    b = _echo_batcher(max_batch=4, window_ms=1.0)
    in_put = threading.Event()
    real_put = b._intake.put

    def parked_put(item, *a, **kw):
        if getattr(item, "payload", None) == "straggler":
            in_put.set()
            b._stopped.wait(5.0)      # park until the drain has passed
        real_put(item, *a, **kw)

    b._intake.put = parked_put
    out = {}

    def racer():
        try:
            t = b.submit("g", "straggler")
            try:
                t.result(timeout=5.0)
                out["outcome"] = "resolved"
            except RuntimeError as e:
                out["outcome"] = ("failed", str(e))
        except RuntimeError as e:
            out["outcome"] = ("refused", str(e))

    thread = threading.Thread(target=racer)
    thread.start()
    assert in_put.wait(5.0)           # submitter passed the stopping check
    b.stop(timeout=5.0)
    thread.join(timeout=10.0)
    assert not thread.is_alive(), "straggler submit never terminated"
    assert out["outcome"][0] == "failed"
    assert "stopping" in out["outcome"][1]
    assert b.pending == 0             # slot released, not leaked


def test_cancel_on_timeout_releases_backpressure_slot():
    """result(cancel_on_timeout=True) must free the max_queue slot an
    abandoned request occupies; without the cancel the slot stays pinned
    until its group flushes."""
    started, release = threading.Event(), threading.Event()

    def dispatch(group, tickets):
        started.set()
        release.wait(10.0)
        for t in tickets:
            t._resolve("ok")

    b = ContinuousBatcher(dispatch, max_batch=1, window_ms=1.0, max_queue=2)
    try:
        b.submit("g", "blocker")
        assert started.wait(5.0)
        abandoned = b.submit("g", "abandoned")   # queue now full
        with pytest.raises(TimeoutError, match="slot released"):
            abandoned.result(timeout=0.05, cancel_on_timeout=True)
        assert abandoned.cancelled
        # the freed slot admits a new request instead of QueueFull
        replacement = b.submit("g", "replacement")
        release.set()
        assert replacement.result(timeout=5.0) == "ok"
    finally:
        release.set()
        b.stop()


def test_expired_property_and_deadline_storage():
    b = _echo_batcher(max_batch=8, window_ms=1.0)
    try:
        t = b.submit("g", 1, deadline_s=30.0)
        assert not t.expired and t.remaining_s() > 29.0
        t2 = b.submit("g", 2)
        assert t2.deadline_at is None and t2.remaining_s() is None
    finally:
        b.stop()


# ---------------------------------------------------------------------------
# server: quarantine, deadlines, retry, breaker, degraded mode
# ---------------------------------------------------------------------------

def test_nan_operand_quarantined_at_submit(server):
    before = server.stats()["quarantined"]
    bad = _operand(1)
    bad[2, 3] = np.nan
    with pytest.raises(PoisonedOperand):
        server.submit(bad)
    assert server.stats()["quarantined"] == before + 1


def test_nan_would_poison_a_vmapped_batch_clean_requests_stay_clean(server):
    """The regression the quarantine exists for: ONE NaN operand in a
    stacked vmapped solve contaminates every co-batched result.  Prove
    the hazard on the raw plan, then prove the server keeps co-submitted
    clean requests finite because the poisoned one never enters a
    batch."""
    import jax.numpy as jnp
    from repro.core.operators import DenseOp
    clean = [_operand(s) for s in (2, 3, 4)]
    bad = _operand(5)
    bad[0, 0] = np.nan
    stacked = jnp.stack([jnp.asarray(a) for a in clean + [bad]])
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        KEY, jnp.arange(4, dtype=jnp.uint32))
    fact, _ = server.plan.solve_batched(DenseOp(stacked), keys=keys,
                                        with_info=True)
    s3 = np.asarray(fact.s)[3]
    # the poisoned row's answer is garbage: NaN/Inf, or collapsed to zero
    # when the NaN washes out through a QR normalization
    assert (not np.isfinite(s3).all()) or not s3.any()
    # (documented hazard: with fsvd's shared reductions the contamination
    # can spread batch-wide; nothing downstream may rely on row isolation)

    tickets = [server.submit(a) for a in clean]
    with pytest.raises(PoisonedOperand):
        server.submit(bad)
    for t in tickets:
        res = t.result(timeout=60.0)
        assert np.isfinite(np.asarray(res.value.s)).all()


def test_deadline_enforced_at_dispatch_admission(server):
    before = server.stats()["deadline_drops"]
    t = server.submit(_operand(6), deadline_ms=0.001)
    with pytest.raises(DeadlineExceeded):
        t.result(timeout=30.0)
    assert server.stats()["deadline_drops"] == before + 1
    # a sane deadline still serves
    res = server.solve(_operand(7), deadline_ms=60000.0, timeout=60.0)
    assert np.isfinite(np.asarray(res.value.s)).all()


def test_transient_fault_retried_with_backoff(server):
    before = server.stats()["retries"]
    faults.arm(faults.PLAN_SOLVE, mode="raise", p=1.0, transient=True,
               max_fires=1)
    res = server.solve(_operand(8), timeout=60.0)
    faults.disarm_all()
    assert not res.meta.get("degraded")          # primary answered
    assert server.stats()["retries"] == before + 1


def test_primary_failure_degrades_with_probe_label(server):
    """A non-transient primary failure falls back to the cheap plan; the
    answer is labeled degraded, carries its probe value, and the probe
    actually certifies it against the operand."""
    before = server.stats()["degraded"]
    faults.arm(faults.PLAN_SOLVE, mode="raise", p=1.0, max_fires=1)
    res = server.solve(_operand(9), timeout=120.0)
    faults.disarm_all()
    assert res.meta["degraded"] is True
    assert res.meta["reason"] == "primary_failed"
    assert res.meta["method"] == "gnystrom"      # the default shed plan
    assert res.meta["probe"] <= server.degraded_tol
    s_true = np.linalg.svd(_operand(9), compute_uv=False)[:4]
    err = np.max(np.abs(np.asarray(res.value.s) - s_true)) / s_true[0]
    assert err < 0.05                            # cheap but not wrong
    assert server.stats()["degraded"] == before + 1
    assert server.stats()["degraded_fraction"] > 0.0


def test_degraded_method_is_configurable_and_reported():
    """Regression: the breaker's shed plan used to hardcode rsvd.  The
    method is now spec-configurable and every degraded answer reports
    which solver produced it."""
    srv = SolveServer(SERVE_SPEC, key=KEY, window_ms=2.0,
                      retry_backoff_ms=1.0, degraded_method="rsvd")
    try:
        faults.arm(faults.PLAN_SOLVE, mode="raise", p=1.0, max_fires=1)
        res = srv.solve(_operand(9), timeout=120.0)
        faults.disarm_all()
        assert res.meta["degraded"] is True
        assert res.meta["method"] == "rsvd"
        assert srv.degraded_method == "rsvd"
    finally:
        faults.disarm_all()
        srv.close()


def test_probe_gate_rejects_uncertifiable_degraded_answer(server):
    """With an impossible gate every degraded answer must be REFUSED —
    the server never returns an uncertified cheap result."""
    before = server.stats()["degraded_rejected"]
    old_tol = server.degraded_tol
    server.degraded_tol = -1.0                   # nothing can pass
    try:
        faults.arm(faults.PLAN_SOLVE, mode="raise", p=1.0, max_fires=1)
        with pytest.raises(DegradedRejected):
            server.solve(_operand(10), timeout=120.0)
    finally:
        faults.disarm_all()
        server.degraded_tol = old_tol
    assert server.stats()["degraded_rejected"] == before + 1


def test_breaker_opens_sheds_to_degraded_then_half_opens(server):
    """breaker_threshold=2 consecutive primary failures open the group's
    breaker: while open, requests are shed straight to the degraded path
    (reason=breaker_open, primary never touched); after breaker_reset_s
    the half-open trial lets the recovered primary close it again."""
    shed_before = server.stats()["breaker_open_shed"]
    # two consecutive primary failures; degraded also fails (fires left)
    # so the failures propagate as typed errors and the breaker counts 2
    faults.arm(faults.PLAN_SOLVE, mode="raise", p=1.0, max_fires=4)
    for _ in range(2):
        with pytest.raises(Exception):
            server.solve(_operand(11), timeout=60.0)
    faults.disarm_all()
    states = {k: v["state"]
              for k, v in server.stats()["health"]["breakers"].items()}
    assert "open" in states.values()
    res = server.solve(_operand(12), timeout=60.0)  # shed while open
    assert res.meta["degraded"] is True
    assert res.meta["reason"] == "breaker_open"
    assert server.stats()["breaker_open_shed"] > shed_before
    time.sleep(server.breaker_reset_s + 0.1)
    res2 = server.solve(_operand(13), timeout=60.0)  # half-open trial
    assert not res2.meta.get("degraded")             # primary recovered
    states = {k: v["state"]
              for k, v in server.stats()["health"]["breakers"].items()}
    assert "open" not in states.values()


def test_server_worker_death_recovery_end_to_end():
    """Satellite acceptance: kill the dispatch thread mid-batch via the
    serve.dispatch failpoint; the supervisor restarts it, the in-flight
    ticket fails cleanly (typed WorkerCrashed), tickets queued behind the
    crash complete, and stats()["worker_restarts"] == 1."""
    srv = SolveServer(SERVE_SPEC, key=KEY, window_ms=2.0,
                      hang_timeout_s=30.0)
    try:
        srv.warmup([SHAPE])
        faults.arm(faults.SERVE_DISPATCH, mode="raise", p=1.0, max_fires=1)
        doomed = srv.submit(_operand(20))
        with pytest.raises(WorkerCrashed):
            doomed.result(timeout=30.0)
        queued = [srv.submit(_operand(21 + i)) for i in range(3)]
        for t in queued:
            res = t.result(timeout=60.0)
            assert np.isfinite(np.asarray(res.value.s)).all()
        deadline = time.perf_counter() + 5.0
        while srv.stats()["worker_restarts"] < 1 \
                and time.perf_counter() < deadline:
            time.sleep(0.02)
        st = srv.stats()
        assert st["worker_restarts"] == 1
        assert st["worker_crashes"] == 1
    finally:
        faults.disarm_all()
        srv.close()


def test_health_block_shape(server):
    h = server.health()
    for k in ("worker_restarts", "worker_crashes", "quarantined",
              "deadline_drops", "retries", "degraded", "degraded_rejected",
              "breaker_open_shed", "degraded_fraction", "breakers"):
        assert k in h
    st = server.stats()
    assert st["health"]["quarantined"] == st["quarantined"]
