"""Rank-k update / downdate path (PR 7): ``repro.core.update``, the
``SolverPlan.update`` staging and the ``Session`` three-way policy.

The acceptance battery: ``update_factorization`` on a rank-k drifted
exact-rank operand must match a cold ``factorize`` of the drifted matrix
to the parity gate (1e-5 * sigma_max on singular values, principal-angle
cosines ~1) with ZERO GK iterations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_lowrank
from repro.api import (LowRankOp, Session, SVDSpec, clear_plan_cache,
                       downdate_cols, downdate_rows, factorize, plan,
                       session, trace_count, update_factorization)
from repro.core.update import (col_removal_delta, delta_factors, delta_rank,
                               materialize_lowrank, row_removal_delta)
from test_solver_parity import ZOO

KEY = jax.random.PRNGKey(77)

M, N, R = 96, 64, 8
SPEC = SVDSpec(method="fsvd", rank=R, max_iters=48)
GATE = 1e-5          # the acceptance parity gate (vs sigma_max)


def _exact(key=KEY, m=M, n=N, r=R):
    return make_lowrank(key, m, n, r)


def _delta(key, m=M, n=N, k=2, rel=1e-2, ref=None):
    ku, kv = jax.random.split(key)
    U = jax.random.normal(ku, (m, k))
    Vt = jax.random.normal(kv, (k, n))
    scale = 1.0 if ref is None else rel * float(
        jnp.linalg.norm(ref)) / float(jnp.linalg.norm(U @ Vt))
    return LowRankOp(U, jnp.full((k,), scale), Vt)


def _sigma_err(fact, A) -> float:
    s_true = jnp.linalg.svd(A, compute_uv=False)
    return float(jnp.max(jnp.abs(fact.s - s_true[: fact.rank]))
                 / s_true[0])


def _subspace_cos(fact, A) -> float:
    _, _, Vt = jnp.linalg.svd(A, full_matrices=False)
    cos = jnp.linalg.svd(Vt[: fact.rank] @ fact.V, compute_uv=False)
    return float(jnp.min(cos))


# ---------------------------------------------------------------------------
# core: update_factorization parity
# ---------------------------------------------------------------------------

def test_update_matches_cold_factorize_exact():
    """Acceptance: rank-k update of an exact rank-r factorization matches
    the dense SVD of the drifted matrix to the parity gate — zero GK."""
    A = _exact()
    fact = factorize(A, SPEC, key=KEY)
    d = _delta(jax.random.fold_in(KEY, 1), ref=A)
    upd = update_factorization(fact, d)
    A2 = A + materialize_lowrank(d)
    assert int(upd.iterations) == 0
    assert upd.method == "update"
    assert _sigma_err(upd, A2) <= GATE
    assert _subspace_cos(upd, A2) >= 1.0 - 1e-5


def test_update_on_zoo_lowrank_matches_gk_parity():
    """On the parity zoo's gapped operand the update stays within the GK
    battery's own accuracy gate (the unabsorbed noise tail is what the
    Session gate then measures)."""
    A, _ = ZOO["lowrank_noise"]
    spec = SVDSpec(method="fsvd", rank=R, max_iters=48)
    fact = factorize(A, spec, key=KEY)
    d = _delta(jax.random.fold_in(KEY, 2), m=A.shape[0], n=A.shape[1],
               rel=1e-3, ref=A)
    upd = update_factorization(fact, d)
    A2 = A + materialize_lowrank(d)
    cold = factorize(A2, spec, key=jax.random.fold_in(KEY, 3))
    assert int(upd.iterations) == 0
    assert _sigma_err(upd, A2) <= max(5e-4, 2.0 * _sigma_err(cold, A2))


def test_update_beta_decay():
    """``beta`` scales the tracked part before the delta lands."""
    A = _exact()
    fact = factorize(A, SPEC, key=KEY)
    d = _delta(jax.random.fold_in(KEY, 4), ref=A)
    upd = update_factorization(fact, d, beta=0.5)
    A2 = 0.5 * A + materialize_lowrank(d)
    assert _sigma_err(upd, A2) <= GATE


def test_update_with_scale_and_extras():
    """``LowRankOp.scale`` and ``extra`` terms fold into the delta
    factors; ``delta_rank`` counts them."""
    A = _exact()
    fact = factorize(A, SPEC, key=KEY)
    d0 = _delta(jax.random.fold_in(KEY, 5), k=1, ref=A)
    L = 1e-3 * jax.random.normal(jax.random.fold_in(KEY, 6), (M, 1))
    Rf = jax.random.normal(jax.random.fold_in(KEY, 7), (1, N))
    d = LowRankOp(d0.U, d0.s, d0.Vt, scale=2.0, extra=((L, Rf),))
    assert delta_rank(d) == 2
    C, D = delta_factors(d)
    np.testing.assert_allclose(np.asarray(C @ D.T),
                               np.asarray(materialize_lowrank(d)),
                               rtol=1e-5, atol=1e-5)
    upd = update_factorization(fact, d)
    A2 = A + materialize_lowrank(d)
    assert _sigma_err(upd, A2) <= GATE


def test_downdate_rows_and_cols():
    """Row/column removal is exact on the factored operator: zeroed
    slices vanish, the rest matches the dense SVD of the slashed
    matrix."""
    A = _exact()
    fact = factorize(A, SPEC, key=KEY)
    rows = [3, 17, 40]
    down = downdate_rows(fact, rows)
    A2 = A.at[jnp.asarray(rows), :].set(0)
    assert int(down.iterations) == 0
    assert _sigma_err(down, A2) <= GATE
    approx = (down.U * down.s[None, :]) @ down.V.T
    assert float(jnp.max(jnp.abs(approx[jnp.asarray(rows), :]))) <= \
        1e-4 * float(jnp.linalg.norm(A))

    cols = [0, 5]
    down_c = downdate_cols(fact, cols)
    A3 = A.at[:, jnp.asarray(cols)].set(0)
    assert _sigma_err(down_c, A3) <= GATE
    d_r = row_removal_delta(fact, rows)
    d_c = col_removal_delta(fact, cols)
    assert delta_rank(d_r) == 3 and delta_rank(d_c) == 2


# ---------------------------------------------------------------------------
# plan staging
# ---------------------------------------------------------------------------

def test_plan_update_compiles_once_across_deltas_and_betas():
    """One staged executable covers every same-signature delta and every
    beta (beta is passed traced)."""
    A = _exact()
    p = plan(SPEC, like=A)
    fact, _ = p.solve(A, key=KEY, with_info=True)
    clear_plan_cache()
    p = plan(SPEC, like=A)
    fact = factorize(A, SPEC, key=KEY)
    base = trace_count()
    for t, beta in enumerate((1.0, 0.9, 1.0, 0.5)):
        d = _delta(jax.random.fold_in(KEY, 30 + t), ref=A)
        upd = p.update(fact, d, beta=beta)
        A2 = beta * A + materialize_lowrank(d)
        assert _sigma_err(upd, A2) <= GATE
    assert trace_count() - base == 1
    clear_plan_cache()


def test_plan_update_rejects_non_lowrank_delta():
    A = _exact()
    p = plan(SPEC, like=A)
    fact = factorize(A, SPEC, key=KEY)
    with pytest.raises(TypeError):
        p.update(fact, jnp.ones_like(A))


# ---------------------------------------------------------------------------
# session three-way policy
# ---------------------------------------------------------------------------

def test_session_delta_stream_zero_iterations():
    """A stream of structured drifts rides the update branch end to end —
    zero GK iterations after the cold solve, accuracy held."""
    A = _exact()
    sess = session(A, SPEC, key=KEY)
    sess.solve()
    cur = A
    for t in range(4):
        d = _delta(jax.random.fold_in(KEY, 50 + t), rel=1e-3, ref=cur)
        fact = sess.delta(d)
        cur = cur + materialize_lowrank(d)
        assert sess.history[-1]["kind"] == "update"
        assert sess.history[-1]["iterations"] == 0
        assert _sigma_err(fact, cur) <= 1e-4
    assert sess.counts()["update"] == 4
    assert sess.meta()["updates"] == 4


def test_session_gate_rejects_and_annotates():
    """A pinned impossible gate forces rejection: the fallback GK solve
    runs and the history records why the cheap path was not taken."""
    A, _ = ZOO["lowrank_noise"]
    sess = session(A, SVDSpec(method="fsvd", rank=R, max_iters=48),
                   key=KEY, update_tol=1e-12)
    sess.solve()
    d = _delta(jax.random.fold_in(KEY, 60), m=A.shape[0], n=A.shape[1],
               rel=1e-3, ref=A)
    sess.delta(d)
    rec = sess.history[-1]
    assert rec["kind"] in ("refine", "restart")
    assert rec["update_rejected"] is True
    assert rec["residual_update"] > rec["gate"] == 1e-12
    assert "update" not in sess.counts()


def test_session_downdate():
    A = _exact()
    sess = session(A, SPEC, key=KEY)
    with pytest.raises(RuntimeError):
        sess.downdate(rows=[0])
    sess.solve()
    with pytest.raises(ValueError):
        sess.downdate(rows=[0], cols=[1])
    fact = sess.downdate(rows=[2, 9])
    A2 = A.at[jnp.asarray([2, 9]), :].set(0)
    assert sess.history[-1]["kind"] == "downdate"
    assert sess.counts()["downdate"] == 1
    assert _sigma_err(fact, A2) <= 1e-4
    # the folded operand is the zeroed dense matrix: tracking continues
    assert float(jnp.max(jnp.abs(sess.op.A[jnp.asarray([2, 9]), :]))) == 0.0


def test_session_oversized_delta_falls_back():
    """rank + delta_rank > min(shape) can't augment: the delta folds and
    re-solves instead of crashing the thin-QR."""
    m, n, r = 24, 10, 8
    A = make_lowrank(jax.random.fold_in(KEY, 70), m, n, r)
    sess = session(A, SVDSpec(method="fsvd", rank=r, max_iters=10),
                   key=KEY)
    sess.solve()
    d = _delta(jax.random.fold_in(KEY, 71), m=m, n=n, k=4, rel=1e-3,
               ref=A)
    sess.delta(d)
    assert sess.history[-1]["kind"] in ("refine", "restart")


# ---------------------------------------------------------------------------
# persistence of the policy knobs (satellite: restore/load_latest)
# ---------------------------------------------------------------------------

def test_restore_preserves_policy_knobs_and_updates(tmp_path):
    """``Session.restore`` / ``load_latest`` carry ``track_residuals``,
    ``restart_angle`` and ``update_tol`` — and the history (update counts
    included) round-trips bit-equal."""
    A = _exact()
    sess = session(A, SPEC, key=KEY, track_residuals=False,
                   restart_angle=0.3, update_tol=1e-3)
    sess.solve()
    d = _delta(jax.random.fold_in(KEY, 80), rel=1e-4, ref=A)
    sess.delta(d)
    assert sess.counts()["update"] == 1
    meta = sess.meta()
    assert meta["track_residuals"] is False
    assert meta["restart_angle"] == 0.3
    assert meta["update_tol"] == 1e-3
    assert meta["updates"] == 1
    sess.save(str(tmp_path))

    A2 = A + materialize_lowrank(d)
    back = Session.restore(str(tmp_path), A2, key=KEY)
    assert back.track_residuals is False
    assert back.restart_angle == 0.3
    assert back.update_tol == 1e-3
    assert back.history == sess.history
    assert back.counts() == sess.counts()

    fresh = session(A2, SPEC, key=KEY)          # default knobs
    assert fresh.load_latest(str(tmp_path))
    assert fresh.track_residuals is False
    assert fresh.restart_angle == 0.3
    assert fresh.update_tol == 1e-3
    assert fresh.history == sess.history


# ---------------------------------------------------------------------------
# no-host-sync contract (satellite: lazy history scalars)
# ---------------------------------------------------------------------------

def test_untracked_solve_issues_no_extra_host_sync(monkeypatch):
    """With ``track_residuals=False`` and a pinned refine budget, a warm
    tracked solve converts at most ONE device scalar to host (the drift
    policy read) — recording history must not add a sync per solve."""
    from jax._src.array import ArrayImpl
    A, _ = ZOO["lowrank_noise"]
    drifts = [A + 1e-4 * jnp.linalg.norm(A) * make_lowrank(
        jax.random.fold_in(KEY, 90 + t), *A.shape, 2) for t in (0, 1)]
    sess = session(A, SPEC, key=KEY, track_residuals=False,
                   refine_iters=16)
    sess.solve()
    sess.update(drifts[0])            # warm: both executables staged

    calls = []

    def _wrap(name, orig):
        def wrapper(self, *a, **kw):
            calls.append(name)
            return orig(self, *a, **kw)
        return wrapper

    for name in ("__array__", "__int__", "__float__", "__bool__",
                 "__index__"):
        orig = getattr(ArrayImpl, name, None)
        if orig is not None:
            monkeypatch.setattr(ArrayImpl, name, _wrap(name, orig))
    sess.update(drifts[1])
    assert len(calls) <= 1, calls
    # reading history IS the sync point
    assert isinstance(sess.history[-1]["iterations"], int)
