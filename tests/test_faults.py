"""Fault-injection registry: armed/disarmed fast paths, probability and
max_fires semantics, seeded determinism, corrupt-mode value crossings,
context-manager scoping, thread safety, and the chaos preset."""
import threading

import numpy as np
import pytest

from repro.runtime import faults
from repro.runtime.faults import FaultInjected, TransientFault


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.disarm_all()
    faults.reset_stats()
    yield
    faults.disarm_all()


def test_disarmed_is_noop():
    # never armed: fire/corrupt must be free and inert
    faults.fire("some.point")
    assert faults.corrupt("some.point", b"abc") == b"abc"
    assert not faults.armed("some.point")


def test_raise_mode_fires_with_p1():
    faults.arm("t.raise", mode="raise", p=1.0)
    assert faults.armed("t.raise")
    with pytest.raises(FaultInjected):
        faults.fire("t.raise")
    assert faults.fire_count("t.raise") == 1


def test_transient_raises_retryable_subtype():
    faults.arm("t.transient", mode="raise", p=1.0, transient=True)
    with pytest.raises(TransientFault):
        faults.fire("t.transient")
    # TransientFault IS a FaultInjected: generic handlers still catch it
    assert issubclass(TransientFault, FaultInjected)


def test_max_fires_bounds_the_blast_radius():
    faults.arm("t.bounded", mode="raise", p=1.0, max_fires=2)
    for _ in range(2):
        with pytest.raises(FaultInjected):
            faults.fire("t.bounded")
    faults.fire("t.bounded")            # exhausted: no-op
    assert faults.fire_count("t.bounded") == 2


def test_probability_is_seeded_and_deterministic():
    def sequence():
        faults.arm("t.seeded", mode="raise", p=0.5, seed=123)
        hits = []
        for _ in range(64):
            try:
                faults.fire("t.seeded")
                hits.append(0)
            except FaultInjected:
                hits.append(1)
        faults.disarm("t.seeded")
        return hits

    a, b = sequence(), sequence()
    assert a == b                        # same seed -> same draw sequence
    assert 0 < sum(a) < 64               # actually probabilistic


def test_corrupt_mode_flips_bytes_and_nans_floats():
    faults.arm("t.corrupt", mode="corrupt", p=1.0)
    raw = b"\x00" * 16
    assert faults.corrupt("t.corrupt", raw) != raw
    arr = np.ones(8, np.float32)
    out = faults.corrupt("t.corrupt", arr.copy())
    assert not np.isfinite(np.asarray(out)).all()


def test_inject_context_manager_scopes_the_fault():
    with faults.inject("t.scoped", mode="raise", p=1.0):
        with pytest.raises(FaultInjected):
            faults.fire("t.scoped")
    faults.fire("t.scoped")              # disarmed on exit


def test_delay_mode_sleeps():
    import time
    faults.arm("t.delay", mode="delay", p=1.0, delay_s=0.05)
    t0 = time.perf_counter()
    faults.fire("t.delay")
    assert time.perf_counter() - t0 >= 0.045


def test_thread_safety_under_concurrent_fire():
    faults.arm("t.mt", mode="raise", p=1.0, max_fires=50)
    fired = []

    def worker():
        for _ in range(25):
            try:
                faults.fire("t.mt")
            except FaultInjected:
                fired.append(1)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # max_fires is exact even under contention
    assert len(fired) == 50
    assert faults.fire_count("t.mt") == 50


def test_chaos_preset_arms_and_restores():
    with faults.chaos(0, dispatch_crash_p=0.5, solve_transient_p=0.5):
        assert faults.armed(faults.SERVE_DISPATCH)
        assert faults.armed(faults.PLAN_SOLVE)
    assert not faults.armed(faults.SERVE_DISPATCH)
    assert not faults.armed(faults.PLAN_SOLVE)
