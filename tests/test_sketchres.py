"""Sketch-resident operators: maintained panels, folds, staleness,
reconstruction, and the Session/serve wiring of the fourth policy branch.

The load-bearing invariant is *linearity*: folding a drift into the
resident panels must agree with a fresh sketch of the drifted operand
drawn from the same seeds — the fold is exact, only staleness (coverage,
adaptivity, storage rounding) degrades the panels.  Everything here pins
that invariant and the policy built on top of it: zero-iteration
sketch-reconstruct answers are only ever served residual-probe-verified,
and a tripped staleness odometer falls back to a re-sketch plus a REAL
solve, never an unverified reconstruction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SVDSpec, Session, clear_plan_cache, trace_count
from repro.api.plan import plan as make_plan
from repro.core.operators import DenseOp, LowRankOp
from repro.sketchres import (BUDGET, apply_dense_delta, apply_entries,
                             apply_lowrank_delta, is_stale, pad_entries,
                             reconstruct, sketch_operand, staleness_ratio)

KEY = jax.random.PRNGKey(3)
SPEC = SVDSpec(method="gnystrom", rank=6, oversample=8)


def _lowrank(key, m, n, r, dtype=jnp.float32):
    ku, kv = jax.random.split(key)
    U = jax.random.normal(ku, (m, r))
    V = jax.random.normal(kv, (n, r))
    s = jnp.logspace(0.0, -2.0, r)
    return ((U * s) @ V.T).astype(dtype)


def _entries(rng, m, n, e, scale=1e-3):
    rows = rng.integers(0, m, e).astype(np.int32)
    cols = rng.integers(0, n, e).astype(np.int32)
    vals = (scale * rng.standard_normal(e)).astype(np.float32)
    return rows, cols, vals


def _coo_apply(A, rows, cols, vals):
    A2 = np.asarray(A).copy()
    np.add.at(A2, (np.asarray(rows), np.asarray(cols)), np.asarray(vals))
    return jnp.asarray(A2)


# --------------------------------------------------------------------------
# state + folds
# --------------------------------------------------------------------------

def test_sketch_operand_panels_match_dense_test_matrices():
    A = _lowrank(jax.random.PRNGKey(0), 40, 30, 6)
    st = sketch_operand(A, SPEC, key=KEY)
    om, ps = st.sketches()
    np.testing.assert_allclose(np.asarray(st.Y),
                               np.asarray(A @ om.dense()),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st.Z),
                               np.asarray(ps.dense().T @ A),
                               rtol=0, atol=1e-5)
    assert float(st.folded_mass) == 0.0
    assert float(st.base_norm) > 0.0


def test_apply_entries_matches_fresh_sketch_same_seeds():
    """The tentpole invariant: a fold IS the sketch of the drifted operand
    (same seeds), to f32/scatter roundoff."""
    rng = np.random.default_rng(1)
    A = _lowrank(jax.random.PRNGKey(1), 48, 36, 6)
    st = sketch_operand(A, SPEC, key=KEY)
    rows, cols, vals = _entries(rng, 48, 36, 300, scale=1e-2)
    folded = apply_entries(st, rows, cols, vals)
    fresh = sketch_operand(_coo_apply(A, rows, cols, vals), SPEC, key=KEY)
    scale = float(jnp.linalg.norm(fresh.Y))
    assert float(jnp.linalg.norm(folded.Y.astype(jnp.float32)
                                 - fresh.Y.astype(jnp.float32))) < 1e-5 * scale
    scale = float(jnp.linalg.norm(fresh.Z))
    assert float(jnp.linalg.norm(folded.Z.astype(jnp.float32)
                                 - fresh.Z.astype(jnp.float32))) < 1e-5 * scale


def test_apply_dense_delta_equals_entry_fold():
    rng = np.random.default_rng(2)
    A = _lowrank(jax.random.PRNGKey(2), 32, 24, 5)
    st = sketch_operand(A, SPEC, key=KEY)
    D = (1e-3 * rng.standard_normal((32, 24))).astype(np.float32)
    rr, cc = np.meshgrid(np.arange(32), np.arange(24), indexing="ij")
    via_entries = apply_entries(st, rr.ravel(), cc.ravel(), D.ravel())
    via_block = apply_dense_delta(st, jnp.asarray(D))
    np.testing.assert_allclose(np.asarray(via_entries.Y),
                               np.asarray(via_block.Y), rtol=0, atol=2e-5)
    np.testing.assert_allclose(np.asarray(via_entries.Z),
                               np.asarray(via_block.Z), rtol=0, atol=2e-5)
    # dense-block mass is the exact ‖D‖_F = ℓ2 of the entry values
    np.testing.assert_allclose(float(via_entries.folded_mass),
                               float(via_block.folded_mass), rtol=1e-5)


def test_apply_lowrank_delta_matches_materialized():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    A = _lowrank(k1, 40, 28, 5)
    st = sketch_operand(A, SPEC, key=KEY)
    U = jax.random.normal(k2, (40, 2))
    Vt = jax.random.normal(jax.random.PRNGKey(5), (2, 28))
    s = jnp.asarray([1e-3, 5e-4])
    dop = LowRankOp(U, s, Vt)
    via_op = apply_lowrank_delta(st, dop)
    via_dense = apply_dense_delta(st, (U * s) @ Vt)
    np.testing.assert_allclose(np.asarray(via_op.Y),
                               np.asarray(via_dense.Y), rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(via_op.Z),
                               np.asarray(via_dense.Z), rtol=0, atol=1e-5)


def test_pallas_and_xla_backends_agree():
    rng = np.random.default_rng(3)
    A = _lowrank(jax.random.PRNGKey(6), 36, 30, 5)
    st_x = sketch_operand(A, SPEC, key=KEY, backend="xla")
    st_p = dataclasses.replace(st_x, backend="pallas")
    rows, cols, vals = _entries(rng, 36, 30, 200)
    fx = apply_entries(st_x, rows, cols, vals)
    fp = apply_entries(st_p, rows, cols, vals)
    np.testing.assert_allclose(np.asarray(fx.Y), np.asarray(fp.Y),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fx.Z), np.asarray(fp.Z),
                               rtol=0, atol=1e-6)


def test_pad_entries_is_exact_noop():
    rng = np.random.default_rng(4)
    A = _lowrank(jax.random.PRNGKey(7), 24, 20, 4)
    st = sketch_operand(A, SPEC, key=KEY)
    rows, cols, vals = _entries(rng, 24, 20, 37)
    pr, pc, pv = pad_entries(rows, cols, vals)
    assert pr.shape[0] == 64                       # quantum, pow2
    assert pad_entries(*_entries(rng, 24, 20, 65))[0].shape[0] == 128
    raw = apply_entries(st, rows, cols, vals)
    padded = apply_entries(st, pr, pc, pv)
    np.testing.assert_array_equal(np.asarray(raw.Y), np.asarray(padded.Y))
    np.testing.assert_array_equal(np.asarray(raw.Z), np.asarray(padded.Z))
    np.testing.assert_allclose(float(raw.folded_mass),
                               float(padded.folded_mass), rtol=1e-6)


def test_staleness_odometer_trips_and_only_then():
    A = _lowrank(jax.random.PRNGKey(8), 32, 24, 4)
    st = sketch_operand(A, SPEC, key=KEY)
    assert not bool(is_stale(st))
    # a fold of exactly budget*base_norm mass lands the ratio on 1.0
    target = float(st.budget * st.base_norm)
    small = apply_entries(st, [0], [0], [0.1 * target])
    assert not bool(is_stale(small))
    assert 0.0 < float(staleness_ratio(small)) < 1.0
    big = apply_entries(small, [1], [1], [target])
    assert bool(is_stale(big))
    assert float(staleness_ratio(big)) >= 1.0


def test_reconstruct_zero_iterations_and_accuracy():
    A = _lowrank(jax.random.PRNGKey(9), 60, 44, 6)
    st = sketch_operand(A, SPEC, key=KEY)
    f = reconstruct(st, SPEC)
    assert int(f.iterations) == 0
    assert f.method == "sketch"
    assert not bool(f.breakdown)
    Ahat = (f.U * f.s) @ f.V.T
    rel = float(jnp.linalg.norm(Ahat - A) / jnp.linalg.norm(A))
    assert rel < 1e-4                               # exact-rank operand


def test_reconstruct_tracks_folded_drift():
    """A rank-1 block shipped entry-by-entry: after the fold, reconstruct
    matches the drifted operand (still within the rank budget) and is far
    from the pre-drift one — the panels genuinely moved."""
    m, n = 60, 44
    A = _lowrank(jax.random.PRNGKey(10), m, n, 5)
    u = jax.random.normal(jax.random.PRNGKey(30), (m,))
    v = jax.random.normal(jax.random.PRNGKey(31), (n,))
    D = 0.05 * jnp.outer(u, v)                      # rank-1, ~5% mass
    st = sketch_operand(A, SPEC, key=KEY)
    rr, cc = np.meshgrid(np.arange(m), np.arange(n), indexing="ij")
    st = apply_entries(st, rr.ravel(), cc.ravel(), np.asarray(D).ravel())
    A2 = A + D
    f = reconstruct(st, SPEC)
    rel = float(jnp.linalg.norm((f.U * f.s) @ f.V.T - A2)
                / jnp.linalg.norm(A2))
    stale_rel = float(jnp.linalg.norm((f.U * f.s) @ f.V.T - A)
                      / jnp.linalg.norm(A))
    assert rel < 1e-3
    assert stale_rel > 10 * rel


# --------------------------------------------------------------------------
# plan staging
# --------------------------------------------------------------------------

def test_plan_sketch_fold_stages_per_padded_length():
    clear_plan_cache()
    rng = np.random.default_rng(6)
    A = _lowrank(jax.random.PRNGKey(11), 40, 32, 5)
    p = make_plan(SPEC, like=DenseOp(A))
    st = p.sketch(A, key=KEY)
    t0 = trace_count()
    for e in (10, 20, 33, 60):                      # all pad to 64
        rows, cols, vals = _entries(rng, 40, 32, e)
        st = p.sketch_fold(st, rows, cols, vals)
    assert trace_count() - t0 == 1                  # one padded length
    st = p.sketch_fold(st, *_entries(rng, 40, 32, 100))   # pads to 128
    assert trace_count() - t0 == 2
    f1 = p.sketch_reconstruct(st)
    t1 = trace_count()
    f2 = p.sketch_reconstruct(st)
    assert trace_count() == t1                      # cached executable
    assert int(f1.iterations) == int(f2.iterations) == 0


def test_plan_sketch_memoizes_per_operand_signature():
    clear_plan_cache()
    A = _lowrank(jax.random.PRNGKey(12), 40, 32, 5)
    p = make_plan(SPEC, like=DenseOp(A))
    p.sketch(A, key=KEY)
    t0 = trace_count()
    p.sketch(A + 1.0, key=jax.random.PRNGKey(99))   # same signature
    assert trace_count() == t0


# --------------------------------------------------------------------------
# Session: the fourth policy branch
# --------------------------------------------------------------------------

def _drift_step(rng, sess, m, n, e=48, scale=5e-4):
    rows, cols, vals = _entries(rng, m, n, e, scale=scale)
    fact = sess.entries(rows, cols, vals)
    return fact, sess.history[-1]


def test_session_entries_sketch_branch_zero_iterations():
    rng = np.random.default_rng(7)
    m, n = 48, 36
    A = _lowrank(jax.random.PRNGKey(13), m, n, 6)
    sess = Session(A, SVDSpec(method="fsvd", rank=6), key=KEY,
                   sketch_tol=5e-3)
    sess.solve()
    kinds = []
    for _ in range(4):
        fact, rec = _drift_step(rng, sess, m, n)
        kinds.append(rec["kind"])
        if rec["kind"] == "sketch":
            assert rec["iterations"] == 0
            assert rec["probe"] <= rec["gate"] == 5e-3
            assert 0.0 < rec["staleness"] < 1.0
    assert kinds.count("sketch") >= 3
    # parity: the final answer matches the dense SVD of the drifted
    # operand at the probe's accuracy scale
    s_true = np.linalg.svd(np.asarray(sess.op.A), compute_uv=False)[:6]
    err = float(np.max(np.abs(np.asarray(sess.fact.s) - s_true))
                / s_true[0])
    assert err < 5e-3
    assert sess.counts()["sketch"] == kinds.count("sketch")
    assert sess.meta()["sketches"] == kinds.count("sketch")


def test_session_entries_staleness_falls_back_to_real_solve():
    rng = np.random.default_rng(8)
    m, n = 40, 30
    A = _lowrank(jax.random.PRNGKey(14), m, n, 5)
    sess = Session(A, SVDSpec(method="fsvd", rank=5), key=KEY,
                   sketch_tol=1e-2)
    sess.solve()
    _drift_step(rng, sess, m, n)                    # sketch resident now
    # one huge batch trips the odometer
    fact, rec = _drift_step(rng, sess, m, n, e=600, scale=1.0)
    assert rec["kind"] in ("refine", "restart")     # a REAL solve
    assert rec["sketch_stale"] is True
    assert rec["staleness"] >= 1.0
    assert "probe" not in rec                       # never reconstructed
    # the re-sketch reset the odometer and tracks the post-drift operand
    assert sess.sketch is not None
    assert float(sess.sketch.folded_mass) == 0.0
    om, _ = sess.sketch.sketches()
    np.testing.assert_allclose(np.asarray(sess.sketch.Y),
                               np.asarray(sess.op.A @ om.dense()),
                               rtol=1e-3, atol=1e-3)


def test_session_entries_rejection_annotates_fallback():
    rng = np.random.default_rng(9)
    m, n = 40, 30
    A = _lowrank(jax.random.PRNGKey(15), m, n, 5)
    sess = Session(A, SVDSpec(method="fsvd", rank=5), key=KEY,
                   sketch_tol=1e-12)                # impossible gate
    sess.solve()
    fact, rec = _drift_step(rng, sess, m, n)
    assert rec["kind"] in ("refine", "restart")
    assert rec["sketch_rejected"] is True
    assert rec["probe"] > rec["gate"] == 1e-12


def test_session_entries_sketch_tol_zero_disables_path():
    rng = np.random.default_rng(10)
    m, n = 32, 24
    A = _lowrank(jax.random.PRNGKey(16), m, n, 4)
    sess = Session(A, SVDSpec(method="fsvd", rank=4), key=KEY,
                   sketch_tol=0.0)
    sess.solve()
    for _ in range(2):
        fact, rec = _drift_step(rng, sess, m, n)
        assert rec["kind"] in ("refine", "restart")
    assert sess.sketch is None                      # never even built
    assert "sketch" not in sess.counts()


def test_session_entries_requires_dense_operand():
    U = jax.random.normal(jax.random.PRNGKey(17), (20, 3))
    Vt = jax.random.normal(jax.random.PRNGKey(18), (3, 16))
    sess = Session(LowRankOp(U, jnp.ones(3), Vt),
                   SVDSpec(method="fsvd", rank=3), key=KEY)
    with pytest.raises(TypeError, match="dense operand"):
        sess.entries([0], [0], [1.0])
    with pytest.raises(ValueError, match="equal lengths"):
        Session(jnp.ones((8, 8)), SVDSpec(method="fsvd", rank=2),
                key=KEY).entries([0, 1], [0], [1.0])


def test_session_delta_keeps_resident_sketch_live():
    """A structured delta between entry batches folds into the resident
    panels (sketch linearity) instead of invalidating them."""
    rng = np.random.default_rng(11)
    m, n = 40, 30
    A = _lowrank(jax.random.PRNGKey(19), m, n, 5)
    sess = Session(A, SVDSpec(method="fsvd", rank=5), key=KEY,
                   sketch_tol=1e-2)
    sess.solve()
    _drift_step(rng, sess, m, n)                    # sketch resident
    U = jax.random.normal(jax.random.PRNGKey(20), (m, 1))
    Vt = jax.random.normal(jax.random.PRNGKey(21), (1, n))
    sess.delta(LowRankOp(U, jnp.asarray([1e-4]), Vt))
    assert sess.sketch is not None
    om, _ = sess.sketch.sketches()
    np.testing.assert_allclose(np.asarray(sess.sketch.Y),
                               np.asarray(sess.op.A @ om.dense()),
                               rtol=1e-3, atol=1e-3)
    # wholesale replacement drops it
    sess.update(jnp.asarray(sess.op.A) + 0.0)
    assert sess.sketch is None


# --------------------------------------------------------------------------
# satellite 2: accepted paths annotate their gate value
# --------------------------------------------------------------------------

def test_accepted_update_and_sketch_records_carry_gate():
    rng = np.random.default_rng(12)
    m, n = 48, 36
    A = _lowrank(jax.random.PRNGKey(22), m, n, 5)
    sess = Session(A, SVDSpec(method="fsvd", rank=5), key=KEY,
                   update_tol=1e-3, sketch_tol=5e-3)
    sess.solve()
    # accepted rank-1 update
    U = jax.random.normal(jax.random.PRNGKey(23), (m, 1))
    Vt = jax.random.normal(jax.random.PRNGKey(24), (1, n))
    sess.delta(LowRankOp(U, jnp.asarray([1e-6]), Vt))
    upd = sess.history[-1]
    assert upd["kind"] == "update"
    assert upd["gate"] == 1e-3 and upd["residual_update"] <= 1e-3
    # accepted sketch-reconstruct
    for _ in range(3):
        fact, rec = _drift_step(rng, sess, m, n, e=32, scale=2e-4)
        if rec["kind"] == "sketch":
            break
    assert rec["kind"] == "sketch"
    assert rec["gate"] == 5e-3 and rec["probe"] <= 5e-3
    # meta() round-trips the annotations as plain JSON scalars
    import json
    hist = sess.meta()["history"]
    json.dumps(hist)
    assert any("gate" in r for r in hist)


# --------------------------------------------------------------------------
# satellite 1: spec validation for the sketch solvers
# --------------------------------------------------------------------------

def test_spec_rejects_rbk_zero_passes():
    with pytest.raises(ValueError, match="at least one pass"):
        SVDSpec(method="rbk", passes=0)
    SVDSpec(method="rbk", passes=1)                 # fine
    SVDSpec(method="gnystrom", passes=0)            # sketch-only regime


@pytest.mark.parametrize("method", ["rbk", "gnystrom"])
def test_spec_rejects_sketch_dim_below_rank(method):
    with pytest.raises(ValueError, match="sketch_dim"):
        SVDSpec(method=method, rank=8, sketch_dim=4)
    SVDSpec(method=method, rank=8, sketch_dim=8)    # boundary is legal
    SVDSpec(method="fsvd", rank=8, sketch_dim=4)    # other methods: no-op
